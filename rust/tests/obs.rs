//! Observability integration: a traced host training run must produce a
//! valid Chrome trace with the expected span hierarchy, and the metrics
//! endpoint must serve the registry over HTTP.

use std::io::{Read, Write};

use deltanet::config::DataConfig;
use deltanet::data::build_task;
use deltanet::obs;
use deltanet::runtime::Runtime;
use deltanet::util::json::Json;

#[derive(Debug)]
struct Ev {
    name: String,
    ts: f64,
    dur: f64,
    tid: f64,
    depth: f64,
}

fn span_events(trace: &Json) -> Vec<Ev> {
    trace
        .get("traceEvents")
        .expect("traceEvents key")
        .as_arr()
        .expect("traceEvents array")
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
        .map(|e| Ev {
            name: e.get("name").unwrap().as_str().unwrap().to_string(),
            ts: e.get("ts").unwrap().as_f64().unwrap(),
            dur: e.get("dur").unwrap().as_f64().unwrap(),
            tid: e.get("tid").unwrap().as_f64().unwrap(),
            depth: e
                .get("args")
                .and_then(|a| a.get("depth"))
                .map(|d| d.as_f64().unwrap())
                .unwrap_or(0.0),
        })
        .collect()
}

/// `inner` strictly nests inside `outer`: same thread, time-contained,
/// one or more levels deeper.
fn nests_within(inner: &Ev, outer: &Ev) -> bool {
    let eps = 1e-3; // µs slop for f64 rounding
    inner.tid == outer.tid
        && inner.ts + eps >= outer.ts
        && inner.ts + inner.dur <= outer.ts + outer.dur + eps
        && inner.depth > outer.depth
}

#[test]
fn traced_host_training_emits_nested_chrome_trace() {
    obs::trace::enable();

    // two host training steps through the Trainer (span: train.step)
    let runtime = Runtime::new("definitely-missing-artifacts").unwrap();
    let mut trainer = deltanet::coordinator::Trainer::new(
        &runtime, "deltanet_tiny", 3).unwrap();
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 9 });
    for _ in 0..2 {
        let b = task.sample(trainer.batch, trainer.seq_len);
        trainer.train_step(&b, 1e-3).unwrap();
    }
    let bd = trainer.last_breakdown().expect("host engine breakdown");
    assert!(bd.forward_ms >= 0.0 && bd.backward_ms >= 0.0);
    assert!(bd.grad_norm.is_finite());

    let dir = std::env::temp_dir().join("deltanet_obs_trace_test");
    let path = dir.join("trace.json");
    obs::trace::write_trace(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let trace = Json::parse(&text).unwrap();
    let events = span_events(&trace);
    std::fs::remove_dir_all(&dir).ok();

    let have = |n: &str| events.iter().filter(|e| e.name == n);
    for name in ["train.step", "train.forward", "train.backward",
                 "train.optimizer", "model.forward", "kernel.batch",
                 "kernel.chunkwise.forward", "kernel.chunkwise.chunk"] {
        assert!(have(name).next().is_some(),
                "no {name:?} span in trace; got {:?}",
                events.iter().map(|e| &e.name).collect::<Vec<_>>());
    }

    // phases nest inside a train.step on the SAME thread
    for phase in ["train.forward", "train.backward", "train.optimizer"] {
        assert!(
            have(phase).any(|p| have("train.step")
                .any(|s| nests_within(p, s))),
            "{phase} span does not nest inside any train.step span");
    }
    // per-chunk kernel spans nest inside a kernel forward (pool threads)
    assert!(
        have("kernel.chunkwise.chunk").any(|c| have("kernel.chunkwise.forward")
            .any(|f| nests_within(c, f))),
        "kernel.chunkwise.chunk does not nest in kernel.chunkwise.forward");

    // the train.* step histograms were fed by the same run
    assert!(obs::metrics::histogram("train.forward_ms").count() >= 2);
    assert!(obs::metrics::counter("train.steps").get() >= 2);
}

fn fetch(addr: std::net::SocketAddr, path: &str) -> String {
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    write!(conn, "GET {path} HTTP/1.1\r\nHost: t\r\n\
                  Connection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    conn.read_to_string(&mut buf).unwrap();
    buf
}

#[test]
fn metrics_endpoint_serves_decode_histograms() {
    // the serving path records these; simulate a few decode latencies
    let h = obs::metrics::histogram("serve.decode_ms");
    for ms in [4.0, 8.0, 15.0, 40.0] {
        h.record(ms);
    }
    let server = match obs::export::serve_metrics("127.0.0.1:0") {
        Ok(s) => s,
        // sandboxes without loopback sockets: skip rather than fail
        Err(_) => return,
    };
    let addr = server.addr();

    let text = fetch(addr, "/metrics");
    assert!(text.starts_with("HTTP/1.1 200"), "bad response: {text}");
    assert!(text.contains("serve.decode_ms"));
    assert!(text.contains("p50_ms") && text.contains("p95_ms")
            && text.contains("p99_ms"));

    let raw = fetch(addr, "/metrics.json");
    assert!(raw.starts_with("HTTP/1.1 200"));
    let body = &raw[raw.find("\r\n\r\n").unwrap() + 4..];
    let j = Json::parse(body).unwrap();
    let hist = j.get("histograms").expect("histograms section")
        .get("serve.decode_ms").expect("serve.decode_ms histogram");
    assert!(hist.get("p95_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(hist.get("count").unwrap().as_f64().unwrap() >= 4.0);

    assert!(fetch(addr, "/definitely-not-a-route")
        .starts_with("HTTP/1.1 404"));
    server.shutdown();
}

/// Raw request with an arbitrary method (fetch() is GET-only).
fn request(addr: std::net::SocketAddr, method: &str, path: &str) -> String {
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    write!(conn, "{method} {path} HTTP/1.1\r\nHost: t\r\n\
                  Connection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    conn.read_to_string(&mut buf).unwrap();
    buf
}

#[test]
fn export_routes_health_flight_and_errors() {
    let server = match obs::export::serve_metrics("127.0.0.1:0") {
        Ok(s) => s,
        Err(_) => return, // no loopback in this sandbox
    };
    let addr = server.addr();

    // healthz: OK while the health gauge is not failing
    let hz = fetch(addr, "/healthz");
    assert!(hz.starts_with("HTTP/1.1 200"), "bad /healthz: {hz}");
    assert!(hz.ends_with("ok\n"));

    // flight.json serves the live ring with the dump schema
    obs::flight::record(obs::flight::EventKind::Mark,
                        "test.obs.export_mark", &[("v", 1.0)]);
    let raw = fetch(addr, "/flight.json");
    assert!(raw.starts_with("HTTP/1.1 200"), "bad /flight.json: {raw}");
    let body = &raw[raw.find("\r\n\r\n").unwrap() + 4..];
    let j = Json::parse(body).unwrap();
    assert_eq!(j.get("schema").unwrap().as_str().unwrap(),
               obs::flight::SCHEMA);
    assert!(j.get("events").unwrap().as_arr().unwrap().iter().any(
        |e| e.get("name").unwrap().as_str().unwrap()
            == "test.obs.export_mark"));

    // wrong method → 405 with an Allow header; unknown path → 404
    let post = request(addr, "POST", "/metrics");
    assert!(post.starts_with("HTTP/1.1 405"), "bad POST response: {post}");
    assert!(post.contains("Allow: GET"));
    assert!(request(addr, "DELETE", "/healthz")
        .starts_with("HTTP/1.1 405"));
    assert!(fetch(addr, "/flight").starts_with("HTTP/1.1 404"));
    server.shutdown();
}

#[test]
fn concurrent_scrapes_see_consistent_snapshots() {
    let server = match obs::export::serve_metrics("127.0.0.1:0") {
        Ok(s) => s,
        Err(_) => return, // no loopback in this sandbox
    };
    let addr = server.addr();
    let c = obs::metrics::counter("test.obs.scrape_races");
    let before = c.get();

    // 4 scraper threads hammer /metrics while a writer bumps the counter:
    // every response must be complete and carry a value in [before, after]
    let stop = std::sync::Arc::new(
        std::sync::atomic::AtomicBool::new(false));
    let writer = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let c = obs::metrics::counter("test.obs.scrape_races");
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                c.inc();
            }
        })
    };
    let scrapers: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let mut seen = 0u64;
                for _ in 0..25 {
                    let text = fetch(addr, "/metrics");
                    assert!(text.starts_with("HTTP/1.1 200"),
                            "scrape failed: {text}");
                    let line = text.lines()
                        .find(|l| l.contains("test.obs.scrape_races"))
                        .expect("counter line present");
                    let v: u64 = line.rsplit(' ').next().unwrap()
                        .parse().expect("counter value parses");
                    assert!(v >= seen, "counter went backwards: {v} < {seen}");
                    seen = v;
                }
                seen
            })
        })
        .collect();
    let max_seen = scrapers.into_iter()
        .map(|t| t.join().unwrap())
        .max().unwrap();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    writer.join().unwrap();
    assert!(max_seen >= before,
            "scrapes never observed the live counter");
    assert!(c.get() >= max_seen, "snapshot overshot the writer");
    server.shutdown();
}
