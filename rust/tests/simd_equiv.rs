//! Pin the dispatched SIMD kernels to independent scalar references.
//!
//! Every `tensor::simd` primitive is checked against a plainly-written
//! scalar loop (re-implemented here, NOT the library's own fallback) at
//! deliberately awkward sizes — 1, 7, 31, 33, 100 — and on unaligned
//! slices, so lane remainders, edge tiles, and tail handling are all
//! exercised.  Under `DELTANET_SIMD=off` (CI runs this whole suite that
//! way too) both sides take the scalar path and the tests pin the
//! fallback to the same contract.
//!
//! These tests never call `simd::force_level` — the test harness runs
//! them in parallel and the dispatch level is process-global.

use deltanet::tensor::rng::Rng;
use deltanet::tensor::simd;

const SIZES: [usize; 5] = [1, 7, 31, 33, 100];

fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-4f32 + 1e-4 * w.abs();
        assert!((g - w).abs() <= tol,
                "{what}[{i}]: got {g}, want {w} (tol {tol})");
    }
}

// ------------------------------------------------- scalar references --

fn ref_dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn ref_axpy(y: &mut [f32], s: f32, b: &[f32]) {
    for (yi, bi) in y.iter_mut().zip(b) {
        *yi += s * bi;
    }
}

fn ref_matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize,
                  kd: usize, n: usize) {
    for i in 0..m {
        for p in 0..kd {
            let aip = a[i * kd + p];
            for j in 0..n {
                out[i * n + j] += aip * b[p * n + j];
            }
        }
    }
}

fn ref_matmul_nt_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize,
                     kd: usize, n: usize) {
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] += ref_dot(&a[i * kd..(i + 1) * kd],
                                      &b[j * kd..(j + 1) * kd]);
        }
    }
}

// -------------------------------------------------------------- tests --

#[test]
fn dot_matches_reference_at_odd_sizes() {
    let mut rng = Rng::new(1);
    for n in SIZES {
        let a = fill(&mut rng, n);
        let b = fill(&mut rng, n);
        let got = simd::dot(&a, &b);
        let want = ref_dot(&a, &b);
        assert_close(&[got], &[want], &format!("dot n={n}"));
    }
}

#[test]
fn dot_handles_unaligned_tails() {
    let mut rng = Rng::new(2);
    let a = fill(&mut rng, 128);
    let b = fill(&mut rng, 128);
    // offset slices shift the data off any 32-byte boundary the Vec
    // allocation might have landed on
    for off in [1usize, 3, 5] {
        for n in SIZES {
            let (xa, xb) = (&a[off..off + n], &b[off..off + n]);
            assert_close(&[simd::dot(xa, xb)], &[ref_dot(xa, xb)],
                         &format!("dot off={off} n={n}"));
        }
    }
}

#[test]
fn axpy_matches_reference_at_odd_sizes() {
    let mut rng = Rng::new(3);
    for n in SIZES {
        let b = fill(&mut rng, n);
        let mut got = fill(&mut rng, n);
        let mut want = got.clone();
        simd::axpy(&mut got, -0.37, &b);
        ref_axpy(&mut want, -0.37, &b);
        assert_close(&got, &want, &format!("axpy n={n}"));
    }
}

#[test]
fn axpy_handles_unaligned_tails() {
    let mut rng = Rng::new(4);
    let b = fill(&mut rng, 128);
    for off in [1usize, 3, 7] {
        for n in SIZES {
            let mut got = fill(&mut rng, off + n + 4);
            let mut want = got.clone();
            simd::axpy(&mut got[off..off + n], 1.25, &b[off..off + n]);
            ref_axpy(&mut want[off..off + n], 1.25, &b[off..off + n]);
            assert_close(&got, &want, &format!("axpy off={off} n={n}"));
        }
    }
}

#[test]
fn axpy4_matches_four_single_axpys() {
    let mut rng = Rng::new(5);
    for n in SIZES {
        let rows: Vec<Vec<f32>> =
            (0..4).map(|_| fill(&mut rng, n)).collect();
        let s = [0.5f32, -1.25, 0.0, 2.0];
        let mut got = fill(&mut rng, n);
        let mut want = got.clone();
        simd::axpy4(&mut got, s,
                    [&rows[0], &rows[1], &rows[2], &rows[3]]);
        for (si, row) in s.iter().zip(&rows) {
            ref_axpy(&mut want, *si, row);
        }
        assert_close(&got, &want, &format!("axpy4 n={n}"));
    }
}

#[test]
fn matmul_acc_matches_reference_at_odd_sizes() {
    let mut rng = Rng::new(6);
    // (m, k, n) triples hit sub-tile, tile-edge, and multi-tile shapes
    let cases = [(1usize, 1usize, 1usize), (7, 31, 33), (33, 7, 100),
                 (100, 33, 7), (31, 100, 1), (33, 33, 33)];
    for (m, kd, n) in cases {
        let a = fill(&mut rng, m * kd);
        let b = fill(&mut rng, kd * n);
        let mut got = fill(&mut rng, m * n);
        let mut want = got.clone();
        simd::matmul_acc(&mut got, &a, &b, m, kd, n);
        ref_matmul_acc(&mut want, &a, &b, m, kd, n);
        assert_close(&got, &want, &format!("matmul_acc {m}x{kd}x{n}"));
    }
}

#[test]
fn matmul_nt_acc_matches_reference_at_odd_sizes() {
    let mut rng = Rng::new(7);
    let cases = [(1usize, 1usize, 1usize), (7, 31, 33), (33, 7, 100),
                 (100, 33, 7), (31, 100, 1), (33, 33, 33)];
    for (m, kd, n) in cases {
        let a = fill(&mut rng, m * kd);
        let b = fill(&mut rng, n * kd);
        let mut got = fill(&mut rng, m * n);
        let mut want = got.clone();
        simd::matmul_nt_acc(&mut got, &a, &b, m, kd, n);
        ref_matmul_nt_acc(&mut want, &a, &b, m, kd, n);
        assert_close(&got, &want, &format!("matmul_nt_acc {m}x{kd}x{n}"));
    }
}

#[test]
fn matmul_acc_deep_k_exercises_depth_tiling() {
    // k = 300 spans two 256-deep slabs; accumulation across slabs must
    // be exact in structure (only rounding-level differences allowed)
    let mut rng = Rng::new(8);
    let (m, kd, n) = (5usize, 300usize, 17usize);
    let a = fill(&mut rng, m * kd);
    let b = fill(&mut rng, kd * n);
    let mut got = vec![0.0f32; m * n];
    let mut want = vec![0.0f32; m * n];
    simd::matmul_acc(&mut got, &a, &b, m, kd, n);
    ref_matmul_acc(&mut want, &a, &b, m, kd, n);
    assert_close(&got, &want, "matmul_acc deep-k");

    let bt = fill(&mut rng, n * kd);
    let mut got_nt = vec![0.0f32; m * n];
    let mut want_nt = vec![0.0f32; m * n];
    simd::matmul_nt_acc(&mut got_nt, &a, &bt, m, kd, n);
    ref_matmul_nt_acc(&mut want_nt, &a, &bt, m, kd, n);
    assert_close(&got_nt, &want_nt, "matmul_nt_acc deep-k");
}

#[test]
fn dispatch_level_reports_a_name() {
    // whatever the host supports, the decision must be queryable and
    // stable across calls
    let l1 = simd::level();
    let l2 = simd::level();
    assert_eq!(l1, l2);
    assert!(!l1.name().is_empty());
}
