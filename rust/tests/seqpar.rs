//! Sequence-parallel scheduler equivalence: the three-phase DAG
//! decomposition (per-chunk UT transforms ─► per-sequence state scan ─►
//! per-chunk outputs, one task per (batch, head, chunk) triple) against
//! the scalar recurrent oracle and the sequential chunkwise entry points,
//! across chunk sizes × thread counts, including prefill→decode state
//! continuation and determinism under an oversubscribed pool.

use deltanet::kernels::{
    backward_batched_on, chunkwise_backward, forward_batched_on,
    recurrent_step, Gradients, HeadProblem,
};
use deltanet::reference::{delta_recurrent, random_problem};
use deltanet::tensor::rng::Rng;
use deltanet::tensor::Mat;
use deltanet::util::threadpool::ThreadPool;

fn problems(n: usize, l: usize, d: usize, seed: u64) -> Vec<HeadProblem> {
    (0..n)
        .map(|i| {
            let (q, k, v, beta) = random_problem(l, d, d, seed + i as u64);
            HeadProblem::new(q, k, v, beta)
        })
        .collect()
}

#[test]
fn forward_matches_oracle_across_chunks_and_threads() {
    // multi-problem (B×H = 6) and single-problem (B = 1, the case the
    // old per-problem fan-out could not parallelize), L = 100 so chunk
    // sizes 4/16/64 all leave a partial tail chunk
    for n in [6usize, 1] {
        let ps = problems(n, 100, 8, 500);
        let oracle: Vec<_> = ps.iter()
            .map(|p| delta_recurrent(&p.q, &p.k, &p.v, &p.beta, None))
            .collect();
        for chunk in [1usize, 4, 16, 64] {
            for threads in [1usize, 4, 8] {
                let pool = ThreadPool::new(threads);
                let outs = forward_batched_on(&pool, &ps, chunk);
                for (i, (f, want)) in outs.iter().zip(&oracle).enumerate()
                {
                    assert!(f.o.allclose(&want.o, 1e-4, 1e-4),
                            "o: n={n} p={i} C={chunk} T={threads}");
                    assert!(f.state.allclose(&want.state, 1e-4, 1e-4),
                            "state: n={n} p={i} C={chunk} T={threads}");
                }
            }
        }
    }
}

#[test]
fn parallel_forward_bit_equals_sequential() {
    // the DAG path runs the SAME phase kernels as the sequential entry
    // point, so any thread count must reproduce it bit for bit
    let ps = problems(3, 57, 8, 520);
    for chunk in [4usize, 16, 64] {
        let want: Vec<_> = ps.iter().map(|p| p.forward(chunk)).collect();
        for threads in [1usize, 4, 8] {
            let pool = ThreadPool::new(threads);
            let got = forward_batched_on(&pool, &ps, chunk);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.o.data, w.o.data,
                           "o: p={i} C={chunk} T={threads}");
                assert_eq!(g.state.data, w.state.data,
                           "state: p={i} C={chunk} T={threads}");
            }
        }
    }
}

fn assert_grads_eq(g: &Gradients, w: &Gradients, label: &str) {
    assert_eq!(g.dq.data, w.dq.data, "dq: {label}");
    assert_eq!(g.dk.data, w.dk.data, "dk: {label}");
    assert_eq!(g.dv.data, w.dv.data, "dv: {label}");
    assert_eq!(g.dbeta, w.dbeta, "dbeta: {label}");
    assert_eq!(g.dstate.data, w.dstate.data, "dstate: {label}");
}

#[test]
fn parallel_backward_bit_equals_sequential() {
    let ps = problems(3, 45, 8, 540);
    let mut rng = Rng::new(541);
    let d_os: Vec<Mat> =
        ps.iter().map(|p| Mat::random(p.q.rows, 8, &mut rng, 1.0)).collect();
    for chunk in [1usize, 4, 16, 64] {
        let want: Vec<Gradients> = ps.iter().zip(&d_os)
            .map(|(p, d_o)| chunkwise_backward(
                &p.q, &p.k, &p.v, &p.beta, chunk, None, d_o, None))
            .collect();
        for threads in [1usize, 4, 8] {
            let pool = ThreadPool::new(threads);
            let got = backward_batched_on(&pool, &ps, &d_os, None, chunk);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_grads_eq(g, w, &format!("p={i} C={chunk} T={threads}"));
            }
        }
    }
}

#[test]
fn backward_is_chunk_invariant_on_the_parallel_path() {
    // different chunk sizes take genuinely different arithmetic routes to
    // the same gradients — agree to allclose, not bit-equality
    let ps = problems(2, 50, 8, 560);
    let mut rng = Rng::new(561);
    let d_os: Vec<Mat> =
        ps.iter().map(|p| Mat::random(p.q.rows, 8, &mut rng, 1.0)).collect();
    let pool = ThreadPool::new(8);
    let base = backward_batched_on(&pool, &ps, &d_os, None, 1);
    for chunk in [4usize, 16, 64] {
        let got = backward_batched_on(&pool, &ps, &d_os, None, chunk);
        for (i, (g, b)) in got.iter().zip(&base).enumerate() {
            let label = format!("p={i} C={chunk}");
            assert!(g.dq.allclose(&b.dq, 1e-3, 1e-3), "dq: {label}");
            assert!(g.dk.allclose(&b.dk, 1e-3, 1e-3), "dk: {label}");
            assert!(g.dv.allclose(&b.dv, 1e-3, 1e-3), "dv: {label}");
            assert!(g.dstate.allclose(&b.dstate, 1e-3, 1e-3),
                    "dstate: {label}");
            for (j, (x, y)) in g.dbeta.iter().zip(&b.dbeta).enumerate() {
                assert!((x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                        "dbeta[{j}]: {label} ({x} vs {y})");
            }
        }
    }
}

#[test]
fn prefill_state_continues_into_decode() {
    // B=1 prefill through the DAG scheduler, then token-by-token decode
    // from the returned state — must match the scalar recurrence over the
    // whole sequence (the serving path: parallel prompt, then decode)
    let (l, l0, d) = (77usize, 48usize, 8usize);
    let (q, k, v, beta) = random_problem(l, d, d, 580);
    let oracle = delta_recurrent(&q, &k, &v, &beta, None);

    let prefix = HeadProblem::new(
        Mat { rows: l0, cols: d, data: q.data[..l0 * d].to_vec() },
        Mat { rows: l0, cols: d, data: k.data[..l0 * d].to_vec() },
        Mat { rows: l0, cols: d, data: v.data[..l0 * d].to_vec() },
        beta[..l0].to_vec(),
    );
    let pool = ThreadPool::new(8);
    let fs = forward_batched_on(&pool, std::slice::from_ref(&prefix), 16);
    let f = &fs[0];
    assert!(f.o.allclose(
        &Mat { rows: l0, cols: d, data: oracle.o.data[..l0 * d].to_vec() },
        1e-4, 1e-4), "prefill outputs");

    let mut s = f.state.clone();
    let mut out = vec![0f32; d];
    for t in l0..l {
        recurrent_step(&mut s, q.row(t), k.row(t), v.row(t), beta[t],
                       &mut out);
        let want = oracle.o.row(t);
        for (j, (&a, &b)) in out.iter().zip(want).enumerate() {
            assert!((a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
                    "decode t={t} j={j}: {a} vs {b}");
        }
    }
    assert!(s.allclose(&oracle.state, 1e-4, 1e-4), "final decode state");
}

#[test]
fn initial_state_and_dstate_chain_through_batched_path() {
    // segment-chained training: segment 2 starts from segment 1's state
    // (forward) and receives a d_state from downstream (backward) — the
    // DAG path must reproduce the sequential entry points bit for bit
    let d = 8usize;
    let mut rng = Rng::new(600);
    let s0 = Mat::random(d, d, &mut rng, 0.5);
    let (q, k, v, beta) = random_problem(39, d, d, 601);
    let mut p = HeadProblem::new(q, k, v, beta);
    p.initial_state = Some(s0.clone());
    let d_o = Mat::random(39, d, &mut rng, 1.0);
    let d_s = Mat::random(d, d, &mut rng, 1.0);

    let pool = ThreadPool::new(8);
    for chunk in [4usize, 16] {
        let fs = forward_batched_on(&pool, std::slice::from_ref(&p), chunk);
        let want_f = p.forward(chunk);
        assert_eq!(fs[0].o.data, want_f.o.data, "o: C={chunk}");
        assert_eq!(fs[0].state.data, want_f.state.data, "state: C={chunk}");

        let gs = backward_batched_on(
            &pool, std::slice::from_ref(&p), std::slice::from_ref(&d_o),
            Some(std::slice::from_ref(&d_s)), chunk);
        let want_g = chunkwise_backward(&p.q, &p.k, &p.v, &p.beta, chunk,
                                        Some(&s0), &d_o, Some(&d_s));
        assert_grads_eq(&gs[0], &want_g, &format!("C={chunk}"));
    }
}

#[test]
fn oversubscribed_pool_is_deterministic() {
    // 8 workers, B=1, L=257, C=4 → 65 tasks per phase racing over a pool
    // far wider than any host core count here; five runs must agree bit
    // for bit with each other and with the sequential path
    let ps = problems(1, 257, 8, 620);
    let mut rng = Rng::new(621);
    let d_os: Vec<Mat> = vec![Mat::random(257, 8, &mut rng, 1.0)];
    let want_f = ps[0].forward(4);
    let want_g = chunkwise_backward(&ps[0].q, &ps[0].k, &ps[0].v,
                                    &ps[0].beta, 4, None, &d_os[0], None);
    let pool = ThreadPool::new(8);
    for run in 0..5 {
        let fs = forward_batched_on(&pool, &ps, 4);
        assert_eq!(fs[0].o.data, want_f.o.data, "o: run={run}");
        assert_eq!(fs[0].state.data, want_f.state.data, "state: run={run}");
        let gs = backward_batched_on(&pool, &ps, &d_os, None, 4);
        assert_grads_eq(&gs[0], &want_g, &format!("run={run}"));
    }
}
