//! Property tests (util::prop — seeded, reproducible) for the blocked
//! tensor primitives the backward pass is built on: transposed matmuls and
//! the unit-lower-triangular solves, each checked against a direct scalar
//! formulation on random shapes and values.

use deltanet::tensor::blocked::{
    matmul_nt, matmul_tn_acc, solve_unit_lower, solve_unit_lower_t,
    tri_inv_unit_lower, tril_matmul_nt,
};
use deltanet::tensor::rng::Rng;
use deltanet::tensor::Mat;
use deltanet::util::prop::{check, f32_vec, usize_in};

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, f32_vec(rng, rows * cols, 1.0)).unwrap()
}

/// Random strictly-lower-triangular [c, c] matrix (the UT-transform A).
fn rand_strict_lower(rng: &mut Rng, c: usize) -> Mat {
    let mut a = rand_mat(rng, c, c);
    for i in 0..c {
        for j in i..c {
            a.data[i * c + j] = 0.0;
        }
    }
    a
}

fn close(x: f32, y: f32) -> bool {
    (x - y).abs() <= 1e-4 + 1e-4 * x.abs().max(y.abs())
}

#[test]
fn matmul_nt_matches_scalar_triple_loop() {
    check("matmul_nt == scalar A·Bᵀ", 40, |rng| {
        let (m, n, kk) = (usize_in(rng, 1, 9), usize_in(rng, 1, 9),
                          usize_in(rng, 1, 9));
        let a = rand_mat(rng, m, kk);
        let b = rand_mat(rng, n, kk);
        let got = matmul_nt(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let want: f32 =
                    (0..kk).map(|p| a[(i, p)] * b[(j, p)]).sum();
                if !close(got[(i, j)], want) {
                    return Err(format!(
                        "[{i},{j}] got {} want {want} (m={m} n={n} k={kk})",
                        got[(i, j)]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn matmul_tn_acc_matches_scalar_triple_loop() {
    check("matmul_tn_acc == out + AᵀB", 40, |rng| {
        let (t, m, n) = (usize_in(rng, 1, 9), usize_in(rng, 1, 9),
                         usize_in(rng, 1, 9));
        let a = rand_mat(rng, t, m);
        let b = rand_mat(rng, t, n);
        let init = rand_mat(rng, m, n);
        let mut got = init.clone();
        matmul_tn_acc(&mut got, &a, &b);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = init[(i, j)]
                    + (0..t).map(|p| a[(p, i)] * b[(p, j)]).sum::<f32>();
                if !close(got[(i, j)], want) {
                    return Err(format!("[{i},{j}] got {} want {want}",
                                       got[(i, j)]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn tril_matmul_nt_masks_above_the_diagonal() {
    check("tril_matmul_nt == masked A·Bᵀ", 40, |rng| {
        let (m, kk) = (usize_in(rng, 1, 9), usize_in(rng, 1, 9));
        let a = rand_mat(rng, m, kk);
        let b = rand_mat(rng, m, kk);
        for diag in [-1i64, 0] {
            let got = tril_matmul_nt(&a, &b, diag);
            for i in 0..m {
                for j in 0..m {
                    let want: f32 = if (j as i64) <= i as i64 + diag {
                        (0..kk).map(|p| a[(i, p)] * b[(j, p)]).sum()
                    } else {
                        0.0
                    };
                    if !close(got[(i, j)], want) {
                        return Err(format!(
                            "diag {diag} [{i},{j}] got {} want {want}",
                            got[(i, j)]));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn solve_unit_lower_reconstructs_rhs() {
    // X := solve((I+A), B)  ⇒  (I+A)·X must reproduce B
    check("(I+A)·solve(A,B) == B", 40, |rng| {
        let c = usize_in(rng, 1, 10);
        let n = usize_in(rng, 1, 8);
        let a = rand_strict_lower(rng, c);
        let b = rand_mat(rng, c, n);
        let x = solve_unit_lower(&a, &b);
        for i in 0..c {
            for j in 0..n {
                let recon: f32 = x[(i, j)]
                    + (0..i).map(|p| a[(i, p)] * x[(p, j)]).sum::<f32>();
                if !close(recon, b[(i, j)]) {
                    return Err(format!(
                        "[{i},{j}] (I+A)X = {recon}, B = {} (c={c})",
                        b[(i, j)]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn solve_unit_lower_t_reconstructs_rhs() {
    // X := solve((I+A)ᵀ, B)  ⇒  (I+A)ᵀ·X must reproduce B
    check("(I+A)ᵀ·solve_t(A,B) == B", 40, |rng| {
        let c = usize_in(rng, 1, 10);
        let n = usize_in(rng, 1, 8);
        let a = rand_strict_lower(rng, c);
        let b = rand_mat(rng, c, n);
        let x = solve_unit_lower_t(&a, &b);
        for i in 0..c {
            // ((I+A)ᵀX)[i] = X[i] + Σ_{p>i} A[p,i]·X[p]
            for j in 0..n {
                let recon: f32 = x[(i, j)]
                    + (i + 1..c).map(|p| a[(p, i)] * x[(p, j)])
                        .sum::<f32>();
                if !close(recon, b[(i, j)]) {
                    return Err(format!(
                        "[{i},{j}] (I+A)ᵀX = {recon}, B = {} (c={c})",
                        b[(i, j)]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn solves_agree_with_explicit_inverse() {
    // the two solves and the materialized T = (I+A)⁻¹ are three routes to
    // the same UT transform; they must agree on random problems
    check("solve == T·B and solve_t == Tᵀ·B", 30, |rng| {
        let c = usize_in(rng, 1, 10);
        let n = usize_in(rng, 1, 6);
        let a = rand_strict_lower(rng, c);
        let b = rand_mat(rng, c, n);
        let t = tri_inv_unit_lower(&a);
        let x1 = solve_unit_lower(&a, &b);
        let x2 = solve_unit_lower_t(&a, &b);
        for i in 0..c {
            for j in 0..n {
                let tb: f32 = (0..c).map(|p| t[(i, p)] * b[(p, j)]).sum();
                let ttb: f32 = (0..c).map(|p| t[(p, i)] * b[(p, j)]).sum();
                if !close(x1[(i, j)], tb) {
                    return Err(format!("solve vs T·B at [{i},{j}]: \
                                        {} vs {tb}", x1[(i, j)]));
                }
                if !close(x2[(i, j)], ttb) {
                    return Err(format!("solve_t vs Tᵀ·B at [{i},{j}]: \
                                        {} vs {ttb}", x2[(i, j)]));
                }
            }
        }
        Ok(())
    });
}
