//! Integration: the recurrent decode path — constant-memory generation,
//! trained-weight transplant, and the serving engine.  Requires
//! `make artifacts`.

use std::time::Duration;

use deltanet::config::DataConfig;
use deltanet::coordinator::generate::Sampling;
use deltanet::coordinator::server::{GenRequest, ServeEngine};
use deltanet::coordinator::{DecodeEngine, Trainer};
use deltanet::data::build_task;
use deltanet::runtime::Runtime;

/// PJRT runtime if the backend and artifacts are both present, else None
/// (the test should return early — skipped in the offline build).
fn runtime() -> Option<Runtime> {
    if !Runtime::backend_available() {
        eprintln!("skipping: PJRT backend not linked (offline build)");
        return None;
    }
    if !std::path::Path::new("artifacts").is_dir() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("PJRT runtime"))
}

#[test]
fn decode_steps_and_resets() {
    let Some(rt) = runtime() else { return };
    let mut engine = DecodeEngine::new(&rt, "deltanet_tiny", 1).unwrap();
    let b = engine.batch;
    let logits1 = engine.step(&vec![1i32; b], 0).unwrap();
    assert_eq!(logits1.len(), b * engine.vocab);
    assert!(logits1.iter().all(|x| x.is_finite()));
    let logits2 = engine.step(&vec![2i32; b], 1).unwrap();
    // state advanced: feeding the same token again gives different logits
    let logits3 = engine.step(&vec![2i32; b], 2).unwrap();
    assert_ne!(logits2, logits3);
    // reset restores the initial distribution
    engine.reset_state().unwrap();
    let logits4 = engine.step(&vec![1i32; b], 0).unwrap();
    for (a, c) in logits1.iter().zip(&logits4) {
        assert!((a - c).abs() < 1e-5, "reset_state did not reset");
    }
}

#[test]
fn generate_respects_prompt_and_length() {
    let Some(rt) = runtime() else { return };
    let mut engine = DecodeEngine::new(&rt, "deltanet_tiny", 1).unwrap();
    let prompts = vec![vec![1, 2, 3], vec![4, 5, 6, 7, 8]];
    let out = engine.generate(&prompts, 10, Sampling::Greedy, 0).unwrap();
    assert_eq!(out.len(), 2);
    assert!(out.iter().all(|g| g.len() == 10));
    let vocab = engine.vocab as i32;
    assert!(out.iter().flatten().all(|&t| t >= 0 && t < vocab));
    // greedy decoding is deterministic
    let out2 = engine.generate(&prompts, 10, Sampling::Greedy, 123).unwrap();
    assert_eq!(out, out2);
}

#[test]
fn hybrid_arch_decodes_too() {
    // the hybrid has SWA layers with a KV cache in the decode state
    let Some(rt) = runtime() else { return };
    let mut engine = DecodeEngine::new(&rt, "hybrid_swa_tiny", 1).unwrap();
    let out = engine.generate(&[vec![3, 1, 4]], 8,
                              Sampling::Greedy, 0).unwrap();
    assert_eq!(out[0].len(), 8);
}

#[test]
fn trained_params_change_generation_quality() {
    // train briefly on MQAR, transplant weights into the decode engine,
    // and verify the trained model completes a recall query correctly
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "deltanet_tiny", 4).unwrap();
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 4 });
    for _ in 0..60 {
        let b = task.sample(trainer.batch, trainer.seq_len);
        trainer.train_step(&b, 3e-3).unwrap();
    }

    let mut engine = DecodeEngine::new(&rt, "deltanet_tiny", 999).unwrap();
    engine.set_params(&trainer.param_literals().unwrap()).unwrap();

    // build a prompt: kv pairs then separator then a query key; greedy
    // decode should emit the bound value
    let mut gen = deltanet::data::mqar::Mqar::new(4, 123);
    use deltanet::data::TaskGen;
    let batch = gen.sample(1, 32);
    // find the first masked query position; prompt = tokens[..=pos]
    let qpos = (0..32).find(|&p| batch.mask[p] > 0.0).unwrap();
    let prompt: Vec<i32> = (0..=qpos).map(|p| batch.token(0, p)).collect();
    let want = batch.token(0, qpos + 1);
    let out = engine.generate(&[prompt], 1, Sampling::Greedy, 0).unwrap();
    // trained-for-60-steps tiny model: should usually get this right; we
    // assert only that it emits a *value-alphabet* token, and report the
    // exact-match result (flaky-free but still meaningful)
    let got = out[0][0];
    assert!(got >= 0 && got < engine.vocab as i32);
    eprintln!("recall query: want {want}, got {got} \
               ({})", if got == want { "exact" } else { "inexact" });
}

#[test]
fn serve_engine_handles_concurrent_requests() {
    if runtime().is_none() {
        return;
    }
    let serve = ServeEngine::spawn(
        || {
            let rt = Runtime::new("artifacts")?;
            DecodeEngine::new(&rt, "deltanet_tiny", 0)
        },
        Sampling::Greedy,
        Duration::from_millis(5),
    );
    let tickets: Vec<_> = (0..12)
        .map(|i| serve.submit(GenRequest {
            prompt: vec![1 + (i % 5) as i32, 2, 3],
            max_new: 6,
        }))
        .collect::<deltanet::Result<_>>().unwrap();
    for t in tickets {
        let resp = t.wait().unwrap();
        assert_eq!(resp.tokens.len(), 6);
    }
    let st = serve.shutdown();
    assert_eq!(st.requests, 12);
    assert!(st.batches <= 12);
    assert!(st.tokens_generated == 72);
}

#[test]
fn serve_engine_reports_init_failure() {
    let serve = ServeEngine::spawn(
        || deltanet::bail!("no such artifact"),
        Sampling::Greedy,
        Duration::from_millis(1),
    );
    let t = serve.submit(GenRequest { prompt: vec![1], max_new: 1 }).unwrap();
    assert!(t.wait().is_err());
}
