//! Integration: the batched/blocked kernel layer against the scalar
//! oracle — parallel batched chunkwise ≡ `delta_recurrent` per
//! (batch, head) across chunk sizes and thread counts, plus state-chaining
//! equivalence under the blocked matmul path.

use deltanet::kernels::{
    forward_batched, forward_batched_on, HeadProblem, KernelConfig,
};
use deltanet::reference::{
    delta_chunkwise, delta_chunkwise_scalar, delta_recurrent, random_problem,
};
use deltanet::tensor::Mat;
use deltanet::util::threadpool::ThreadPool;

fn head_problems(b: usize, h: usize, l: usize, d: usize)
                 -> Vec<HeadProblem> {
    (0..b * h)
        .map(|i| {
            let (q, k, v, beta) = random_problem(l, d, d, 1000 + i as u64);
            HeadProblem::new(q, k, v, beta)
        })
        .collect()
}

#[test]
fn batched_chunkwise_equals_recurrent_all_chunks_and_threads() {
    // [B, H] = [2, 3] problems, every chunk × thread combination
    let problems = head_problems(2, 3, 64, 16);
    let oracle: Vec<_> = problems
        .iter()
        .map(|p| delta_recurrent(&p.q, &p.k, &p.v, &p.beta, None))
        .collect();
    for chunk in [1usize, 4, 16, 64] {
        for threads in [1usize, 4, 8] {
            let cfg = KernelConfig::new()
                .chunk(chunk).threads(threads).build().unwrap();
            let outs = forward_batched(&problems, &cfg);
            for (i, (got, want)) in outs.iter().zip(&oracle).enumerate() {
                assert!(got.o.allclose(&want.o, 1e-4, 1e-4),
                        "output mismatch: problem {i} C={chunk} T={threads}");
                assert!(got.state.allclose(&want.state, 1e-4, 1e-4),
                        "state mismatch: problem {i} C={chunk} T={threads}");
            }
        }
    }
}

#[test]
fn state_chaining_under_blocked_path() {
    // carrying the state across a split must equal one pass, for every
    // (chunk, threads) combination, with the carried state produced by the
    // blocked kernels themselves
    let (l, half, d) = (64usize, 32usize, 8usize);
    let problems = head_problems(1, 4, l, d);
    let slice = |m: &Mat, a: usize, b: usize| Mat {
        rows: b - a,
        cols: m.cols,
        data: m.data[a * m.cols..b * m.cols].to_vec(),
    };
    for chunk in [4usize, 16] {
        for threads in [1usize, 4, 8] {
            let cfg = KernelConfig::new()
                .chunk(chunk).threads(threads).build().unwrap();
            let full = forward_batched(&problems, &cfg);
            let first: Vec<HeadProblem> = problems
                .iter()
                .map(|p| HeadProblem::new(
                    slice(&p.q, 0, half), slice(&p.k, 0, half),
                    slice(&p.v, 0, half), p.beta[..half].to_vec()))
                .collect();
            let states = forward_batched(&first, &cfg);
            let second: Vec<HeadProblem> = problems
                .iter()
                .zip(&states)
                .map(|(p, f)| HeadProblem {
                    q: slice(&p.q, half, l),
                    k: slice(&p.k, half, l),
                    v: slice(&p.v, half, l),
                    beta: p.beta[half..].to_vec(),
                    initial_state: Some(f.state.clone()),
                })
                .collect();
            let tails = forward_batched(&second, &cfg);
            for (i, (tail, whole)) in tails.iter().zip(&full).enumerate() {
                assert!(tail.state.allclose(&whole.state, 1e-4, 1e-4),
                        "chained state: problem {i} C={chunk} T={threads}");
                for t in 0..(l - half) {
                    for (a, b) in
                        tail.o.row(t).iter().zip(whole.o.row(half + t))
                    {
                        assert!((a - b).abs() < 1e-3,
                                "chained output: problem {i} token {t}");
                    }
                }
            }
        }
    }
}

#[test]
fn routed_delta_chunkwise_still_matches_scalar_form() {
    // reference::delta_chunkwise is routed through the blocked kernels;
    // it must stay interchangeable with the retained scalar form
    let (q, k, v, beta) = random_problem(64, 8, 8, 42);
    for chunk in [1usize, 4, 16, 64] {
        let routed = delta_chunkwise(&q, &k, &v, &beta, chunk, None);
        let scalar = delta_chunkwise_scalar(&q, &k, &v, &beta, chunk, None);
        assert!(routed.o.allclose(&scalar.o, 1e-4, 1e-4), "C={chunk}");
        assert!(routed.state.allclose(&scalar.state, 1e-4, 1e-4),
                "C={chunk}");
    }
}

#[test]
fn shared_pool_across_batches_is_deterministic() {
    let problems = head_problems(2, 2, 32, 8);
    let pool = ThreadPool::new(4);
    let a = forward_batched_on(&pool, &problems, 8);
    let b = forward_batched_on(&pool, &problems, 8);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.o.data, y.o.data, "f32 kernel must be bit-stable");
        assert_eq!(x.state.data, y.state.data);
    }
}
