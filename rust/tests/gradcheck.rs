//! Gradient check for the chunkwise backward pass: analytic q/k/v/β (and
//! state) gradients against central finite differences of the scalar f64
//! oracle (`reference::fd`), across sequence lengths that exercise the
//! partial-tail-chunk path, plus thread-count invariance of the batched
//! fan-out.

use deltanet::kernels::{
    backward_batched, backward_batched_on, chunkwise_backward, HeadProblem,
    KernelConfig,
};
use deltanet::reference::fd::{fd_grads, slice_to_f64, to_f64};
use deltanet::reference::random_problem;
use deltanet::tensor::rng::Rng;
use deltanet::tensor::Mat;
use deltanet::util::threadpool::ThreadPool;

fn assert_close(analytic: f32, fd: f64, what: &str) {
    let a = analytic as f64;
    let diff = (a - fd).abs();
    let tol = 1e-3 + 1e-3 * a.abs().max(fd.abs());
    assert!(diff <= tol,
            "{what}: analytic {a:.6} vs fd {fd:.6} (diff {diff:.2e})");
}

fn check_problem(l: usize, chunks: &[usize], with_state: bool, seed: u64) {
    let (dk, dv) = (4usize, 4usize);
    let (q, k, v, beta) = random_problem(l, dk, dv, seed);
    let mut rng = Rng::new(seed ^ 0xabcd);
    let s0 = if with_state {
        Some(Mat::random(dk, dv, &mut rng, 0.5))
    } else {
        None
    };
    // loss = <w_o, O> + <w_s, S_L>  =>  d_o = w_o, d_state = w_s
    let w_o = Mat::random(l, dv, &mut rng, 1.0);
    let w_s = Mat::random(dk, dv, &mut rng, 1.0);

    // the FD reference does not depend on the chunking — compute it once
    let s0_f64 = s0.as_ref().map(to_f64);
    let fd = fd_grads(&to_f64(&q), &to_f64(&k), &to_f64(&v),
                      &slice_to_f64(&beta), l, dk, dv,
                      s0_f64.as_deref(), &to_f64(&w_o), &to_f64(&w_s),
                      1e-3);

    for &chunk in chunks {
        let g = chunkwise_backward(&q, &k, &v, &beta, chunk, s0.as_ref(),
                                   &w_o, Some(&w_s));
        let label = format!("L={l} C={chunk} state={with_state}");
        for (i, (&a, &f)) in g.dq.data.iter().zip(&fd.dq).enumerate() {
            assert_close(a, f, &format!("{label} dq[{i}]"));
        }
        for (i, (&a, &f)) in g.dk.data.iter().zip(&fd.dk).enumerate() {
            assert_close(a, f, &format!("{label} dk[{i}]"));
        }
        for (i, (&a, &f)) in g.dv.data.iter().zip(&fd.dv).enumerate() {
            assert_close(a, f, &format!("{label} dv[{i}]"));
        }
        for (i, (&a, &f)) in g.dbeta.iter().zip(&fd.dbeta).enumerate() {
            assert_close(a, f, &format!("{label} dbeta[{i}]"));
        }
        for (i, (&a, &f)) in g.dstate.data.iter().zip(&fd.dstate)
            .enumerate()
        {
            assert_close(a, f, &format!("{label} dstate[{i}]"));
        }
    }
}

#[test]
fn gradcheck_single_token() {
    check_problem(1, &[1, 4, 16], false, 70);
    check_problem(1, &[1, 4, 16], true, 71);
}

#[test]
fn gradcheck_partial_tail_chunk() {
    // L=7 against C ∈ {1,4,16}: a short tail for C=4, a single short
    // chunk for C=16
    check_problem(7, &[1, 4, 16], false, 72);
    check_problem(7, &[1, 4, 16], true, 73);
}

#[test]
fn gradcheck_long_sequence() {
    check_problem(64, &[1, 4, 16], false, 74);
    check_problem(64, &[1, 4, 16], true, 75);
}

#[test]
fn gradcheck_through_dag_scheduler() {
    // the sequence-parallel backward (per-chunk recompute, reverse state
    // scan, parallel phase C) on an oversubscribed 8-thread pool must
    // still match finite differences — B=1, so every task the pool runs
    // comes from the chunk fan-out of this single problem
    let (l, dk, dv) = (13usize, 4usize, 4usize);
    let (q, k, v, beta) = random_problem(l, dk, dv, 76);
    let mut rng = Rng::new(77);
    let s0 = Mat::random(dk, dv, &mut rng, 0.5);
    let w_o = Mat::random(l, dv, &mut rng, 1.0);
    let w_s = Mat::random(dk, dv, &mut rng, 1.0);
    let fd = fd_grads(&to_f64(&q), &to_f64(&k), &to_f64(&v),
                      &slice_to_f64(&beta), l, dk, dv,
                      Some(&to_f64(&s0)), &to_f64(&w_o), &to_f64(&w_s),
                      1e-3);

    let mut p = HeadProblem::new(q, k, v, beta);
    p.initial_state = Some(s0);
    let pool = ThreadPool::new(8);
    for chunk in [1usize, 4, 16] {
        let gs = backward_batched_on(
            &pool, std::slice::from_ref(&p), std::slice::from_ref(&w_o),
            Some(std::slice::from_ref(&w_s)), chunk);
        let g = &gs[0];
        let label = format!("dag L={l} C={chunk} T=8");
        for (i, (&a, &f)) in g.dq.data.iter().zip(&fd.dq).enumerate() {
            assert_close(a, f, &format!("{label} dq[{i}]"));
        }
        for (i, (&a, &f)) in g.dk.data.iter().zip(&fd.dk).enumerate() {
            assert_close(a, f, &format!("{label} dk[{i}]"));
        }
        for (i, (&a, &f)) in g.dv.data.iter().zip(&fd.dv).enumerate() {
            assert_close(a, f, &format!("{label} dv[{i}]"));
        }
        for (i, (&a, &f)) in g.dbeta.iter().zip(&fd.dbeta).enumerate() {
            assert_close(a, f, &format!("{label} dbeta[{i}]"));
        }
        for (i, (&a, &f)) in g.dstate.data.iter().zip(&fd.dstate)
            .enumerate()
        {
            assert_close(a, f, &format!("{label} dstate[{i}]"));
        }
    }
}

#[test]
fn gradients_invariant_to_thread_count() {
    // same [B,H] fan-out on 1/2/8 threads must be bit-identical: each
    // head problem is computed by exactly the same sequential code
    let problems: Vec<HeadProblem> = (0..8)
        .map(|i| {
            let (q, k, v, beta) = random_problem(33, 8, 8, 400 + i as u64);
            HeadProblem::new(q, k, v, beta)
        })
        .collect();
    let mut rng = Rng::new(401);
    let d_os: Vec<Mat> =
        (0..8).map(|_| Mat::random(33, 8, &mut rng, 1.0)).collect();
    let base = backward_batched(
        &problems, &d_os, None,
        &KernelConfig::new().chunk(16).threads(1).build().unwrap());
    for threads in [2usize, 8] {
        let cfg =
            KernelConfig::new().chunk(16).threads(threads).build().unwrap();
        let got = backward_batched(&problems, &d_os, None, &cfg);
        for (g, b) in got.iter().zip(&base) {
            assert_eq!(g.dq.data, b.dq.data, "T={threads}");
            assert_eq!(g.dk.data, b.dk.data, "T={threads}");
            assert_eq!(g.dv.data, b.dv.data, "T={threads}");
            assert_eq!(g.dbeta, b.dbeta, "T={threads}");
            assert_eq!(g.dstate.data, b.dstate.data, "T={threads}");
        }
    }
}
