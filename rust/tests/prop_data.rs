//! Property-based tests over the data pipeline: every generator must emit
//! batches whose masked targets are actually solvable from the context
//! (or the fixed map), stay in vocab, and be deterministic under seed.

use deltanet::config::DataConfig;
use deltanet::data::{build_task, mad, Batch};
use deltanet::util::prop::check;

fn all_configs(seed: u64) -> Vec<DataConfig> {
    let mut v = vec![
        DataConfig::Corpus { seed },
        DataConfig::Mqar { num_pairs: 4, seed },
        DataConfig::Mqar { num_pairs: 8, seed },
        DataConfig::RegBench { seed },
        DataConfig::Recall { style: "swde".into(), seed },
        DataConfig::Recall { style: "squad".into(), seed },
        DataConfig::Recall { style: "fda".into(), seed },
    ];
    for task in mad::ALL_TASKS {
        v.push(DataConfig::Mad { task: task.to_string(), seed });
    }
    v
}

#[test]
fn prop_all_generators_stay_in_vocab_and_mask() {
    check("generators in-vocab", 10, |rng| {
        let seed = rng.next_u64();
        for cfg in all_configs(seed) {
            let mut gen = build_task(&cfg);
            let vocab = gen.vocab_required() as i32;
            let b = gen.sample(4, 64);
            if b.tokens.iter().any(|&t| t < 0 || t >= vocab) {
                return Err(format!("{}: token out of vocab", gen.name()));
            }
            if b.masked_positions() == 0 {
                return Err(format!("{}: no targets", gen.name()));
            }
            if b.tokens.len() != 4 * 65 || b.mask.len() != 4 * 64 {
                return Err(format!("{}: bad layout", gen.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_generators_deterministic_under_seed() {
    check("generator determinism", 8, |rng| {
        let seed = rng.next_u64();
        for cfg in all_configs(seed) {
            let mut g1 = build_task(&cfg);
            let mut g2 = build_task(&cfg);
            let a = g1.sample(2, 48);
            let b = g2.sample(2, 48);
            if a.tokens != b.tokens || a.mask != b.mask {
                return Err(format!("{}: nondeterministic", g1.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_vocab_requirements_fit_tiny_preset() {
    // every generator must fit the tiny artifact vocab (128) — an
    // out-of-range token id would hit the embedding gather out of bounds
    // and poison training with NaNs
    let mut configs = all_configs(1);
    configs.push(DataConfig::Mqar { num_pairs: 16, seed: 1 });
    for cfg in configs {
        let gen = build_task(&cfg);
        assert!(gen.vocab_required() <= 128,
                "{} needs vocab {}", gen.name(), gen.vocab_required());
    }
}

#[test]
fn prop_oracle_predictions_score_100() {
    // feeding the literal targets as predictions must give 100% accuracy
    // for every generator (sanity of the scoring path itself)
    check("oracle scores 100", 6, |rng| {
        let seed = rng.next_u64();
        for cfg in all_configs(seed) {
            let mut gen = build_task(&cfg);
            let b = gen.sample(3, 56);
            let preds = oracle_preds(&b);
            let (c, t) = b.score_preds(&preds);
            if c != t {
                return Err(format!("{}: oracle scored {c}/{t}", gen.name()));
            }
        }
        Ok(())
    });
}

fn oracle_preds(b: &Batch) -> Vec<i32> {
    let mut preds = vec![0i32; b.batch * b.seq_len];
    for bi in 0..b.batch {
        for pos in 0..b.seq_len {
            preds[bi * b.seq_len + pos] = b.token(bi, pos + 1);
        }
    }
    preds
}

#[test]
fn prop_mqar_query_keys_seen_before() {
    // every masked query position must use a key that appeared in the kv
    // section — otherwise the task would be unsolvable
    check("mqar solvable", 10, |rng| {
        let seed = rng.next_u64();
        let pairs = [4, 8][rng.below(2)];
        let mut gen = build_task(&DataConfig::Mqar { num_pairs: pairs, seed });
        let b = gen.sample(4, 64);
        for bi in 0..4 {
            for pos in 0..64 {
                if b.mask[bi * 64 + pos] > 0.0 {
                    let key = b.token(bi, pos);
                    let seen = (0..pos).any(|p| b.token(bi, p) == key);
                    if !seen {
                        return Err(format!("query key {key} unseen"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scoring_counts_match_mask() {
    check("score totals == mask", 10, |rng| {
        let seed = rng.next_u64();
        for cfg in all_configs(seed) {
            let mut gen = build_task(&cfg);
            let b = gen.sample(2, 40);
            let preds = vec![-1i32; 2 * 40]; // always wrong (out of vocab)
            let (c, t) = b.score_preds(&preds);
            if c != 0 || t != b.masked_positions() {
                return Err(format!("{}: {c}/{t} vs mask {}",
                                   gen.name(), b.masked_positions()));
            }
        }
        Ok(())
    });
}
