//! Property-based tests over the pure-Rust reference implementation —
//! the WY-representation invariants the paper's algorithm rests on.

use deltanet::reference::{self, delta_chunkwise, delta_recurrent,
                          tri_inv_unit_lower, ut_transform};
use deltanet::tensor::rng::Rng;
use deltanet::tensor::{dot, l2_normalize, Mat};
use deltanet::util::prop::{check, f32_vec, unit_vec};

fn random_problem(rng: &mut Rng, l: usize, dk: usize, dv: usize)
                  -> (Mat, Mat, Mat, Vec<f32>) {
    let q = Mat::from_vec(l, dk, f32_vec(rng, l * dk, 1.0)).unwrap();
    let mut k = Mat::from_vec(l, dk, f32_vec(rng, l * dk, 1.0)).unwrap();
    for i in 0..l {
        l2_normalize(k.row_mut(i));
    }
    let v = Mat::from_vec(l, dv, f32_vec(rng, l * dv, 1.0)).unwrap();
    let beta = unit_vec(rng, l);
    (q, k, v, beta)
}

#[test]
fn prop_chunkwise_equals_recurrent_any_chunk() {
    check("chunkwise == recurrent", 40, |rng| {
        let l = [8, 16, 32, 64][rng.below(4)];
        let dk = [4, 8, 16][rng.below(3)];
        let dv = [4, 8, 16][rng.below(3)];
        // any chunk size dividing L
        let divisors: Vec<usize> =
            (1..=l).filter(|c| l % c == 0).collect();
        let c = divisors[rng.below(divisors.len())];
        let (q, k, v, beta) = random_problem(rng, l, dk, dv);
        let a = delta_recurrent(&q, &k, &v, &beta, None);
        let b = delta_chunkwise(&q, &k, &v, &beta, c, None);
        if !b.o.allclose(&a.o, 2e-3, 2e-3) {
            return Err(format!("outputs differ (L={l} dk={dk} C={c})"));
        }
        if !b.state.allclose(&a.state, 2e-3, 2e-3) {
            return Err(format!("states differ (L={l} dk={dk} C={c})"));
        }
        Ok(())
    });
}

#[test]
fn prop_state_chaining_is_associative() {
    // splitting the sequence at ANY boundary and chaining states must give
    // the same result as one pass — the prefill/decode contract
    check("state chaining", 30, |rng| {
        let l = 32;
        let (q, k, v, beta) = random_problem(rng, l, 8, 8);
        let full = delta_recurrent(&q, &k, &v, &beta, None);
        let cut = 1 + rng.below(l - 1);
        let take = |m: &Mat, a: usize, b: usize| Mat {
            rows: b - a,
            cols: m.cols,
            data: m.data[a * m.cols..b * m.cols].to_vec(),
        };
        let h1 = delta_recurrent(&take(&q, 0, cut), &take(&k, 0, cut),
                                 &take(&v, 0, cut), &beta[..cut], None);
        let h2 = delta_recurrent(&take(&q, cut, l), &take(&k, cut, l),
                                 &take(&v, cut, l), &beta[cut..],
                                 Some(&h1.state));
        if !h2.state.allclose(&full.state, 2e-3, 2e-3) {
            return Err(format!("state mismatch at cut {cut}"));
        }
        Ok(())
    });
}

#[test]
fn prop_eigenvalue_bound_keeps_state_bounded() {
    // with L2-normalized keys and β ∈ (0,1), eigenvalues of (I − βkkᵀ) lie
    // in [0, 1] ⇒ long rollouts cannot blow up
    check("bounded state", 10, |rng| {
        let l = 512;
        let (q, k, v, beta) = random_problem(rng, l, 8, 8);
        let _ = q;
        let f = delta_recurrent(&Mat::zeros(l, 8), &k, &v, &beta, None);
        let m = f.state.max_abs();
        if !m.is_finite() || m > 1e3 {
            return Err(format!("state magnitude {m}"));
        }
        Ok(())
    });
}

#[test]
fn prop_tri_inv_is_inverse() {
    check("(I+A)(I+A)^-1 == I", 30, |rng| {
        let c = 2 + rng.below(24);
        let mut a = Mat::zeros(c, c);
        for i in 0..c {
            for j in 0..i {
                a[(i, j)] = rng.normal() * 0.5;
            }
        }
        let inv = tri_inv_unit_lower(&a);
        let mut ia = Mat::eye(c);
        for i in 0..c {
            for j in 0..c {
                ia[(i, j)] += a[(i, j)];
            }
        }
        let prod = ia.matmul(&inv);
        if !prod.allclose(&Mat::eye(c), 1e-3, 1e-3) {
            return Err(format!("not an inverse at C={c}"));
        }
        Ok(())
    });
}

#[test]
fn prop_wy_representation_reconstructs_householder_product() {
    // P = I − Σ w_t k_tᵀ  must equal  ∏_t (I − β_t k_t k_tᵀ)  (appendix A)
    check("WY == product of Householders", 25, |rng| {
        let c = 2 + rng.below(12);
        let dk = 4 + rng.below(8);
        let (_, k, v, beta) = random_problem(rng, c, dk, dk);
        let (w, _) = ut_transform(&k, &v, &beta);
        // P_wy = I − Wᵀ K (in [dk, dk])
        let mut p_wy = Mat::eye(dk);
        let wt_k = w.transpose().matmul(&k);
        for i in 0..dk {
            for j in 0..dk {
                p_wy[(i, j)] -= wt_k[(i, j)];
            }
        }
        // product form (row convention: right-multiplied in order)
        let mut p = Mat::eye(dk);
        for t in 0..c {
            let mut h = Mat::eye(dk);
            for i in 0..dk {
                for j in 0..dk {
                    h[(i, j)] -= beta[t] * k[(t, i)] * k[(t, j)];
                }
            }
            p = p.matmul(&h);
        }
        if !p_wy.allclose(&p, 2e-3, 2e-3) {
            return Err(format!("WY mismatch at C={c} dk={dk}"));
        }
        Ok(())
    });
}

#[test]
fn prop_beta_zero_tokens_are_transparent() {
    // tokens with β=0 must not change the state at all
    check("beta=0 transparency", 20, |rng| {
        let l = 16;
        let (q, k, v, mut beta) = random_problem(rng, l, 8, 8);
        let dead = rng.below(l);
        beta[dead] = 0.0;
        let f = delta_recurrent(&q, &k, &v, &beta, None);
        // rebuild without the dead token
        let keep: Vec<usize> = (0..l).filter(|&t| t != dead).collect();
        let sel = |m: &Mat| Mat::from_rows(
            keep.iter().map(|&t| m.row(t).to_vec()).collect()).unwrap();
        let beta2: Vec<f32> = keep.iter().map(|&t| beta[t]).collect();
        let g = delta_recurrent(&sel(&q), &sel(&k), &sel(&v), &beta2, None);
        if !f.state.allclose(&g.state, 1e-4, 1e-4) {
            return Err("β=0 token affected the state".into());
        }
        Ok(())
    });
}

#[test]
fn prop_attention_matrix_is_causal_and_reconstructs() {
    check("parallel-form attention matrix", 15, |rng| {
        let l = 8 + rng.below(16);
        let (q, k, v, beta) = random_problem(rng, l, 8, 8);
        let a = reference::delta_attention_matrix(&q, &k, &beta);
        // strictly causal: A[i, j] == 0 for j > i
        for i in 0..l {
            for j in (i + 1)..l {
                if a[(i, j)].abs() > 1e-5 {
                    return Err(format!("acausal entry at ({i},{j})"));
                }
            }
        }
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        if !a.matmul(&v).allclose(&want.o, 5e-3, 5e-3) {
            return Err("A·V != O".into());
        }
        Ok(())
    });
}

#[test]
fn prop_delta_with_beta_one_unit_keys_retrieves_exactly() {
    // writing distinct one-hot keys with β=1 gives exact retrieval — the
    // "key collision free" regime the delta rule is designed for
    check("exact retrieval", 20, |rng| {
        let dk = 8;
        let n = 1 + rng.below(dk);
        let mut k = Mat::zeros(n, dk);
        let slots: Vec<usize> = {
            let mut idx: Vec<usize> = (0..dk).collect();
            rng.shuffle(&mut idx);
            idx.truncate(n);
            idx
        };
        for (t, &s) in slots.iter().enumerate() {
            k[(t, s)] = 1.0;
        }
        let v = Mat::from_vec(n, 4, f32_vec(rng, n * 4, 1.0)).unwrap();
        let beta = vec![1.0; n];
        let f = delta_recurrent(&k.clone(), &k, &v, &beta, None);
        // query each key at the end: o from state directly
        for t in 0..n {
            let mut got = vec![0.0f32; 4];
            for i in 0..dk {
                deltanet::tensor::axpy(&mut got, k[(t, i)],
                                       f.state.row(i));
            }
            if dot(&got, &got) == 0.0 {
                return Err("empty retrieval".into());
            }
            for j in 0..4 {
                if (got[j] - v[(t, j)]).abs() > 1e-4 {
                    return Err(format!("slot {t} retrieved wrong value"));
                }
            }
        }
        Ok(())
    });
}
