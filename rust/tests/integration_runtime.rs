//! Integration: PJRT artifact loading + execution, cross-checked against
//! the pure-Rust reference implementation.  Requires `make artifacts` and
//! a real PJRT backend — in the offline build (xla shim, no artifacts)
//! these tests skip themselves.

use deltanet::reference;
use deltanet::runtime::{HostValue, Role, Runtime};
use deltanet::tensor::Mat;

/// PJRT runtime if the backend and artifacts are both present, else None
/// (the test should return early — skipped).
fn runtime() -> Option<Runtime> {
    if !Runtime::backend_available() {
        eprintln!("skipping: PJRT backend not linked (offline build)");
        return None;
    }
    if !std::path::Path::new("artifacts").is_dir() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("PJRT runtime"))
}

#[test]
fn list_and_load_artifacts() {
    let Some(rt) = runtime() else { return };
    let names = rt.list_artifacts().unwrap();
    assert!(names.iter().any(|n| n == "deltanet_tiny.train"),
            "run `make artifacts` first; found {names:?}");
    let exe = rt.load("deltanet_tiny.train").unwrap();
    assert_eq!(exe.manifest.kind, "train");
    assert!(exe.manifest.param_count() > 10_000);
    // cache: second load is instant and shares the Arc
    let exe2 = rt.load("deltanet_tiny.train").unwrap();
    assert!(std::sync::Arc::ptr_eq(&exe, &exe2));
}

#[test]
fn kernel_artifact_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let (b, l, d) = (4usize, 1024usize, 64usize);
    let exe = rt.load("kernel_chunkwise_L1024_d64_C64_B4").unwrap();

    let mut q_all = vec![0f32; b * l * d];
    let mut k_all = vec![0f32; b * l * d];
    let mut v_all = vec![0f32; b * l * d];
    let mut beta_all = vec![0f32; b * l];
    let mut problems = vec![];
    for bi in 0..b {
        let (q, k, v, beta) = reference::random_problem(l, d, d, bi as u64);
        q_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&q.data);
        k_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&k.data);
        v_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&v.data);
        beta_all[bi * l..(bi + 1) * l].copy_from_slice(&beta);
        problems.push((q, k, v, beta));
    }
    let outs = exe.run(&[
        HostValue::from_f32(&[b, l, d], q_all).unwrap(),
        HostValue::from_f32(&[b, l, d], k_all).unwrap(),
        HostValue::from_f32(&[b, l, d], v_all).unwrap(),
        HostValue::from_f32(&[b, l], beta_all).unwrap(),
    ]).unwrap();

    let o = outs[0].as_f32().unwrap();
    let s = outs[1].as_f32().unwrap();
    // cross-check every sequence with the host chunkwise implementation
    for (bi, (q, k, v, beta)) in problems.iter().enumerate() {
        let want = reference::delta_chunkwise(q, k, v, beta, 64, None);
        let got = Mat::from_vec(l, d,
                                o[bi * l * d..(bi + 1) * l * d].to_vec())
            .unwrap();
        assert!(got.allclose(&want.o, 3e-3, 3e-3), "sequence {bi} output");
        let got_s = Mat::from_vec(d, d,
                                  s[bi * d * d..(bi + 1) * d * d].to_vec())
            .unwrap();
        assert!(got_s.allclose(&want.state, 3e-3, 3e-3), "sequence {bi} state");
    }
}

#[test]
fn chunkwise_and_recurrent_artifacts_agree() {
    // the two forms are different programs; on the same inputs they must
    // produce identical outputs (Fig. 1's correctness precondition)
    let Some(rt) = runtime() else { return };
    let (b, l, d) = (16usize, 256usize, 32usize);
    let chunk = rt.load("kernel_chunkwise_L256_d32_C64_B16").unwrap();
    let rec = rt.load("kernel_recurrent_L256_d32_C64_B16").unwrap();

    // keys L2-normalized (the regime the model produces; raw gaussian keys
    // make the Householder products ill-conditioned in fp32 and the two
    // forms accumulate differently)
    let mut q_all = vec![0f32; b * l * d];
    let mut k_all = vec![0f32; b * l * d];
    let mut v_all = vec![0f32; b * l * d];
    let mut beta_all = vec![0f32; b * l];
    for bi in 0..b {
        let (q, k, v, beta) =
            reference::random_problem(l, d, d, 900 + bi as u64);
        q_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&q.data);
        k_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&k.data);
        v_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&v.data);
        beta_all[bi * l..(bi + 1) * l].copy_from_slice(&beta);
    }
    let args = vec![
        HostValue::from_f32(&[b, l, d], q_all).unwrap(),
        HostValue::from_f32(&[b, l, d], k_all).unwrap(),
        HostValue::from_f32(&[b, l, d], v_all).unwrap(),
        HostValue::from_f32(&[b, l], beta_all).unwrap(),
    ];
    let o1 = chunk.run(&args).unwrap();
    let o2 = rec.run(&args).unwrap();
    assert!(o1[0].allclose(&o2[0], 3e-3, 3e-3), "outputs disagree");
    assert!(o1[1].allclose(&o2[1], 3e-3, 3e-3), "states disagree");
}

#[test]
fn manifest_roles_and_carry_wiring() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("deltanet_tiny.train").unwrap();
    let m = &exe.manifest;
    // every param output maps back to a param input of the same shape
    let carry = m.carry_map();
    let n_params = m.inputs_with_role(Role::Param).len();
    assert!(carry.len() >= 3 * n_params, "carry should cover params+m+v");
    for (&o, &i) in &carry {
        assert_eq!(m.outputs[o].name, m.inputs[i].name);
        assert_eq!(m.outputs[o].shape, m.inputs[i].shape);
    }
    // data inputs present
    for name in ["step", "lr", "tokens", "mask"] {
        m.input_index(name).unwrap();
    }
    m.output_index("loss").unwrap();
}

#[test]
fn eval_artifact_runs_and_scores() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("deltanet_tiny.eval").unwrap();
    let m = &exe.manifest;
    let inputs = exe.init_inputs(3).unwrap();
    let mut args: Vec<HostValue> = inputs;
    // random tokens
    let ti = m.input_index("tokens").unwrap();
    let mi = m.input_index("mask").unwrap();
    let (b, l) = (m.batch, m.seq_len);
    args[ti] = HostValue::from_i32(&[b, l + 1],
                                   (0..b * (l + 1)).map(|i| (i % 60) as i32)
                                       .collect()).unwrap();
    args[mi] = HostValue::from_f32(&[b, l], vec![1.0; b * l]).unwrap();
    let outs = exe.run(&args).unwrap();
    let nll = outs[m.output_index("nll_sum").unwrap()].scalar().unwrap();
    assert!(nll.is_finite() && nll > 0.0);
    let preds = outs[m.output_index("preds").unwrap()].as_i32().unwrap();
    assert_eq!(preds.len(), b * l);
    let vocab = m.config.as_ref().unwrap().vocab_size as i32;
    assert!(preds.iter().all(|&p| p >= 0 && p < vocab));
}

#[test]
fn missing_artifact_errors_cleanly() {
    // runs even in the offline build: lookup fails before any execution
    let rt = Runtime::new("artifacts").expect("runtime handle");
    assert!(!rt.has_artifact("nope_nothing"));
    let err = match rt.load("nope_nothing") {
        Ok(_) => panic!("load of missing artifact succeeded"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("nope_nothing"), "unhelpful error: {msg}");
}
