//! Integration: the full training loop over the compiled train-step
//! artifact — loss decreases, checkpoints round-trip, eval wiring works.
//! Requires `make artifacts`.

use deltanet::config::{DataConfig, LrSchedule, RunConfig};
use deltanet::coordinator::Trainer;
use deltanet::data::batcher::Split;
use deltanet::data::build_task;
use deltanet::runtime::Runtime;

/// PJRT runtime if the backend and artifacts are both present, else None
/// (the test should return early — skipped in the offline build).
fn runtime() -> Option<Runtime> {
    if !Runtime::backend_available() {
        eprintln!("skipping: PJRT backend not linked (offline build)");
        return None;
    }
    if !std::path::Path::new("artifacts").is_dir() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("PJRT runtime"))
}

#[test]
fn loss_decreases_on_mqar() {
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "deltanet_tiny", 1).unwrap();
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 1 });
    let mut first = None;
    let mut last = 0.0;
    for step in 0..30 {
        let b = task.sample(trainer.batch, trainer.seq_len);
        let loss = trainer.train_step(&b, 3e-3).unwrap();
        assert!(loss.is_finite(), "step {step}");
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < first * 0.9,
            "loss did not decrease: {first} -> {last}");
}

#[test]
fn full_train_loop_with_eval_and_checkpoint() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("deltanet_it_train");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("ck.npz");
    let log = dir.join("log.jsonl");

    let data = DataConfig::Mqar { num_pairs: 4, seed: 2 };
    let mut trainer = Trainer::new(&rt, "deltanet_tiny", 2).unwrap();
    let split = Split::from_config(&data);
    let mut train_task = split.train;
    let mut eval_task = split.eval;
    let cfg = RunConfig {
        artifact: "deltanet_tiny".into(),
        artifacts_dir: "artifacts".into(),
        steps: 20,
        seed: 2,
        lr: LrSchedule::Constant { lr: 3e-3 },
        data,
        eval_every: 10,
        eval_batches: 2,
        log_path: Some(log.clone()),
        checkpoint_path: Some(ckpt.clone()),
    };
    let report = trainer.train(&cfg, train_task.as_mut(),
                               Some(eval_task.as_mut())).unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_loss.expect("steps ran").is_finite());
    assert_eq!(report.evals.len(), 3); // @10, @20, final
    assert!(ckpt.exists());
    // log has one record per step
    let lines = std::fs::read_to_string(&log).unwrap();
    assert_eq!(lines.lines().count(), 20);

    // checkpoint round-trip: fresh trainer + load == same eval results
    let mut t2 = Trainer::new(&rt, "deltanet_tiny", 999).unwrap();
    t2.load_checkpoint(&ckpt).unwrap();
    let mut fresh_eval = build_task(
        &DataConfig::Mqar { num_pairs: 4, seed: 77 });
    let e1 = trainer.evaluate(fresh_eval.as_mut(), 2).unwrap();
    let mut fresh_eval2 = build_task(
        &DataConfig::Mqar { num_pairs: 4, seed: 77 });
    let e2 = t2.evaluate(fresh_eval2.as_mut(), 2).unwrap();
    assert!((e1.nll - e2.nll).abs() < 1e-5,
            "checkpoint restore changed the model: {} vs {}", e1.nll, e2.nll);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn training_is_deterministic_under_seed() {
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut trainer = Trainer::new(&rt, "deltanet_tiny", 5).unwrap();
        let mut task = build_task(&DataConfig::Corpus { seed: 5 });
        let mut losses = vec![];
        for _ in 0..5 {
            let b = task.sample(trainer.batch, trainer.seq_len);
            losses.push(trainer.train_step(&b, 1e-3).unwrap());
        }
        losses
    };
    assert_eq!(run(), run());
}

#[test]
fn different_archs_all_train() {
    let Some(rt) = runtime() else { return };
    for arch in ["gla", "retnet", "mamba2", "linattn", "transformer",
                 "hybrid_swa", "hybrid_global"] {
        let mut trainer =
            Trainer::new(&rt, &format!("{arch}_tiny"), 1).unwrap();
        let mut task = build_task(&DataConfig::Corpus { seed: 1 });
        let b = task.sample(trainer.batch, trainer.seq_len);
        let l1 = trainer.train_step(&b, 1e-3).unwrap();
        let l2 = trainer.train_step(&b, 1e-3).unwrap();
        assert!(l1.is_finite() && l2.is_finite(), "{arch}");
        assert!(l2 < l1, "{arch}: same-batch loss should drop ({l1}->{l2})");
    }
}

#[test]
fn wrong_batch_shape_rejected() {
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "deltanet_tiny", 1).unwrap();
    let bad = deltanet::data::Batch::new(trainer.batch + 1, trainer.seq_len);
    assert!(trainer.train_step(&bad, 1e-3).is_err());
}

#[test]
fn lr_actually_reaches_the_update() {
    // lr=0 must leave params unchanged (same loss twice on the same batch)
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "deltanet_tiny", 3).unwrap();
    let mut task = build_task(&DataConfig::Corpus { seed: 3 });
    let b = task.sample(trainer.batch, trainer.seq_len);
    let l1 = trainer.train_step(&b, 0.0).unwrap();
    let l2 = trainer.train_step(&b, 0.0).unwrap();
    assert!((l1 - l2).abs() < 1e-6,
            "lr=0 changed the model: {l1} vs {l2}");
}
