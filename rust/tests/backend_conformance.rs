//! Backend-trait conformance: the host implementation, driven ONLY through
//! `dyn Backend` (the way `DecodeEngine`, the server, and the repro
//! harnesses now drive it), must match the scalar reference oracle and
//! train a model end to end.  The PJRT implementation must fail cleanly —
//! not silently substitute — when no plugin is linked in.

use deltanet::config::DataConfig;
use deltanet::coordinator::{
    host_training_backend, select_kernel_backend, Backend,
    HostKernelBackend, KernelForm, PjrtBackend,
};
use deltanet::data::build_task;
use deltanet::model::{HostModel, HostModelCfg};
use deltanet::reference::delta_recurrent;
use deltanet::repro::fig1::host_inputs;
use deltanet::runtime::Runtime;
use deltanet::tensor::Mat;

const B: usize = 3;
const L: usize = 32;
const D: usize = 8;

fn host_backend() -> Box<dyn Backend> {
    Box::new(HostKernelBackend::new(4, 8))
}

/// Per-sequence [L,D] / [L] views into the flat [B,L,D] kernel layout.
fn seq_mats(flat: &[f32], b: usize) -> Mat {
    Mat::from_vec(L, D, flat[b * L * D..(b + 1) * L * D].to_vec()).unwrap()
}

#[test]
fn run_matches_scalar_oracle_through_trait_object() {
    let backend = host_backend();
    let (q, k, v, beta) = host_inputs(B, L, D, 21);
    let (qd, kd, vd, bd) = (q.as_f32().unwrap(), k.as_f32().unwrap(),
                            v.as_f32().unwrap(), beta.as_f32().unwrap());
    for form in [KernelForm::Recurrent, KernelForm::Chunkwise] {
        let (o, state) = backend.run(form, &q, &k, &v, &beta).unwrap();
        assert_eq!(o.shape(), &[B, L, D]);
        assert_eq!(state.shape(), &[B, D, D]);
        let (od, sd) = (o.as_f32().unwrap(), state.as_f32().unwrap());
        for bi in 0..B {
            let want = delta_recurrent(
                &seq_mats(qd, bi), &seq_mats(kd, bi), &seq_mats(vd, bi),
                &bd[bi * L..(bi + 1) * L], None);
            let got_o = seq_mats(od, bi);
            assert!(got_o.allclose(&want.o, 1e-4, 1e-4),
                    "output mismatch, seq {bi}");
            let got_s = Mat::from_vec(
                D, D, sd[bi * D * D..(bi + 1) * D * D].to_vec()).unwrap();
            assert!(got_s.allclose(&want.state, 1e-4, 1e-4),
                    "state mismatch, seq {bi}");
        }
    }
}

#[test]
fn chunk_override_is_equivalent_through_trait_object() {
    let backend = host_backend();
    let (q, k, v, beta) = host_inputs(B, L, D, 22);
    let (o64, s64) = backend
        .run_with_chunk(KernelForm::Chunkwise, 64, &q, &k, &v, &beta)
        .unwrap();
    let (o1, s1) = backend
        .run_with_chunk(KernelForm::Chunkwise, 1, &q, &k, &v, &beta)
        .unwrap();
    let oa = o64.as_f32().unwrap();
    let ob = o1.as_f32().unwrap();
    for (x, y) in oa.iter().zip(ob) {
        assert!((x - y).abs() < 1e-3, "chunk 64 vs 1: {x} vs {y}");
    }
    for (x, y) in s64.as_f32().unwrap().iter().zip(s1.as_f32().unwrap()) {
        assert!((x - y).abs() < 1e-3);
    }
}

#[test]
fn prefill_then_decode_continues_the_full_forward() {
    let backend = host_backend();
    let half = L / 2;
    let (q, k, v, beta) = host_inputs(B, L, D, 23);
    let (full_o, full_s) =
        backend.run(KernelForm::Chunkwise, &q, &k, &v, &beta).unwrap();
    let (fo, fs) = (full_o.as_f32().unwrap(), full_s.as_f32().unwrap());
    let (qd, kd, vd, bd) = (q.as_f32().unwrap(), k.as_f32().unwrap(),
                            v.as_f32().unwrap(), beta.as_f32().unwrap());

    // prefill on the first half...
    let front = |src: &[f32]| -> deltanet::runtime::HostValue {
        let mut out = Vec::with_capacity(B * half * D);
        for bi in 0..B {
            out.extend_from_slice(
                &src[bi * L * D..bi * L * D + half * D]);
        }
        deltanet::runtime::HostValue::from_f32(&[B, half, D], out).unwrap()
    };
    let beta_front = {
        let mut out = Vec::with_capacity(B * half);
        for bi in 0..B {
            out.extend_from_slice(&bd[bi * L..bi * L + half]);
        }
        deltanet::runtime::HostValue::from_f32(&[B, half], out).unwrap()
    };
    let mut states = backend
        .prefill(&front(qd), &front(kd), &front(vd), &beta_front)
        .unwrap();
    assert_eq!(states.len(), B);

    // ...then decode the second half token by token
    for t in half..L {
        let row = |src: &[f32]| {
            let mut out = Vec::with_capacity(B * D);
            for bi in 0..B {
                let at = bi * L * D + t * D;
                out.extend_from_slice(&src[at..at + D]);
            }
            Mat::from_vec(B, D, out).unwrap()
        };
        let bt: Vec<f32> = (0..B).map(|bi| bd[bi * L + t]).collect();
        let o_t = backend
            .decode_step(&mut states, &row(qd), &row(kd), &row(vd), &bt)
            .unwrap();
        for bi in 0..B {
            for j in 0..D {
                let want = fo[bi * L * D + t * D + j];
                let got = o_t[(bi, j)];
                assert!((got - want).abs() < 1e-3,
                        "token {t} seq {bi} dim {j}: {got} vs {want}");
            }
        }
    }
    // final decoded state == full-forward state
    for bi in 0..B {
        for j in 0..D * D {
            let want = fs[bi * D * D + j];
            let got = states[bi].data[j];
            assert!((got - want).abs() < 1e-3,
                    "final state seq {bi} elem {j}: {got} vs {want}");
        }
    }
}

#[test]
fn train_step_learns_through_trait_object() {
    let cfg = HostModelCfg {
        vocab: 64, d_model: 32, n_layers: 2, n_heads: 2, chunk: 8,
    };
    let model = HostModel::new(cfg, 9, 2).unwrap();
    let mut backend: Box<dyn Backend> =
        Box::new(host_training_backend(model));
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 2 });
    let mut first = None;
    let mut last = f32::MAX;
    for _ in 0..15 {
        let batch = task.sample(4, 32);
        last = backend.train_step(&batch, 1e-2).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last.is_finite() && last < first,
            "loss did not drop under dyn Backend training: \
             {first} -> {last}");
}

#[test]
fn train_step_without_model_fails_cleanly() {
    let mut backend = host_backend();
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 2 });
    let batch = task.sample(2, 16);
    let err = backend.train_step(&batch, 1e-2).unwrap_err();
    assert!(format!("{err:#}").contains("model"),
            "unhelpful error: {err:#}");
}

#[test]
fn selection_and_pjrt_behavior_offline() {
    if Runtime::backend_available() {
        return; // covered by the artifact integration suite
    }
    // selection must hand back the host impl, not a doomed pjrt one
    let backend =
        select_kernel_backend(std::path::Path::new("artifacts"), 16)
            .unwrap();
    assert_eq!(backend.name(), "host");

    // and a force-constructed pjrt backend must error, not hang or lie
    let pjrt =
        PjrtBackend::new(Runtime::new("artifacts").unwrap(), 16).unwrap();
    assert_eq!(pjrt.name(), "pjrt");
    let (q, k, v, beta) = host_inputs(1, 8, 4, 1);
    assert!(pjrt.run(KernelForm::Chunkwise, &q, &k, &v, &beta).is_err());
    let mut states = vec![Mat::zeros(4, 4)];
    let r = pjrt.decode_step(&mut states, &Mat::zeros(1, 4),
                             &Mat::zeros(1, 4), &Mat::zeros(1, 4), &[0.5]);
    assert!(r.is_err());
}
