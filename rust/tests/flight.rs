//! Crash-drill integration test for the flight recorder (ISSUE 9
//! acceptance): a pool-worker panic in the middle of a host training run
//! must leave a valid `FLIGHT_<run>.json` post-mortem on disk containing
//! the last pre-panic train step.
//!
//! Everything lives in ONE test: the dump path and panic hook are process
//! globals, and this integration binary owns its process.

use deltanet::config::DataConfig;
use deltanet::coordinator::host_training_backend;
use deltanet::data::build_task;
use deltanet::kernels::default_threads;
use deltanet::model::{HostModel, HostModelCfg};
use deltanet::obs::flight;
use deltanet::util::json::Json;
use deltanet::util::threadpool::ThreadPool;

#[test]
fn pool_panic_mid_training_dumps_a_valid_flight_recording() {
    let dir = std::env::temp_dir().join("deltanet_it_flight");
    std::fs::create_dir_all(&dir).unwrap();
    flight::set_dump_dir(&dir);
    flight::set_run_id("it_flight");
    flight::install_panic_hook();
    let dump = flight::dump_path();
    std::fs::remove_file(&dump).ok();

    // a short traced training run: each step records a flight Step event
    let steps = 5usize;
    let model =
        HostModel::new(HostModelCfg::tiny(), 11, default_threads()).unwrap();
    let mut backend = host_training_backend(model);
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 11 });
    let mut last_loss = 0f32;
    for _ in 0..steps {
        let batch = task.sample(2, 32);
        let (loss, _) = backend.train_step_detailed(&batch, 1e-2).unwrap();
        last_loss = loss;
    }

    // crash drill: a pool worker panics; the pool survives, the hook dumps
    let pool = ThreadPool::new(1);
    let r = pool.submit(|| panic!("injected flight-test panic")).join();
    assert!(r.is_err(), "injected job should report a panic");

    // the post-mortem exists, parses, and matches the dump schema
    let text = std::fs::read_to_string(&dump)
        .unwrap_or_else(|e| panic!("no dump at {}: {e}", dump.display()));
    let j = Json::parse(&text).expect("dump is valid JSON");
    assert_eq!(j.get("schema").unwrap().as_str().unwrap(), flight::SCHEMA);
    assert_eq!(j.get("run").unwrap().as_str().unwrap(), "it_flight");
    assert!(j.get("metrics").unwrap().get("counters").is_some());

    let events = j.get("events").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    // sequence numbers strictly increase (snapshot is ordered + untorn)
    let seqs: Vec<u64> = events.iter()
        .map(|e| e.get("seq").unwrap().as_u64().unwrap())
        .collect();
    assert!(seqs.windows(2).all(|w| w[1] > w[0]), "seq not increasing");

    // the LAST pre-panic train step made it into the recording, with the
    // loss the backend actually reported
    let step_evs: Vec<&Json> = events.iter()
        .filter(|e| e.get("name").unwrap().as_str().unwrap() == "train.step")
        .collect();
    assert!(step_evs.len() >= steps, "expected {} step events, got {}",
            steps, step_evs.len());
    let last = step_evs.last().unwrap();
    assert_eq!(last.get("kind").unwrap().as_str().unwrap(), "step");
    let fields = last.get("fields").unwrap();
    assert_eq!(fields.get("step").unwrap().as_f64().unwrap(),
               steps as f64);
    let recorded = fields.get("loss").unwrap().as_f64().unwrap();
    assert!((recorded - last_loss as f64).abs() < 1e-6,
            "dump loss {recorded} != live loss {last_loss}");

    // ... and the panic itself was recorded after it
    let last_step_seq = last.get("seq").unwrap().as_u64().unwrap();
    let panic_ev = events.iter()
        .find(|e| e.get("kind").unwrap().as_str().unwrap() == "panic")
        .expect("panic event recorded");
    assert!(panic_ev.get("seq").unwrap().as_u64().unwrap() > last_step_seq,
            "panic event should follow the last train step");
    assert!(panic_ev.get("name").unwrap().as_str().unwrap()
        .starts_with("panic@"), "panic event names its location");

    std::fs::remove_dir_all(&dir).ok();
}
