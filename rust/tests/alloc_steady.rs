//! Steady-state allocation audit of the chunkwise hot path.
//!
//! The chunk loops in `kernels::chunkwise` / `kernels::backward` run on
//! thread-local [`ChunkWorkspace`] scratch, so after warmup the heap
//! traffic of a forward or backward call must not depend on how many
//! chunks the sequence has: only the per-call outputs (o, gradients,
//! state) allocate.  A counting `#[global_allocator]` makes that claim a
//! test — one extra allocation per chunk shows up as a count difference
//! between a 2-chunk and a 16-chunk problem.
//!
//! Single `#[test]` on purpose: the counter is process-global, and a
//! concurrent test would perturb the counts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use deltanet::kernels::{chunkwise_backward, chunkwise_forward};
use deltanet::reference::random_problem;
use deltanet::tensor::Mat;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

const C: usize = 16;
const D: usize = 16;

struct Problem {
    q: Mat,
    k: Mat,
    v: Mat,
    beta: Vec<f32>,
    d_o: Mat,
}

fn problem(n_chunks: usize, seed: u64) -> Problem {
    let l = n_chunks * C;
    let (q, k, v, beta) = random_problem(l, D, D, seed);
    let (_, _, d_o, _) = random_problem(l, D, D, seed + 1);
    Problem { q, k, v, beta, d_o }
}

fn run_forward(p: &Problem) {
    let f = chunkwise_forward(&p.q, &p.k, &p.v, &p.beta, C, None);
    std::hint::black_box(&f);
}

fn run_backward(p: &Problem) {
    let g = chunkwise_backward(&p.q, &p.k, &p.v, &p.beta, C, None, &p.d_o,
                               None);
    std::hint::black_box(&g);
}

fn counted<F: FnOnce()>(f: F) -> u64 {
    let before = alloc_calls();
    f();
    alloc_calls() - before
}

#[test]
fn chunk_loop_is_allocation_free_at_steady_state() {
    // inputs built up front so only the kernel calls are counted
    let small = problem(2, 11);
    let big = problem(16, 12);

    // Warmup sizes the thread-local workspace (and the backward
    // checkpoint buffer) for the LARGEST problem, and interns the
    // kernels.* counters — after this, steady state.
    for _ in 0..2 {
        run_forward(&big);
        run_backward(&big);
        run_forward(&small);
        run_backward(&small);
    }

    let fwd_small = counted(|| run_forward(&small));
    let fwd_big = counted(|| run_forward(&big));
    assert_eq!(
        fwd_small, fwd_big,
        "forward allocation count grew with chunk count \
         (2 chunks: {fwd_small}, 16 chunks: {fwd_big}) — \
         something in the chunk loop allocates per chunk"
    );

    let bwd_small = counted(|| run_backward(&small));
    let bwd_big = counted(|| run_backward(&big));
    assert_eq!(
        bwd_small, bwd_big,
        "backward allocation count grew with chunk count \
         (2 chunks: {bwd_small}, 16 chunks: {bwd_big}) — \
         something in the pre-pass or reverse scan allocates per chunk"
    );

    // The per-call budget is the outputs plus a couple of temporaries;
    // a generous ceiling still catches a per-chunk regression (16 chunks
    // x several mats each would blow straight past it).
    assert!(fwd_big <= 16,
            "forward makes {fwd_big} allocations per call (budget 16)");
    assert!(bwd_big <= 32,
            "backward makes {bwd_big} allocations per call (budget 32)");
}
