//! Host-side shim of the `xla-rs` PJRT surface the coordinator uses.
//!
//! The real build links a vendored `xla-rs` (PJRT CPU plugin + HLO
//! compiler).  That toolchain is not available in the offline CI image, so
//! this crate provides the same API with two behaviours:
//!
//!   * **Literals are fully functional** — host tensors (shape + dtype +
//!     bytes) with creation, reshape, raw copies and typed readback.  All
//!     coordinator plumbing that moves data in and out of literals works.
//!   * **Compilation/execution is unavailable** — `PjRtClient::compile`
//!     returns an error, so artifact-driven paths fail cleanly and callers
//!     (tests, benches, repro harnesses) fall back to the host kernel
//!     backend or skip.  `pjrt_available()` reports which build this is.
//!
//! Swapping the real bindings back in is a Cargo-level change only; no
//! coordinator code references this crate's stub-ness beyond
//! `pjrt_available()`.

use std::fmt;
use std::path::Path;

/// Whether a real PJRT backend is linked in.  This shim always says no.
pub fn pjrt_available() -> bool {
    false
}

// --------------------------------------------------------------- errors

/// Error type mirroring xla-rs (message-only in the shim).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// --------------------------------------------------------------- dtypes

/// Element types of array literals (subset the exporter emits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

impl ElementType {
    pub fn element_size_in_bytes(&self) -> usize {
        match self {
            ElementType::Pred | ElementType::U8 => 1,
            ElementType::S32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::F64 => 8,
        }
    }
}

/// HLO-level primitive types (alias surface used by literal constructors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

impl PrimitiveType {
    pub fn element_type(self) -> ElementType {
        match self {
            PrimitiveType::Pred => ElementType::Pred,
            PrimitiveType::S32 => ElementType::S32,
            PrimitiveType::S64 => ElementType::S64,
            PrimitiveType::U8 => ElementType::U8,
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::F64 => ElementType::F64,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
}

// --------------------------------------------------------------- shapes

/// Array shape: dims + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>().max(1) as usize
    }
}

// -------------------------------------------------------------- literal

/// A host tensor: shape + dtype + raw little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
}

fn dims_product(dims: &[i64]) -> usize {
    dims.iter().product::<i64>().max(1) as usize
}

impl Literal {
    /// Scalar literal (rank 0).
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut data = vec![0u8; std::mem::size_of::<T>()];
        unsafe {
            std::ptr::copy_nonoverlapping(
                &v as *const T as *const u8,
                data.as_mut_ptr(),
                data.len(),
            );
        }
        Literal {
            shape: ArrayShape { dims: vec![], ty: T::TY },
            data,
        }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        let bytes = values.len() * std::mem::size_of::<T>();
        let mut data = vec![0u8; bytes];
        unsafe {
            std::ptr::copy_nonoverlapping(
                values.as_ptr() as *const u8,
                data.as_mut_ptr(),
                bytes,
            );
        }
        Literal {
            shape: ArrayShape {
                dims: vec![values.len() as i64],
                ty: T::TY,
            },
            data,
        }
    }

    /// Zero-initialized literal of a given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let ty = ty.element_type();
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let bytes = dims_product(&dims) * ty.element_size_in_bytes();
        Literal {
            shape: ArrayShape { dims, ty },
            data: vec![0u8; bytes],
        }
    }

    /// Literal of a given shape from raw bytes (single copy).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let want = dims_product(&dims) * ty.element_size_in_bytes();
        if data.len() != want {
            return Err(Error::msg(format!(
                "shape {dims:?} ({ty:?}) wants {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal {
            shape: ArrayShape { dims, ty },
            data: data.to_vec(),
        })
    }

    /// Same element count, new dims.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if dims_product(dims) != self.shape.element_count() {
            return Err(Error::msg(format!(
                "cannot reshape {:?} to {dims:?}",
                self.shape.dims
            )));
        }
        let mut out = self.clone();
        out.shape.dims = dims.to_vec();
        Ok(out)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn element_count(&self) -> usize {
        self.shape.element_count()
    }

    /// Typed readback (copies).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.shape.ty != T::TY {
            return Err(Error::msg(format!(
                "literal is {:?}, asked for {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        let n = self.shape.element_count();
        let mut out: Vec<T> = Vec::with_capacity(n);
        // byte-wise copy: the Vec<u8> buffer has no alignment guarantee for T
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                n * std::mem::size_of::<T>(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    /// Overwrite the buffer from typed host data (shape unchanged).
    pub fn copy_raw_from<T: NativeType>(&mut self, data: &[T]) -> Result<()> {
        if self.shape.ty != T::TY {
            return Err(Error::msg(format!(
                "literal is {:?}, copying {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        if data.len() != self.shape.element_count() {
            return Err(Error::msg(format!(
                "literal holds {} elems, copying {}",
                self.shape.element_count(),
                data.len()
            )));
        }
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr() as *const u8,
                self.data.as_mut_ptr(),
                self.data.len(),
            );
        }
        Ok(())
    }

    /// Split a tuple literal into its elements.  The shim never produces
    /// tuple literals (execution is unavailable), so this always errors.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::msg("not a tuple literal (shim build)"))
    }
}

// ------------------------------------------------------------------ HLO

/// Parsed HLO module text (opaque; the shim only checks readability).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::msg(format!("reading {}: {e}", path.display()))
        })?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation built from an HLO proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

// ----------------------------------------------------------------- PJRT

/// PJRT client handle.  Construction succeeds so that coordinator wiring
/// (artifact listing, manifests, host fallbacks) works; only `compile`
/// reports the missing backend.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "host-shim (no PJRT)".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::msg(
            "PJRT backend not linked in this build; artifact execution is \
             unavailable (host kernel backend and reference paths still \
             work)",
        ))
    }
}

/// A compiled executable (never constructed by the shim).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg("PJRT backend not linked in this build"))
    }
}

/// A device buffer (never constructed by the shim).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::msg("PJRT backend not linked in this build"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let mut lit = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(lit.element_count(), 6);
        lit.copy_raw_from(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let mut lit = Literal::create_from_shape(PrimitiveType::S32, &[4]);
        lit.copy_raw_from(&[7i32, 8, 9, 10]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9, 10]);
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
        assert!(s.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn vec1_reshape_and_untyped() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(lit.reshape(&[3]).is_err());

        let bytes: Vec<u8> = [1.5f32, -2.5]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let u = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &bytes,
        )
        .unwrap();
        assert_eq!(u.to_vec::<f32>().unwrap(), vec![1.5, -2.5]);
    }

    #[test]
    fn type_mismatch_rejected() {
        let lit = Literal::vec1(&[1i32, 2]);
        assert!(lit.to_vec::<f32>().is_err());
        let mut lit = lit;
        assert!(lit.copy_raw_from(&[1.0f32, 2.0]).is_err());
        assert!(lit.copy_raw_from(&[1i32]).is_err());
    }

    #[test]
    fn compile_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        assert!(!pjrt_available());
        assert!(client.platform_name().contains("shim"));
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: String::new(),
        });
        let err = client.compile(&comp).err().unwrap();
        assert!(format!("{err}").contains("PJRT"));
    }
}
