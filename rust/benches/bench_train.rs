//! Host training smoke bench: N AdamW steps of the tiny DeltaNet model on
//! MQAR through `Backend::train_step`, reporting the loss trajectory and
//! tokens/sec.  CI runs this with DELTANET_BENCH_SMOKE=1 (20 steps) and
//! archives `BENCH_train.json` next to `BENCH_kernels.json`, so both the
//! perf trajectory AND the does-it-still-learn signal are tracked per PR.
//!
//! With DELTANET_TRACE set, also writes the span trace to
//! `TRACE_train.json` at the repo root (CI validates it with
//! `deltanet trace-check`).  Without tracing, the bench measures the
//! disabled-span overhead and fails if it exceeds 2% of a train step.
//!
//!     DELTANET_BENCH_SMOKE=1 cargo bench --bench bench_train

use std::time::Instant;

use deltanet::config::DataConfig;
use deltanet::coordinator::host_training_backend;
use deltanet::data::build_task;
use deltanet::kernels::default_threads;
use deltanet::model::{HostModel, HostModelCfg};
use deltanet::tensor::simd;
use deltanet::util::bench::{repo_root, smoke_mode, BenchResult};
use deltanet::util::json::Json;

const BATCH: usize = 8;
const SEQ: usize = 64;

fn main() -> deltanet::Result<()> {
    deltanet::obs::trace::init_from_env();
    // Arm the flight recorder: a panic anywhere in the bench (including a
    // pool worker) leaves FLIGHT_train.json at the repo root.
    if std::env::var_os("DELTANET_FLIGHT_DIR").is_none() {
        deltanet::obs::flight::set_dump_dir(&repo_root());
    }
    if std::env::var_os("DELTANET_RUN_ID").is_none() {
        deltanet::obs::flight::set_run_id("train");
    }
    deltanet::obs::flight::init_from_env();
    let steps = if smoke_mode() { 20 } else { 100 };
    let lr = 1e-2f32;
    // Crash-drill knob: panic a pool worker at the given step to prove the
    // flight recorder dumps a valid post-mortem mid-bench.
    let inject_panic: Option<usize> = std::env::var("DELTANET_INJECT_PANIC")
        .ok()
        .and_then(|v| v.parse().ok());

    let model = HostModel::new(HostModelCfg::tiny(), 7, default_threads())?;
    println!("host training bench: {} params, {BATCH}x{SEQ} tokens/step, \
              {steps} steps", model.param_count());
    let mut backend = host_training_backend(model);
    let mut task = build_task(&DataConfig::Mqar { num_pairs: 8, seed: 7 });

    let mut losses: Vec<f32> = Vec::with_capacity(steps);
    let mut times: Vec<f64> = Vec::with_capacity(steps);
    let mut gflops: Vec<f64> = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for s in 0..steps {
        if inject_panic == Some(s) {
            println!("injecting pool-worker panic at step {s} (crash drill)");
            let pool = deltanet::util::threadpool::ThreadPool::new(1);
            let r = pool.submit(|| panic!("bench_train injected panic"))
                .join();
            assert!(r.is_err(), "injected job did not panic");
            println!("pool survived; flight dump at {}",
                     deltanet::obs::flight::dump_path().display());
        }
        let batch = task.sample(BATCH, SEQ);
        let ts = Instant::now();
        let (loss, bd) = backend.train_step_detailed(&batch, lr)?;
        times.push(ts.elapsed().as_secs_f64());
        losses.push(loss);
        gflops.push(bd.gflops);
        if s % 10 == 0 || s + 1 == steps {
            println!("step {s:>4}  loss {loss:.4}  \
                      {:>7.0} tok/s  {:>6.2} GFLOP/s",
                     bd.tokens_per_sec, bd.gflops);
        }
    }
    let total = t0.elapsed().as_secs_f64();
    let tokens_per_sec = (steps * BATCH * SEQ) as f64 / total;
    let gflops_mean = gflops.iter().sum::<f64>() / gflops.len() as f64;

    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let step_bench = BenchResult {
        name: "host_train_step_tiny_mqar".to_string(),
        reps: steps,
        median_s: q(0.5),
        p10_s: q(0.1),
        p90_s: q(0.9),
    };
    step_bench.print();

    let (loss_first, loss_last) = (losses[0], losses[steps - 1]);
    println!("loss {loss_first:.4} -> {loss_last:.4} | \
              {tokens_per_sec:.0} tok/s | {gflops_mean:.2} GFLOP/s \
              ({} kernels) | {total:.1}s",
             simd::level().name());

    // When NOT tracing, bound the cost of the disabled instrumentation:
    // time raw disabled span() calls and scale to a generous per-step span
    // count.  A train step opens well under 1000 spans at tiny scale
    // (per-chunk kernel spans dominate), so 1000 × disabled-span cost must
    // stay under 2% of the median step.
    let mut span_overhead_frac = None;
    if !deltanet::obs::trace::enabled() {
        let reps = 200_000u32;
        let t = Instant::now();
        for _ in 0..reps {
            let _sp = deltanet::obs::trace::span("bench.disabled_span");
        }
        let per_span_s = t.elapsed().as_secs_f64() / reps as f64;
        let frac = 1000.0 * per_span_s / step_bench.median_s;
        println!("disabled-span overhead: {:.1} ns/span \
                  (~{:.3}% of a train step at 1000 spans/step)",
                 per_span_s * 1e9, frac * 100.0);
        deltanet::ensure!(frac < 0.02,
                          "disabled tracing costs {:.2}% of a train step \
                           (budget 2%)", frac * 100.0);
        span_overhead_frac = Some(frac);
    }

    // the BENCH_<suite>.json schema plus the training trajectory
    let path = repo_root().join("BENCH_train.json");
    let mut fields = vec![
        ("suite", Json::str("train")),
        ("steps", Json::num(steps as f64)),
        ("loss_first", Json::num(loss_first as f64)),
        ("loss_last", Json::num(loss_last as f64)),
        ("tokens_per_sec", Json::num(tokens_per_sec)),
        ("gflops_mean", Json::num(gflops_mean)),
        ("simd_level", Json::str(simd::level().name())),
        ("losses",
         Json::Arr(losses.iter().map(|&l| Json::num(l as f64)).collect())),
        ("results", Json::Arr(vec![step_bench.to_json()])),
    ];
    if let Some(frac) = span_overhead_frac {
        fields.push(("span_overhead_frac", Json::num(frac)));
    }
    let json = Json::obj(fields);
    std::fs::write(&path, json.render() + "\n")?;
    println!("report: {}", path.display());

    // cargo bench runs with cwd = the package dir, so anchor the trace at
    // the repo root where CI's `deltanet trace-check TRACE_train.json`
    // (run from the checkout root) will look for it
    if deltanet::obs::trace::enabled() {
        let trace_path = repo_root().join("TRACE_train.json");
        deltanet::obs::trace::write_trace(&trace_path)?;
        println!("trace: {}", trace_path.display());
    }

    deltanet::ensure!(loss_last.is_finite() && loss_last < loss_first,
                      "training smoke did not reduce loss: \
                       {loss_first} -> {loss_last}");
    Ok(())
}
