//! Bench: Figure 1 — chunkwise-parallel vs recurrent DeltaNet kernels
//! across (L, d_head) at fixed B·L = 4096 tokens, plus the chunk-size
//! sweep.  Prefers the PJRT kernel artifacts; without them (offline
//! build) it runs the same comparison on the batched host kernel backend.
//! Writes `BENCH_fig1_forms.json` at the repo root.
//!
//!     cargo bench --bench bench_fig1_forms

use deltanet::coordinator::host::{HostKernelBackend, KernelForm};
use deltanet::kernels::default_threads;
use deltanet::repro::fig1::host_inputs;
use deltanet::runtime::{HostValue, Runtime};
use deltanet::tensor::rng::Rng;
use deltanet::util::bench::{
    bench_result, smoke_mode, write_report, BenchResult,
};

fn inputs(b: usize, l: usize, d: usize, seed: u64) -> Vec<xla::Literal> {
    let mut rng = Rng::new(seed);
    let mut t = |shape: &[usize]| {
        let n: usize = shape.iter().product();
        HostValue::from_f32(shape, (0..n).map(|_| rng.normal()).collect())
            .unwrap().to_literal().unwrap()
    };
    let q = t(&[b, l, d]);
    let k = t(&[b, l, d]);
    let v = t(&[b, l, d]);
    let mut rng2 = Rng::new(seed ^ 1);
    let beta = HostValue::from_f32(&[b, l], (0..b * l)
        .map(|_| 1.0 / (1.0 + (-rng2.normal()).exp())).collect())
        .unwrap().to_literal().unwrap();
    vec![q, k, v, beta]
}

/// PJRT path: one (form, L, d, C, B) kernel artifact.
fn bench_artifact(rt: &Runtime, form: &str, l: usize, d: usize, c: usize,
                  b: usize) -> deltanet::Result<BenchResult> {
    let name = format!("kernel_{form}_L{l}_d{d}_C{c}_B{b}");
    let exe = rt.load(&name)?;
    let args = inputs(b, l, d, 7);
    bench_result(&name, 1, 5, || {
        exe.execute(&args)?;
        Ok(())
    })
}

/// Both forms through the artifact path, failing if either is unavailable.
fn bench_artifact_pair(rt: &Runtime, l: usize, d: usize, b: usize)
                       -> deltanet::Result<(BenchResult, BenchResult)> {
    let rec = bench_artifact(rt, "recurrent", l, d, 64, b)?;
    let chk = bench_artifact(rt, "chunkwise", l, d, 64, b)?;
    Ok((rec, chk))
}

/// Host path: same comparison on the batched host kernel backend (one
/// shared pool for the whole bench).
fn bench_host(backend: &HostKernelBackend, form: KernelForm, l: usize,
              d: usize, c: usize, b: usize, reps: usize)
              -> deltanet::Result<BenchResult> {
    let tag = match form {
        KernelForm::Recurrent => "recurrent",
        KernelForm::Chunkwise => "chunkwise",
    };
    let (q, k, v, beta) = host_inputs(b, l, d, 7);
    bench_result(&format!("host_{tag}_L{l}_d{d}_C{c}_B{b}"), 1, reps, || {
        backend.run_with_chunk(form, c, &q, &k, &v, &beta)?;
        Ok(())
    })
}

fn main() -> deltanet::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let smoke = smoke_mode();
    let host = HostKernelBackend::new(default_threads(), 64);
    let mut report: Vec<BenchResult> = vec![];

    println!("# Figure 1: forms comparison (B·L = 4096 tokens, C = 64)");
    let ds: &[usize] = if smoke { &[64] } else { &[32, 64] };
    let ls: &[usize] =
        if smoke { &[256, 1024] } else { &[256, 512, 1024, 2048, 4096] };
    for &d in ds {
        for &l in ls {
            let b = 4096 / l;
            let reps = if smoke { 3 } else { 5 };
            let artifact = bench_artifact_pair(&rt, l, d, b);
            let pair = match artifact {
                Ok(p) => p,
                Err(_) => (
                    bench_host(&host, KernelForm::Recurrent, l, d, 64, b,
                               reps)?,
                    bench_host(&host, KernelForm::Chunkwise, l, d, 64, b,
                               reps)?,
                ),
            };
            println!("speedup L={l} d={d}: {:.1}x",
                     pair.0.median_s / pair.1.median_s);
            report.push(pair.0);
            report.push(pair.1);
        }
    }

    println!("\n# chunk-size sweep (L=1024, d=64, B=4)");
    let cs: &[usize] = if smoke { &[32, 64] } else { &[16, 32, 64, 128] };
    for &c in cs {
        let r = bench_artifact(&rt, "chunkwise", 1024, 64, c, 4).or_else(
            |_| bench_host(&host, KernelForm::Chunkwise, 1024, 64, c, 4,
                           3))?;
        report.push(r);
    }

    let path = write_report("fig1_forms", &report)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
