//! Bench: Figure 1 — chunkwise-parallel vs recurrent DeltaNet kernels
//! across (L, d_head) at fixed B·L = 4096 tokens, plus the chunk-size
//! sweep.  `cargo bench --bench bench_fig1_forms`

use deltanet::runtime::{HostValue, Runtime};
use deltanet::tensor::rng::Rng;
use deltanet::util::bench::bench_result;

fn inputs(b: usize, l: usize, d: usize, seed: u64) -> Vec<xla::Literal> {
    let mut rng = Rng::new(seed);
    let mut t = |shape: &[usize]| {
        let n: usize = shape.iter().product();
        HostValue::from_f32(shape, (0..n).map(|_| rng.normal()).collect())
            .unwrap().to_literal().unwrap()
    };
    let q = t(&[b, l, d]);
    let k = t(&[b, l, d]);
    let v = t(&[b, l, d]);
    let mut rng2 = Rng::new(seed ^ 1);
    let beta = HostValue::from_f32(&[b, l], (0..b * l)
        .map(|_| 1.0 / (1.0 + (-rng2.normal()).exp())).collect())
        .unwrap().to_literal().unwrap();
    vec![q, k, v, beta]
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("# Figure 1: forms comparison (B·L = 4096 tokens, C = 64)");
    for d in [32, 64] {
        for l in [256, 512, 1024, 2048, 4096] {
            let b = 4096 / l;
            let mut results = vec![];
            for form in ["recurrent", "chunkwise"] {
                let name = format!("kernel_{form}_L{l}_d{d}_C64_B{b}");
                let exe = rt.load(&name)?;
                let args = inputs(b, l, d, 7);
                let r = bench_result(&name, 1, 5, || {
                    exe.execute(&args)?;
                    Ok(())
                })?;
                results.push(r.median_s);
            }
            println!("speedup L={l} d={d}: {:.1}x",
                     results[0] / results[1]);
        }
    }

    println!("\n# chunk-size sweep (L=1024, d=64, B=4)");
    for c in [16, 32, 64, 128] {
        let name = format!("kernel_chunkwise_L1024_d64_C{c}_B4");
        let exe = rt.load(&name)?;
        let args = inputs(4, 1024, 64, 7);
        bench_result(&name, 1, 5, || {
            exe.execute(&args)?;
            Ok(())
        })?;
    }
    Ok(())
}
