//! Bench: recurrent decode step latency + generation throughput — the
//! constant-memory serving path.  `cargo bench --bench bench_decode`

use deltanet::coordinator::generate::Sampling;
use deltanet::coordinator::DecodeEngine;
use deltanet::runtime::Runtime;
use deltanet::util::bench::bench_result;

fn main() -> deltanet::Result<()> {
    let rt = Runtime::new("artifacts")?;
    if !Runtime::backend_available() {
        println!("no PJRT backend in this build; decode bench needs \
                  artifacts — skipping");
        return Ok(());
    }
    for artifact in ["deltanet_tiny", "hybrid_swa_tiny", "deltanet_small"] {
        if !rt.has_artifact(&format!("{artifact}.decode")) {
            continue;
        }
        let mut engine = DecodeEngine::new(&rt, artifact, 0)?;
        let b = engine.batch;
        let tokens = vec![1i32; b];
        let mut pos = 0usize;
        let max = engine.max_seq_len;
        let r = bench_result(&format!("{artifact}.decode_step(B={b})"),
                             3, 20, || {
                                 engine.step(&tokens, pos % max)?;
                                 pos += 1;
                                 Ok(())
                             })?;
        println!("  -> per-token decode latency {:.2} ms, {:.0} tok/s \
                  across the batch",
                 r.median_s * 1e3, b as f64 / r.median_s);

        // whole-generation throughput (prompt 4, 32 new tokens)
        let prompts: Vec<Vec<i32>> = (0..b).map(|i| vec![1 + i as i32 % 8,
                                                         2, 3, 4]).collect();
        let r = bench_result(&format!("{artifact}.generate(32 new)"),
                             1, 3, || {
                                 engine.generate(&prompts, 32,
                                                 Sampling::Greedy, 0)?;
                                 Ok(())
                             })?;
        println!("  -> {:.0} tok/s generation",
                 (b * 32) as f64 / r.median_s);
    }
    Ok(())
}
