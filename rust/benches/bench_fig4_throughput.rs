//! Bench: Figure 4 — end-to-end training throughput (tokens/sec) per
//! architecture family.  `cargo bench --bench bench_fig4_throughput`

use deltanet::config::DataConfig;
use deltanet::coordinator::Trainer;
use deltanet::data::build_task;
use deltanet::runtime::Runtime;
use deltanet::util::bench::bench_result;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("# Figure 4: train-step wall time per architecture");
    for preset in ["tiny", "small"] {
        for arch in ["transformer", "retnet", "mamba2", "gla", "linattn",
                     "deltanet", "hybrid_swa", "hybrid_global"] {
            let artifact = format!("{arch}_{preset}");
            if !rt.has_artifact(&format!("{artifact}.train")) {
                continue;
            }
            let mut trainer = Trainer::new(&rt, &artifact, 0)?;
            let mut task = build_task(&DataConfig::Corpus { seed: 0 });
            let tokens = trainer.batch * trainer.seq_len;
            let batch = task.sample(trainer.batch, trainer.seq_len);
            let r = bench_result(&format!("{artifact}.train_step"), 2, 8,
                                 || {
                                     trainer.train_step(&batch, 1e-4)?;
                                     Ok(())
                                 })?;
            println!("  -> {:.0} tokens/sec", tokens as f64 / r.median_s);
        }
    }
    Ok(())
}
