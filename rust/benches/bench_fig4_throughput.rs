//! Bench: Figure 4 — end-to-end training throughput (tokens/sec) per
//! architecture family.  Requires train artifacts; without them (offline
//! build) it falls back to the sequence-mixing core on the batched host
//! kernel backend, which is the arch-independent denominator of the
//! figure.  Writes `BENCH_fig4_throughput.json` at the repo root.
//!
//!     cargo bench --bench bench_fig4_throughput

use deltanet::config::DataConfig;
use deltanet::coordinator::host::{HostKernelBackend, KernelForm};
use deltanet::coordinator::Trainer;
use deltanet::data::build_task;
use deltanet::kernels::default_threads;
use deltanet::repro::fig1::host_inputs;
use deltanet::runtime::Runtime;
use deltanet::util::bench::{
    bench_result, smoke_mode, write_report, BenchResult,
};

fn main() -> deltanet::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let mut report: Vec<BenchResult> = vec![];
    let mut any_artifact = false;

    println!("# Figure 4: train-step wall time per architecture");
    // stale artifacts on disk can't execute without a real PJRT backend;
    // only enter the artifact path when one is linked in
    let presets: &[&str] =
        if Runtime::backend_available() { &["tiny", "small"] } else { &[] };
    for preset in presets {
        for arch in ["transformer", "retnet", "mamba2", "gla", "linattn",
                     "deltanet", "hybrid_swa", "hybrid_global"] {
            let artifact = format!("{arch}_{preset}");
            if !rt.has_artifact(&format!("{artifact}.train")) {
                continue;
            }
            any_artifact = true;
            let mut trainer = Trainer::new(&rt, &artifact, 0)?;
            let mut task = build_task(&DataConfig::Corpus { seed: 0 });
            let tokens = trainer.batch * trainer.seq_len;
            let batch = task.sample(trainer.batch, trainer.seq_len);
            let r = bench_result(&format!("{artifact}.train_step"), 2, 8,
                                 || {
                                     trainer.train_step(&batch, 1e-4)?;
                                     Ok(())
                                 })?;
            println!("  -> {:.0} tokens/sec", tokens as f64 / r.median_s);
            report.push(r);
        }
    }

    if !any_artifact {
        // host fallback: throughput of the chunkwise sequence-mixing core
        // (the part Fig. 4 varies by architecture) on the worker pool
        println!("  no train artifacts; benching the host kernel core");
        let threads = default_threads();
        let backend = HostKernelBackend::new(threads, 64);
        let ls: &[usize] = if smoke_mode() { &[512] } else { &[512, 2048] };
        for &l in ls {
            let (b, d) = (8usize, 64usize);
            let (q, k, v, beta) = host_inputs(b, l, d, 11);
            let r = bench_result(
                &format!("host_core_chunkwise_B{b}_L{l}_d{d}_T{threads}"),
                1, 5, || {
                    backend.run(KernelForm::Chunkwise, &q, &k, &v, &beta)?;
                    Ok(())
                })?;
            println!("  -> {:.0} tokens/sec through the mixing core",
                     (b * l) as f64 / r.median_s);
            report.push(r);
        }
    }

    let path = write_report("fig4_throughput", &report)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
