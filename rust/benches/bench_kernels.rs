//! Microbench: scalar vs SIMD GFLOP/s per tensor primitive, at the
//! chunkwise kernel's operating point (C=64, d=64..128).  Writes
//! `BENCH_kernels.json` at the repo root (archived by CI's bench-smoke
//! job), so the dispatch layer's speedup is measured per PR, not
//! asserted.
//!
//!     cargo bench --bench bench_kernels
//!     DELTANET_BENCH_SMOKE=1 cargo bench --bench bench_kernels  # CI
//!
//! Each primitive runs twice through the same `tensor::blocked` /
//! `tensor::simd` entry points: once with the dispatch level forced to
//! Scalar, once at the natively detected level (AVX2+FMA where
//! available).  Outputs of the two legs are pinned allclose(1e-4) to each
//! other before timing, and on AVX2 hosts the matmul primitives must
//! show >= 1.5x scalar GFLOP/s or the bench fails.
//!
//! Single-threaded by design: `simd::force_level` flips a process-global
//! dispatch atomic, so nothing else may run kernels concurrently.

use deltanet::tensor::rng::Rng;
use deltanet::tensor::simd::{self, Level};
use deltanet::tensor::{blocked, Mat};
use deltanet::util::bench::{bench, repo_root, smoke_mode, BenchResult};
use deltanet::util::json::Json;

/// One primitive's scalar-vs-SIMD comparison.
struct PrimResult {
    name: String,
    flops_per_call: f64,
    scalar: BenchResult,
    simd: BenchResult,
}

impl PrimResult {
    fn gflops_scalar(&self) -> f64 {
        self.flops_per_call / self.scalar.median_s / 1e9
    }

    fn gflops_simd(&self) -> f64 {
        self.flops_per_call / self.simd.median_s / 1e9
    }

    fn speedup(&self) -> f64 {
        self.scalar.median_s / self.simd.median_s
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("flops_per_call", Json::num(self.flops_per_call)),
            ("gflops_scalar", Json::num(self.gflops_scalar())),
            ("gflops_simd", Json::num(self.gflops_simd())),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

/// Time `f` once per dispatch level.  `iters` inner calls per timed rep
/// keep each rep well above timer resolution; `flops` is per inner call.
fn compare<F: FnMut()>(name: &str, native: Level, flops: f64,
                       iters: usize, reps: usize, mut f: F) -> PrimResult {
    simd::force_level(Level::Scalar);
    let scalar = bench(&format!("{name}_scalar"), 1, reps, || {
        for _ in 0..iters {
            f();
        }
    });
    simd::force_level(native);
    let simd_r = bench(&format!("{name}_{}", native.name()), 1, reps, || {
        for _ in 0..iters {
            f();
        }
    });
    PrimResult {
        name: name.to_string(),
        flops_per_call: flops,
        scalar,
        simd: simd_r,
    }
}

/// Run `f` at both levels and pin the two outputs together.
fn pin_equiv<F: FnMut() -> Mat>(name: &str, native: Level, mut f: F) {
    simd::force_level(Level::Scalar);
    let want = f();
    simd::force_level(native);
    let got = f();
    assert!(got.allclose(&want, 1e-4, 1e-4),
            "{name}: SIMD output diverged from scalar");
}

fn main() {
    let native = simd::detect_level();
    println!("# kernel primitives: scalar vs {} dispatch", native.name());
    if native == Level::Scalar {
        println!("  (no SIMD level detected or DELTANET_SIMD=off; \
                  both legs run the scalar path)");
    }
    let smoke = smoke_mode();
    let reps = if smoke { 7 } else { 21 };
    let mut rng = Rng::new(17);
    let mut prims: Vec<PrimResult> = vec![];

    // ---- vector primitives ------------------------------------------
    for n in [64usize, 128, 1024] {
        let a = Mat::random(1, n, &mut rng, 1.0);
        let b = Mat::random(1, n, &mut rng, 1.0);
        let iters = if smoke { 20_000 } else { 100_000 };
        let mut acc = 0f32;
        prims.push(compare(&format!("dot_n{n}"), native,
                           2.0 * n as f64, iters, reps, || {
            acc += simd::dot(&a.data, &b.data);
        }));
        std::hint::black_box(acc);

        let mut y = Mat::zeros(1, n);
        prims.push(compare(&format!("axpy_n{n}"), native,
                           2.0 * n as f64, iters, reps, || {
            simd::axpy(&mut y.data, 0.5, &b.data);
        }));
        std::hint::black_box(&y);
    }

    // ---- matmul microkernels at the chunk operating point ------------
    // C=64 rows; d sweeps the head dims the model actually uses.
    let c = 64usize;
    for d in [64usize, 128] {
        let a = Mat::random(c, d, &mut rng, 1.0);
        let b = Mat::random(d, d, &mut rng, 1.0);
        let bt = Mat::random(c, d, &mut rng, 1.0);
        let iters = if smoke { 50 } else { 200 };
        let mut out = Mat::zeros(c, d);

        pin_equiv("matmul_into", native, || {
            let mut o = Mat::zeros(c, d);
            blocked::matmul_into(&mut o, &a, &b, false);
            o
        });
        prims.push(compare(&format!("matmul_into_{c}x{d}x{d}"), native,
                           2.0 * (c * d * d) as f64, iters, reps, || {
            blocked::matmul_into(&mut out, &a, &b, false);
        }));
        std::hint::black_box(&out);

        pin_equiv("matmul_nt_into", native, || {
            let mut o = Mat::zeros(c, c);
            blocked::matmul_nt_into(&mut o, &a, &bt, false);
            o
        });
        let mut out_nt = Mat::zeros(c, c);
        prims.push(compare(&format!("matmul_nt_into_{c}x{d}x{c}"), native,
                           2.0 * (c * d * c) as f64, iters, reps, || {
            blocked::matmul_nt_into(&mut out_nt, &a, &bt, false);
        }));
        std::hint::black_box(&out_nt);

        pin_equiv("matmul_tn_acc", native, || {
            let mut o = Mat::zeros(d, d);
            blocked::matmul_tn_acc(&mut o, &a, &bt);
            o
        });
        let mut out_tn = Mat::zeros(d, d);
        prims.push(compare(&format!("matmul_tn_acc_{d}x{c}x{d}"), native,
                           2.0 * (c * d * d) as f64, iters, reps, || {
            out_tn.reset(d, d);
            blocked::matmul_tn_acc(&mut out_tn, &a, &bt);
        }));
        std::hint::black_box(&out_tn);
    }
    simd::force_level(native);

    // ---- report ------------------------------------------------------
    println!("\n{:<28} {:>12} {:>12} {:>9}", "primitive", "scalar GF/s",
             "simd GF/s", "speedup");
    for p in &prims {
        println!("{:<28} {:>12.2} {:>12.2} {:>8.2}x", p.name,
                 p.gflops_scalar(), p.gflops_simd(), p.speedup());
    }

    let mut results: Vec<Json> = vec![];
    for p in &prims {
        results.push(p.scalar.to_json());
        results.push(p.simd.to_json());
    }
    let json = Json::obj(vec![
        ("suite", Json::str("kernels")),
        ("simd_level", Json::str(native.name())),
        ("primitives",
         Json::Arr(prims.iter().map(PrimResult::to_json).collect())),
        ("results", Json::Arr(results)),
    ]);
    let path = repo_root().join("BENCH_kernels.json");
    std::fs::write(&path, json.render() + "\n").expect("write report");
    println!("\nwrote {}", path.display());

    // The PR's acceptance bar: on AVX2 hosts the matmul entry points must
    // beat scalar by >= 1.5x at the chunk operating point.
    if native == Level::Avx2 {
        for p in &prims {
            if p.name.starts_with("matmul_into")
                || p.name.starts_with("matmul_nt_into")
            {
                assert!(p.speedup() >= 1.5,
                        "{}: SIMD speedup {:.2}x below the 1.5x bar",
                        p.name, p.speedup());
            }
        }
        println!("matmul SIMD speedups clear the 1.5x bar");
    }
}
