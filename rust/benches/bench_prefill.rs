//! Prefill thread-scaling bench: B=1 sequence-parallel chunkwise forward.
//!
//!     cargo bench --bench bench_prefill
//!     DELTANET_BENCH_SMOKE=1 cargo bench --bench bench_prefill  # CI
//!
//! The three-phase DAG decomposition schedules one task per
//! (batch, head, chunk) triple, so a SINGLE sequence (B=1) fans out
//! across the whole pool — the per-problem loop it replaced could use at
//! most B×H threads and left a lone long prompt single-threaded per
//! head.  This bench pins that down: H ∈ {1, 4}, L ∈ {512, 2048},
//! threads ∈ {1, 2, 4, 8} at the d=64, C=64 operating point, reporting
//! tokens/s and the parallel speedup of every config relative to its own
//! single-thread leg.
//!
//! Writes `BENCH_prefill.json` at the repo root (archived by CI's
//! bench-smoke job and compared against the committed baseline by
//! `deltanet bench-diff`).  On hosts with >= 8 cores the full run
//! asserts the headline config (H=4, L=2048) reaches >= 2x throughput at
//! 8 threads over 1 — the PR's acceptance bar.

use deltanet::kernels::{default_threads, forward_batched_on, HeadProblem};
use deltanet::reference::random_problem;
use deltanet::util::bench::{bench, repo_root, smoke_mode, BenchResult};
use deltanet::util::json::Json;
use deltanet::util::threadpool::ThreadPool;

const DIM: usize = 64;
const CHUNK: usize = 64;

fn problems(heads: usize, l: usize) -> Vec<HeadProblem> {
    (0..heads)
        .map(|h| {
            let (q, k, v, beta) = random_problem(l, DIM, DIM, 40 + h as u64);
            HeadProblem::new(q, k, v, beta)
        })
        .collect()
}

fn main() {
    let smoke = smoke_mode();
    let (warmup, reps) = if smoke { (1, 3) } else { (2, 7) };
    let avail = default_threads();
    println!("# B=1 prefill scaling: d={DIM} C={CHUNK} \
              ({avail} hardware threads){}",
             if smoke { " [smoke]" } else { "" });

    let mut results: Vec<BenchResult> = vec![];
    let mut speedups: Vec<(String, Json)> = vec![];
    let mut tokens_per_sec = 0f64;

    for heads in [1usize, 4] {
        for l in [512usize, 2048] {
            let ps = problems(heads, l);
            let mut t1_median = 0f64;
            for threads in [1usize, 2, 4, 8] {
                let pool = ThreadPool::new(threads);
                let name = format!("prefill_h{heads}_l{l}_t{threads}");
                let r = bench(&name, warmup, reps, || {
                    std::hint::black_box(
                        forward_batched_on(&pool, &ps, CHUNK));
                });
                if threads == 1 {
                    t1_median = r.median_s;
                }
                let speedup = t1_median / r.median_s;
                speedups.push((name.clone(), Json::num(speedup)));
                // headline throughput: the big multi-head config's best leg
                if heads == 4 && l == 2048 {
                    tokens_per_sec =
                        tokens_per_sec.max(l as f64 / r.median_s);
                }
                results.push(r);
            }
        }
    }

    println!("\n{:<24} {:>9}", "config", "speedup");
    for (name, s) in &speedups {
        println!("{:<24} {:>8.2}x", name,
                 s.as_f64().expect("speedup is numeric"));
    }
    println!("headline tokens/s (h4, l2048): {tokens_per_sec:.0}");

    let json = Json::obj(vec![
        ("suite", Json::str("prefill")),
        ("threads_available", Json::num(avail as f64)),
        ("tokens_per_sec", Json::num(tokens_per_sec)),
        ("speedups",
         Json::obj(speedups.iter()
             .map(|(n, s)| (n.as_str(), s.clone())).collect())),
        ("results",
         Json::Arr(results.iter().map(BenchResult::to_json).collect())),
    ]);
    let path = repo_root().join("BENCH_prefill.json");
    std::fs::write(&path, json.render() + "\n").expect("write report");
    println!("wrote {}", path.display());

    // Acceptance bar: >= 2x at 8 threads over 1 on the headline config.
    // Only meaningful on hosts that actually have 8 cores, and smoke reps
    // are too few to trust — CI's smoke leg records, the full run gates.
    if !smoke && avail >= 8 {
        let s = speedups.iter()
            .find(|(n, _)| n == "prefill_h4_l2048_t8")
            .and_then(|(_, v)| v.as_f64().ok())
            .expect("headline speedup present");
        assert!(s >= 2.0,
                "prefill_h4_l2048_t8 speedup {s:.2}x below the 2x bar");
        println!("8-thread prefill speedup {s:.2}x clears the 2x bar");
    }
}
