//! Bench: the pure-Rust reference implementation — host-side profile of the
//! recurrent vs chunkwise work (the Fig-1 story independent of XLA), plus
//! the UT-transform cost.  `cargo bench --bench bench_reference`

use deltanet::reference::{delta_chunkwise, delta_recurrent, random_problem,
                          ut_transform};
use deltanet::util::bench::bench;

fn main() {
    println!("# host reference: recurrent vs chunkwise");
    for (l, d) in [(256, 32), (1024, 64), (4096, 64)] {
        let (q, k, v, beta) = random_problem(l, d, d, 1);
        let r = bench(&format!("host_recurrent_L{l}_d{d}"), 1, 5, || {
            std::hint::black_box(delta_recurrent(&q, &k, &v, &beta, None));
        });
        let c = bench(&format!("host_chunkwise_L{l}_d{d}_C64"), 1, 5, || {
            std::hint::black_box(delta_chunkwise(&q, &k, &v, &beta, 64,
                                                 None));
        });
        println!("  host speedup L={l} d={d}: {:.2}x",
                 r.median_s / c.median_s);
    }

    println!("\n# UT transform (per chunk)");
    for c in [16, 64, 128] {
        let (_, k, v, beta) = random_problem(c, 64, 64, 2);
        bench(&format!("ut_transform_C{c}_d64"), 2, 20, || {
            std::hint::black_box(ut_transform(&k, &v, &beta));
        });
    }

    // §Perf: host→literal path comparison (the to_literal change) — build
    // a 30M-element tensor the two ways the runtime could
    println!("\n# literal creation path (30M f32 ≈ e2e param volume)");
    let data = vec![0.5f32; 30_000_000];
    let one_copy = bench("literal_create_from_untyped (1 copy)", 1, 5, || {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        };
        std::hint::black_box(
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32, &[30_000_000], bytes).unwrap());
    });
    let two_copy = bench("literal_vec1_reshape      (2 copies)", 1, 5, || {
        std::hint::black_box(
            xla::Literal::vec1(&data).reshape(&[30_000_000]).unwrap());
    });
    println!("  -> to_literal single-copy path: {:.2}x faster",
             two_copy.median_s / one_copy.median_s);

    // §Perf: eval arg-construction — clone-per-batch vs clone-once
    println!("\n# eval arg construction (113k params, 8 batches)");
    let params: Vec<xla::Literal> = (0..32)
        .map(|_| xla::Literal::vec1(&vec![0.1f32; 3536]))
        .collect();
    let per_batch = bench("clone params per batch (x8)", 1, 10, || {
        for _ in 0..8 {
            let args: Vec<xla::Literal> =
                params.iter().map(|p| p.clone()).collect();
            std::hint::black_box(args);
        }
    });
    let once = bench("clone params once", 1, 10, || {
        let args: Vec<xla::Literal> =
            params.iter().map(|p| p.clone()).collect();
        std::hint::black_box(args);
    });
    println!("  -> hoisting clones out of the batch loop: {:.2}x less \
              arg-construction work", per_batch.median_s / once.median_s);
}
