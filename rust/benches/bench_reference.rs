//! Bench: the host kernel layer — parallel blocked chunkwise vs the
//! scalar recurrent/chunkwise reference paths, the UT-transform cost, and
//! the literal-creation perf notes.  Writes `BENCH_reference.json` at the
//! repo root (archived by the CI bench-smoke job; per-primitive
//! scalar-vs-SIMD numbers live in `bench_kernels` / `BENCH_kernels.json`).
//!
//!     cargo bench --bench bench_reference
//!     DELTANET_BENCH_SMOKE=1 cargo bench --bench bench_reference  # CI
//!
//! Headline claim tracked per PR: the parallel blocked chunkwise kernel at
//! L=4096, d=64, B·H=8 on 8 threads vs token-by-token `delta_recurrent`,
//! with outputs pinned to the scalar oracle at 1e-4.

use deltanet::kernels::{forward_batched_on, HeadProblem};
use deltanet::reference::{
    delta_chunkwise, delta_chunkwise_scalar, delta_recurrent,
    random_problem, ut_transform,
};
use deltanet::util::bench::{bench, smoke_mode, write_report, BenchResult};
use deltanet::util::threadpool::ThreadPool;

fn main() {
    let smoke = smoke_mode();
    let mut report: Vec<BenchResult> = vec![];

    // ---- single-sequence: recurrent vs scalar chunkwise vs blocked ----
    println!("# host single-sequence: recurrent vs chunkwise (C=64)");
    let single_cases: &[(usize, usize)] =
        if smoke { &[(256, 32), (1024, 64)] }
        else { &[(256, 32), (1024, 64), (4096, 64)] };
    for &(l, d) in single_cases {
        let (q, k, v, beta) = random_problem(l, d, d, 1);
        let r = bench(&format!("host_recurrent_L{l}_d{d}"), 1, 5, || {
            std::hint::black_box(delta_recurrent(&q, &k, &v, &beta, None));
        });
        let cs = bench(&format!("host_chunkwise_scalar_L{l}_d{d}_C64"), 1, 5,
                       || {
            std::hint::black_box(delta_chunkwise_scalar(&q, &k, &v, &beta,
                                                        64, None));
        });
        let cb = bench(&format!("kernel_chunkwise_blocked_L{l}_d{d}_C64"), 1,
                       5, || {
            std::hint::black_box(delta_chunkwise(&q, &k, &v, &beta, 64,
                                                 None));
        });
        println!("  blocked vs recurrent L={l} d={d}: {:.2}x  \
                  (vs scalar chunkwise: {:.2}x)",
                 r.median_s / cb.median_s, cs.median_s / cb.median_s);
        report.extend([r, cs, cb]);
    }

    // ---- headline: batched multi-head fan-out on the worker pool ------
    let (l, d, bh, threads) =
        if smoke { (512, 64, 8, 4) } else { (4096, 64, 8, 8) };
    println!("\n# batched multi-head: B·H={bh} problems, L={l}, d={d}, \
              {threads} threads");
    let problems: Vec<HeadProblem> = (0..bh)
        .map(|i| {
            let (q, k, v, beta) = random_problem(l, d, d, 40 + i as u64);
            HeadProblem::new(q, k, v, beta)
        })
        .collect();
    let pool = ThreadPool::new(threads);
    let rec = bench(&format!("batched_recurrent_BH{bh}_L{l}_d{d}"), 1, 5,
                    || {
        for p in &problems {
            std::hint::black_box(delta_recurrent(&p.q, &p.k, &p.v, &p.beta,
                                                 None));
        }
    });
    let par = bench(
        &format!("kernels_parallel_chunkwise_BH{bh}_L{l}_d{d}_T{threads}"),
        1, 5, || {
            std::hint::black_box(forward_batched_on(&pool, &problems, 64));
        });
    let speedup = rec.median_s / par.median_s;
    println!("  -> parallel blocked chunkwise speedup over \
              delta_recurrent: {speedup:.2}x");
    report.extend([rec, par]);

    // numerics: the fast path must match the scalar oracle
    let outs = forward_batched_on(&pool, &problems, 64);
    let mut worst = 0f32;
    for (p, f) in problems.iter().zip(&outs) {
        let want = delta_recurrent(&p.q, &p.k, &p.v, &p.beta, None);
        assert!(f.o.allclose(&want.o, 1e-4, 1e-4),
                "parallel kernel diverged from the scalar oracle");
        assert!(f.state.allclose(&want.state, 1e-4, 1e-4),
                "parallel kernel state diverged from the scalar oracle");
        for (a, b) in f.o.data.iter().zip(&want.o.data) {
            worst = worst.max((a - b).abs());
        }
    }
    println!("  numerics OK: max |Δ| vs oracle = {worst:.2e} \
              (tolerance 1e-4)");

    // ---- UT transform (per chunk) -------------------------------------
    println!("\n# UT transform (per chunk)");
    for c in [16, 64, 128] {
        let (_, k, v, beta) = random_problem(c, 64, 64, 2);
        report.push(bench(&format!("ut_transform_C{c}_d64"), 2, 20, || {
            std::hint::black_box(ut_transform(&k, &v, &beta));
        }));
    }

    // §Perf: host→literal path comparison (the to_literal change) — build
    // a large tensor the two ways the runtime could
    let n_lit = if smoke { 3_000_000 } else { 30_000_000 };
    println!("\n# literal creation path ({n_lit} f32)");
    let data = vec![0.5f32; n_lit];
    let one_copy = bench("literal_create_from_untyped (1 copy)", 1, 5, || {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        };
        std::hint::black_box(
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32, &[n_lit], bytes).unwrap());
    });
    let two_copy = bench("literal_vec1_reshape      (2 copies)", 1, 5, || {
        std::hint::black_box(
            xla::Literal::vec1(&data).reshape(&[n_lit as i64]).unwrap());
    });
    println!("  -> to_literal single-copy path: {:.2}x faster",
             two_copy.median_s / one_copy.median_s);
    report.extend([one_copy, two_copy]);

    match write_report("reference", &report) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench report: {e}"),
    }
}
