//! Experiment harnesses: one per table/figure of the paper's evaluation.
//!
//! | harness | paper artifact | what it prints |
//! |---------|----------------|----------------|
//! | fig1    | Figure 1       | chunkwise vs recurrent kernel speedup grid |
//! | fig2    | Figure 2       | MQAR accuracy across kv-pairs × archs |
//! | tab1    | Table 1        | MAD: 6 synthetic tasks × archs |
//! | fig3    | Figure 3       | RegBench in-context learning accuracy |
//! | tab2    | Table 2        | LM ppl + recall-intensive task accuracy |
//! | tab3    | Table 3        | zero-shot suite, 3 model families |
//! | fig4    | Figure 4       | training throughput vs seq-len × archs |
//! | ablate  | Table 2 (btm)  | feature-map / key-norm ablations |
//!
//! Numbers are produced on this testbed (CPU PJRT, tiny presets): the
//! reproduction target is the *shape* — orderings, crossovers, rough
//! factors — not the paper's absolute values (see DESIGN.md §Substitutions).

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod tab1;
pub mod tab2;
pub mod tab3;

use crate::config::{DataConfig, LrSchedule, RunConfig};
use crate::coordinator::{EvalOutcome, Trainer};
use crate::data::batcher::Split;
use crate::runtime::Runtime;

/// Options shared by all harnesses.
#[derive(Debug, Clone)]
pub struct ReproOpts {
    pub steps: usize,
    pub seed: u64,
    pub eval_batches: usize,
    /// peak LR for the cosine schedule — tiny models train best around
    /// 1e-3 (the paper's 3e-4 is tuned for 340M+)
    pub lr_peak: f64,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts { steps: 300, seed: 0, eval_batches: 8, lr_peak: 1e-3 }
    }
}

impl ReproOpts {
    pub fn schedule(&self) -> LrSchedule {
        LrSchedule::Cosine {
            peak: self.lr_peak,
            floor: self.lr_peak / 10.0,
            warmup_steps: (self.steps / 30).max(1),
            total_steps: self.steps,
        }
    }
}

/// Train `artifact` on `data` for `opts.steps` and return the final eval.
/// The generic cell used by every accuracy table.
pub fn train_cell(runtime: &Runtime, artifact: &str, data: DataConfig,
                  opts: &ReproOpts) -> crate::Result<(EvalOutcome, f64)> {
    let mut trainer = Trainer::new(runtime, artifact, opts.seed)?;
    let split = Split::from_config(&data);
    let mut train_task = split.train;
    let mut eval_task = split.eval;
    let cfg = RunConfig {
        artifact: artifact.to_string(),
        artifacts_dir: runtime.artifacts_dir().to_path_buf(),
        steps: opts.steps,
        seed: opts.seed,
        lr: opts.schedule(),
        data,
        eval_every: 0,
        eval_batches: opts.eval_batches,
        log_path: None,
        checkpoint_path: None,
    };
    let report = trainer.train(&cfg, train_task.as_mut(),
                               Some(eval_task.as_mut()))?;
    let (_, outcome) = *report.evals.last()
        .ok_or_else(|| crate::err!("no eval"))?;
    Ok((outcome, report.tokens_per_sec))
}

/// Archs × artifact-name helper: which tiny artifacts exist for a family.
pub fn tiny_artifact(arch: &str) -> String {
    format!("{arch}_tiny")
}

/// Run a named harness.
pub fn run(runtime: &Runtime, which: &str, opts: &ReproOpts) -> crate::Result<()> {
    match which {
        "fig1" => fig1::run(runtime, opts),
        "fig2" => fig2::run(runtime, opts),
        "fig3" => fig3::run(runtime, opts),
        "fig4" => fig4::run(runtime, opts),
        "tab1" => tab1::run(runtime, opts),
        "tab2" => tab2::run(runtime, opts),
        "tab3" => tab3::run(runtime, opts),
        "ablate" => tab2::run_ablations(runtime, opts),
        "all" => {
            for w in ["fig1", "fig2", "tab1", "fig3", "tab2", "tab3",
                      "fig4", "ablate"] {
                run(runtime, w, opts)?;
            }
            Ok(())
        }
        other => crate::bail!("unknown experiment {other:?} \
            (fig1|fig2|fig3|fig4|tab1|tab2|tab3|ablate|all)"),
    }
}
