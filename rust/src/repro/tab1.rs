//! Table 1 — the MAD benchmark: six synthetic token-manipulation probes.
//!
//! Expected shape (paper): DeltaNet at/near 100% on the recall family
//! (in-context, noisy, fuzzy) and selective copy; weakest on memorize.

use crate::config::DataConfig;
use crate::data::mad::ALL_TASKS;
use crate::eval::{pct, Table};
use crate::runtime::Runtime;

use super::{tiny_artifact, train_cell, ReproOpts};

pub const ARCHS: [&str; 5] = ["transformer", "mamba2", "gla", "linattn",
                              "deltanet"];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut headers: Vec<&str> = vec!["model"];
    headers.extend(ALL_TASKS);
    headers.push("average");
    let mut table = Table::new(
        &format!("Table 1: MAD benchmark accuracy (%) after {} steps",
                 opts.steps),
        &headers);

    for arch in ARCHS {
        let mut cells = vec![arch.to_string()];
        let mut sum = 0.0;
        for task in ALL_TASKS {
            let (outcome, _) = train_cell(
                runtime,
                &tiny_artifact(arch),
                DataConfig::Mad { task: task.to_string(), seed: opts.seed },
                opts)?;
            sum += outcome.accuracy;
            cells.push(pct(outcome.accuracy));
        }
        cells.push(pct(sum / ALL_TASKS.len() as f64));
        table.row(cells);
    }
    table.print();
    Ok(())
}
