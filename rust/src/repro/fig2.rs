//! Figure 2 — MQAR (multi-query associative recall) accuracy.
//!
//! The paper's grid: model dim × kv-pairs, DeltaNet vs Mamba vs others.
//! Here: kv-pairs ∈ {4, 8, 16} × the four architecture families with tiny
//! artifacts.  Expected shape: DeltaNet ≈ attention ≫ decay-based linear
//! models as the number of pairs approaches state capacity.

use crate::config::DataConfig;
use crate::eval::{pct, Table};
use crate::runtime::Runtime;

use super::{tiny_artifact, train_cell, ReproOpts};

pub const ARCHS: [&str; 4] = ["deltanet", "gla", "mamba2", "transformer"];
pub const PAIRS: [usize; 3] = [4, 8, 16];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        &format!("Figure 2: MQAR accuracy (%) after {} steps", opts.steps),
        &["model", "4 pairs", "8 pairs", "16 pairs"]);

    for arch in ARCHS {
        let mut cells = vec![arch.to_string()];
        for pairs in PAIRS {
            let (outcome, _) = train_cell(
                runtime,
                &tiny_artifact(arch),
                DataConfig::Mqar { num_pairs: pairs, seed: opts.seed },
                opts)?;
            cells.push(pct(outcome.accuracy));
        }
        table.row(cells);
    }
    table.print();
    Ok(())
}
