//! Figure 1 — speed-up of the chunkwise-parallel form over the recurrent
//! form, across sequence length L and head dimension d (B·L fixed at 4096
//! tokens, as the paper fixes batch×length).
//!
//! Both forms were AOT-lowered from the same Pallas kernels and run through
//! the same PJRT pipeline, so the comparison isolates exactly what the
//! paper isolates: O(L) sequential rank-1 steps vs O(L/C) matmul-dense
//! steps.  The expected *shape*: speedup grows with L and with d.
//!
//! The harness picks ONE backend up front via
//! `coordinator::select_kernel_backend` — the PJRT artifact path when a
//! real plugin is linked in, the batched host kernel backend otherwise —
//! and every cell times the same `Backend::run_with_chunk` call.  A cell
//! whose artifact is missing prints "-" rather than silently switching
//! backends mid-table.

use std::time::Instant;

use crate::coordinator::{select_kernel_backend, Backend, KernelForm};
use crate::eval::Table;
use crate::runtime::{HostValue, Runtime};
use crate::tensor::rng::Rng;

use super::ReproOpts;

const LS: [usize; 5] = [256, 512, 1024, 2048, 4096];
const DS: [usize; 2] = [32, 64];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        "Figure 1: chunkwise-parallel vs recurrent DeltaNet forward \
         (B·L = 4096 tokens, C = 64)",
        &["L", "d_head", "backend", "recurrent_ms", "chunkwise_ms",
          "speedup"]);

    let backend = select_kernel_backend(runtime.artifacts_dir(), 64)?;

    for &d in &DS {
        for &l in &LS {
            let b = 4096 / l;
            let pair = time_backend(backend.as_ref(), KernelForm::Recurrent,
                                    l, d, 64, b, opts)
                .and_then(|rec| {
                    let chk = time_backend(backend.as_ref(),
                                           KernelForm::Chunkwise,
                                           l, d, 64, b, opts)?;
                    Ok((rec, chk))
                });
            let (rec_s, chk_s, speedup_s) = match pair {
                Ok((rec, chk)) => (format!("{:.1}", rec * 1e3),
                                   format!("{:.1}", chk * 1e3),
                                   format!("{:.1}x", rec / chk)),
                // missing artifact for this cell — leave the hole visible
                Err(_) => ("-".into(), "-".into(), "-".into()),
            };
            table.row(vec![
                l.to_string(),
                d.to_string(),
                backend.name().to_string(),
                rec_s,
                chk_s,
                speedup_s,
            ]);
        }
    }
    table.print();
    Ok(())
}

/// Median-of-N wall time (seconds) for one batched kernel execution on any
/// [`Backend`] — the single timing path for both PJRT and host cells.
pub fn time_backend(backend: &dyn Backend, form: KernelForm, l: usize,
                    d: usize, c: usize, b: usize, opts: &ReproOpts)
                    -> crate::Result<f64> {
    let (q, k, v, beta) = host_inputs(b, l, d, opts.seed);
    // warmup (loads + caches the artifact on the PJRT path)
    backend.run_with_chunk(form, c, &q, &k, &v, &beta)?;
    let reps = 5usize;
    let mut times: Vec<f64> = (0..reps)
        .map(|_| -> crate::Result<f64> {
            let t0 = Instant::now();
            backend.run_with_chunk(form, c, &q, &k, &v, &beta)?;
            Ok(t0.elapsed().as_secs_f64())
        })
        .collect::<crate::Result<_>>()?;
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[reps / 2])
}

/// Random [B,L,D] q/k/v + [B,L] β in the kernel-artifact layout.
pub fn host_inputs(b: usize, l: usize, d: usize, seed: u64)
                   -> (HostValue, HostValue, HostValue, HostValue) {
    let mut rng = Rng::new(seed);
    let mut tensor = |shape: &[usize]| -> HostValue {
        let n: usize = shape.iter().product();
        HostValue::from_f32(shape, (0..n).map(|_| rng.normal()).collect())
            .expect("shape/data agree by construction")
    };
    let q = tensor(&[b, l, d]);
    let k = tensor(&[b, l, d]);
    let v = tensor(&[b, l, d]);
    let beta = HostValue::from_f32(
        &[b, l],
        (0..b * l).map(|_| 1.0 / (1.0 + (-rng.normal()).exp())).collect())
        .expect("shape/data agree by construction");
    (q, k, v, beta)
}

/// Chunk-size sweep used by the perf study (EXPERIMENTS.md §Perf), on the
/// same backend selection as the main harness.
pub fn chunk_sweep(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        "Chunk-size ablation: chunkwise kernel, L=1024, d=64, B=4",
        &["C", "ms", "vs C=64"]);
    let backend = select_kernel_backend(runtime.artifacts_dir(), 64)?;
    let time = |c: usize| -> crate::Result<f64> {
        time_backend(backend.as_ref(), KernelForm::Chunkwise, 1024, 64, c,
                     4, opts)
    };
    let base = time(64)?;
    for c in [16, 32, 64, 128] {
        let t = time(c)?;
        table.row(vec![
            c.to_string(),
            format!("{:.1}", t * 1e3),
            format!("{:.2}x", t / base),
        ]);
    }
    table.print();
    Ok(())
}
