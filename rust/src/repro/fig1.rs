//! Figure 1 — speed-up of the chunkwise-parallel form over the recurrent
//! form, across sequence length L and head dimension d (B·L fixed at 4096
//! tokens, as the paper fixes batch×length).
//!
//! Both forms were AOT-lowered from the same Pallas kernels and run through
//! the same PJRT pipeline, so the comparison isolates exactly what the
//! paper isolates: O(L) sequential rank-1 steps vs O(L/C) matmul-dense
//! steps.  The expected *shape*: speedup grows with L and with d.
//!
//! When the kernel artifacts (or the PJRT backend) are unavailable, the
//! harness falls back to the batched host kernel backend
//! (`coordinator::host`), which runs the same two forms multi-threaded on
//! the CPU — the comparison's shape survives the substitution.

use std::time::Instant;

use crate::coordinator::host::{HostKernelBackend, KernelForm};
use crate::eval::Table;
use crate::kernels::default_threads;
use crate::runtime::{HostValue, Runtime};
use crate::tensor::rng::Rng;

use super::ReproOpts;

const LS: [usize; 5] = [256, 512, 1024, 2048, 4096];
const DS: [usize; 2] = [32, 64];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        "Figure 1: chunkwise-parallel vs recurrent DeltaNet forward \
         (B·L = 4096 tokens, C = 64)",
        &["L", "d_head", "backend", "recurrent_ms", "chunkwise_ms",
          "speedup"]);

    // one pool for every host-fallback measurement in the table
    let host = HostKernelBackend::new(default_threads(), 64);

    for &d in &DS {
        for &l in &LS {
            let b = 4096 / l;
            let artifact = time_kernel_pair(runtime, l, d, b, opts);
            let ((rec, chk), backend) = match artifact {
                Ok(pair) => (pair, "pjrt"),
                Err(_) => (
                    (time_host(&host, KernelForm::Recurrent, l, d, 64, b,
                               opts)?,
                     time_host(&host, KernelForm::Chunkwise, l, d, 64, b,
                               opts)?),
                    "host",
                ),
            };
            table.row(vec![
                l.to_string(),
                d.to_string(),
                backend.to_string(),
                format!("{:.1}", rec * 1e3),
                format!("{:.1}", chk * 1e3),
                format!("{:.1}x", rec / chk),
            ]);
        }
    }
    table.print();
    Ok(())
}

/// Both forms through the artifact path, failing if either is unavailable.
fn time_kernel_pair(runtime: &Runtime, l: usize, d: usize, b: usize,
                    opts: &ReproOpts) -> crate::Result<(f64, f64)> {
    let rec = time_kernel(runtime, "recurrent", l, d, 64, b, opts)?;
    let chk = time_kernel(runtime, "chunkwise", l, d, 64, b, opts)?;
    Ok((rec, chk))
}

/// Median-of-N wall time for one kernel artifact execution (seconds).
pub fn time_kernel(runtime: &Runtime, form: &str, l: usize, d: usize,
                   c: usize, b: usize, opts: &ReproOpts)
                   -> crate::Result<f64> {
    let name = format!("kernel_{form}_L{l}_d{d}_C{c}_B{b}");
    let exe = runtime.load(&name)?;
    let mut rng = Rng::new(opts.seed);
    let mk = |rng: &mut Rng, shape: &[usize]| -> crate::Result<xla::Literal> {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        HostValue::from_f32(shape, data)?.to_literal()
    };
    let args = vec![
        mk(&mut rng, &[b, l, d])?,
        mk(&mut rng, &[b, l, d])?,
        mk(&mut rng, &[b, l, d])?,
        // β in (0,1)
        {
            let data: Vec<f32> = (0..b * l)
                .map(|_| 1.0 / (1.0 + (-rng.normal()).exp()))
                .collect();
            HostValue::from_f32(&[b, l], data)?.to_literal()?
        },
    ];
    // warmup
    exe.execute(&args)?;
    let reps = 5usize;
    let mut times: Vec<f64> = (0..reps)
        .map(|_| -> crate::Result<f64> {
            let t0 = Instant::now();
            exe.execute(&args)?;
            Ok(t0.elapsed().as_secs_f64())
        })
        .collect::<crate::Result<_>>()?;
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[reps / 2])
}

/// Median-of-N wall time for the host kernel backend on the same problem
/// (seconds).  The backend (and its worker pool) is shared across calls.
pub fn time_host(backend: &HostKernelBackend, form: KernelForm, l: usize,
                 d: usize, c: usize, b: usize, opts: &ReproOpts)
                 -> crate::Result<f64> {
    let (q, k, v, beta) = host_inputs(b, l, d, opts.seed);
    // warmup
    backend.run_with_chunk(form, c, &q, &k, &v, &beta)?;
    let reps = 5usize;
    let mut times: Vec<f64> = (0..reps)
        .map(|_| -> crate::Result<f64> {
            let t0 = Instant::now();
            backend.run_with_chunk(form, c, &q, &k, &v, &beta)?;
            Ok(t0.elapsed().as_secs_f64())
        })
        .collect::<crate::Result<_>>()?;
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[reps / 2])
}

/// Random [B,L,D] q/k/v + [B,L] β in the kernel-artifact layout.
pub fn host_inputs(b: usize, l: usize, d: usize, seed: u64)
                   -> (HostValue, HostValue, HostValue, HostValue) {
    let mut rng = Rng::new(seed);
    let mut tensor = |shape: &[usize]| -> HostValue {
        let n: usize = shape.iter().product();
        HostValue::from_f32(shape, (0..n).map(|_| rng.normal()).collect())
            .expect("shape/data agree by construction")
    };
    let q = tensor(&[b, l, d]);
    let k = tensor(&[b, l, d]);
    let v = tensor(&[b, l, d]);
    let beta = HostValue::from_f32(
        &[b, l],
        (0..b * l).map(|_| 1.0 / (1.0 + (-rng.normal()).exp())).collect())
        .expect("shape/data agree by construction");
    (q, k, v, beta)
}

/// Chunk-size sweep used by the perf study (EXPERIMENTS.md §Perf), with
/// the same host fallback as the main harness.
pub fn chunk_sweep(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        "Chunk-size ablation: chunkwise kernel, L=1024, d=64, B=4",
        &["C", "ms", "vs C=64"]);
    let host = HostKernelBackend::new(default_threads(), 64);
    let time = |c: usize| -> crate::Result<f64> {
        time_kernel(runtime, "chunkwise", 1024, 64, c, 4, opts).or_else(
            |_| time_host(&host, KernelForm::Chunkwise, 1024, 64, c, 4,
                          opts))
    };
    let base = time(64)?;
    for c in [16, 32, 64, 128] {
        let t = time(c)?;
        table.row(vec![
            c.to_string(),
            format!("{:.1}", t * 1e3),
            format!("{:.2}x", t / base),
        ]);
    }
    table.print();
    Ok(())
}
