//! Table 2 — the main language-modeling table: perplexity on the LM corpus
//! plus the recall-intensive suite (SWDE / SQuAD / FDA analogs), across all
//! architecture families and the two hybrids; plus the feature-map /
//! key-norm ablation rows (paper Table 2, bottom block).
//!
//! Expected shape: all models reach similar ppl on the corpus (the paper's
//! Wiki ppl gaps are small), while recall columns separate the families —
//! DeltaNet > GLA/Mamba on recall, hybrids on top.

use crate::config::DataConfig;
use crate::eval::{f2, pct, Table};
use crate::runtime::Runtime;
use crate::util::error::Context;

use super::{tiny_artifact, train_cell, ReproOpts};

pub const ARCHS: [&str; 8] = [
    "transformer", "retnet", "mamba2", "gla", "linattn", "deltanet",
    "hybrid_swa", "hybrid_global",
];

pub const RECALL_STYLES: [&str; 3] = ["swde", "squad", "fda"];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        &format!("Table 2: LM perplexity + recall-intensive accuracy (%) \
                  after {} steps/task", opts.steps),
        &["model", "corpus ppl", "swde", "squad", "fda", "recall avg"]);

    for arch in ARCHS {
        table.row(model_row(runtime, &tiny_artifact(arch), arch, opts)?);
    }
    table.print();
    Ok(())
}

/// One table row: ppl on the corpus + accuracy per recall style.
pub fn model_row(runtime: &Runtime, artifact: &str, label: &str,
                 opts: &ReproOpts) -> crate::Result<Vec<String>> {
    let (lm, _) = train_cell(
        runtime, artifact,
        DataConfig::Corpus { seed: opts.seed }, opts)?;
    let mut cells = vec![label.to_string(), f2(lm.ppl)];
    let mut sum = 0.0;
    for style in RECALL_STYLES {
        let (outcome, _) = train_cell(
            runtime, artifact,
            DataConfig::Recall { style: style.to_string(), seed: opts.seed },
            opts)?;
        sum += outcome.accuracy;
        cells.push(pct(outcome.accuracy));
    }
    cells.push(pct(sum / RECALL_STYLES.len() as f64));
    Ok(cells)
}

/// Paper Table 2 bottom block: DeltaNet feature-map / key-norm ablations.
pub fn run_ablations(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        &format!("Table 2 (bottom): DeltaNet ablations after {} steps",
                 opts.steps),
        &["variant", "corpus ppl", "swde", "squad", "fda", "recall avg"]);

    // (artifact, label); the default row is the standard deltanet artifact
    let variants = [
        ("deltanet_tiny".to_string(), "silu + L2 (default)"),
        ("deltanet_abl_silu_l1_tiny".to_string(), "silu + L1"),
        ("deltanet_abl_elu1_l2_tiny".to_string(), "1+elu + L2"),
        ("deltanet_abl_elu1_l1_tiny".to_string(), "1+elu + L1"),
        ("deltanet_abl_relu_l2_tiny".to_string(), "relu + L2"),
    ];
    for (artifact, label) in variants {
        if !runtime.has_artifact(&format!("{artifact}.train")) {
            eprintln!("(skipping {label}: artifact {artifact} not built)");
            continue;
        }
        table.row(ablation_row(runtime, &artifact, label, opts)?);
    }
    table.print();
    Ok(())
}

/// Ablation artifacts have no .eval twin; train on the corpus and report
/// the training-loss-derived ppl plus recall-task accuracy measured by
/// training loss proxy.  For artifacts with an eval twin, defer to
/// model_row.
fn ablation_row(runtime: &Runtime, artifact: &str, label: &str,
                opts: &ReproOpts) -> crate::Result<Vec<String>> {
    if runtime.has_artifact(&format!("{artifact}.eval")) {
        return model_row(runtime, artifact, label, opts);
    }
    use crate::config::{LrSchedule, RunConfig};
    use crate::coordinator::Trainer;
    use crate::data::build_task;

    let mut cells = vec![label.to_string()];
    // corpus ppl from final training loss (fresh stream each batch ⇒ an
    // honest held-out estimate for ablation ranking)
    let mut sums = vec![];
    for data in [
        DataConfig::Corpus { seed: opts.seed },
        DataConfig::Recall { style: "swde".into(), seed: opts.seed },
        DataConfig::Recall { style: "squad".into(), seed: opts.seed },
        DataConfig::Recall { style: "fda".into(), seed: opts.seed },
    ] {
        let mut trainer = Trainer::new(runtime, artifact, opts.seed)?;
        let mut task = build_task(&data);
        let cfg = RunConfig {
            artifact: artifact.to_string(),
            artifacts_dir: runtime.artifacts_dir().to_path_buf(),
            steps: opts.steps,
            seed: opts.seed,
            lr: LrSchedule::paper_default(opts.steps),
            data,
            eval_every: 0,
            eval_batches: opts.eval_batches,
            log_path: None,
            checkpoint_path: None,
        };
        let report = trainer.train(&cfg, task.as_mut(), None)?;
        let final_loss = report.final_loss
            .context("training run recorded no final loss")?;
        sums.push(final_loss as f64);
    }
    cells.push(f2(sums[0].exp()));
    for s in &sums[1..] {
        // report exp(-loss) as a recall-quality proxy in (0,1]
        cells.push(pct((-s).exp()));
    }
    let avg = sums[1..].iter().map(|s| (-s).exp()).sum::<f64>() / 3.0;
    cells.push(pct(avg));
    Ok(cells)
}
