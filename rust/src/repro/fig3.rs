//! Figure 3 — RegBench: in-context language learning over random PFAs.
//!
//! Accuracy counts a prediction correct when it is ANY valid next symbol
//! under the sequence's PFA (the benchmark's scoring rule) — wired through
//! Batch::accept.  Expected shape: DeltaNet and attention adapt to the
//! held-out languages; pure-decay models trail.

use crate::config::DataConfig;
use crate::eval::{pct, Table};
use crate::runtime::Runtime;

use super::{tiny_artifact, train_cell, ReproOpts};

pub const ARCHS: [&str; 5] = ["deltanet", "gla", "mamba2", "retnet",
                              "transformer"];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        &format!("Figure 3: RegBench accuracy (%) after {} steps \
                  (held-out PFAs)", opts.steps),
        &["model", "accuracy"]);

    for arch in ARCHS {
        let (outcome, _) = train_cell(
            runtime,
            &tiny_artifact(arch),
            DataConfig::RegBench { seed: opts.seed },
            opts)?;
        table.row(vec![arch.to_string(), pct(outcome.accuracy)]);
    }
    table.print();
    Ok(())
}
