//! Table 3 — zero-shot suite at the "largest trained scale".
//!
//! The paper's Table 3 compares 3B models across six benchmarks.  At this
//! testbed's scale the analog is: train the three families at the *small*
//! preset (the largest default-built preset) on the LM corpus, then
//! zero-shot them on the full task battery WITHOUT task-specific training
//! — measuring how much task structure LM pretraining alone transfers,
//! which is exactly what zero-shot columns measure.

use crate::config::DataConfig;
use crate::eval::{pct, Table};
use crate::runtime::Runtime;

use super::ReproOpts;

pub const ARCHS: [&str; 3] = ["transformer", "mamba2", "deltanet"];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let mut table = Table::new(
        &format!("Table 3: zero-shot task accuracy (%) after {} corpus \
                  steps (small preset)", opts.steps),
        &["model", "swde", "squad", "fda", "mqar", "average"]);

    for arch in ARCHS {
        let artifact = format!("{arch}_small");
        if !runtime.has_artifact(&format!("{artifact}.train")) {
            eprintln!("(skipping {arch}: {artifact} not built)");
            continue;
        }
        table.row(zero_shot_row(runtime, &artifact, arch, opts)?);
    }
    table.print();
    Ok(())
}

fn zero_shot_row(runtime: &Runtime, artifact: &str, label: &str,
                 opts: &ReproOpts) -> crate::Result<Vec<String>> {
    use crate::config::{LrSchedule, RunConfig};
    use crate::coordinator::Trainer;
    use crate::data::batcher::Split;

    // 1. pretrain on the corpus only
    let mut trainer = Trainer::new(runtime, artifact, opts.seed)?;
    let corpus = DataConfig::Corpus { seed: opts.seed };
    let split = Split::from_config(&corpus);
    let mut train_task = split.train;
    let cfg = RunConfig {
        artifact: artifact.to_string(),
        artifacts_dir: runtime.artifacts_dir().to_path_buf(),
        steps: opts.steps,
        seed: opts.seed,
        lr: LrSchedule::paper_default(opts.steps),
        data: corpus,
        eval_every: 0,
        eval_batches: opts.eval_batches,
        log_path: None,
        checkpoint_path: None,
    };
    trainer.train(&cfg, train_task.as_mut(), None)?;

    // 2. zero-shot evaluate on the task battery
    let mut cells = vec![label.to_string()];
    let mut sum = 0.0;
    let tasks = [
        DataConfig::Recall { style: "swde".into(), seed: opts.seed ^ 1 },
        DataConfig::Recall { style: "squad".into(), seed: opts.seed ^ 2 },
        DataConfig::Recall { style: "fda".into(), seed: opts.seed ^ 3 },
        DataConfig::Mqar { num_pairs: 8, seed: opts.seed ^ 4 },
    ];
    for t in tasks {
        let mut task = crate::data::build_task(&t);
        let outcome = trainer.evaluate(task.as_mut(), opts.eval_batches)?;
        sum += outcome.accuracy;
        cells.push(pct(outcome.accuracy));
    }
    cells.push(pct(sum / 4.0));
    Ok(cells)
}

/// Convenience used by `repro::run("tab3")` tests: the arch list.
pub fn arch_list() -> &'static [&'static str] {
    &ARCHS
}
