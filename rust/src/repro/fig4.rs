//! Figure 4 — training throughput (tokens/sec) per architecture.
//!
//! The paper sweeps (seq-len, batch) at fixed tokens-per-batch on one H100.
//! Here: tiny and small presets on the CPU PJRT backend, measuring the full
//! train-step wall time (fwd + bwd + AdamW + host I/O — the honest number a
//! user gets).  Expected shape: linear-time models hold throughput as L
//! grows while the transformer degrades; DeltaNet lands between GLA and
//! attention (the paper's §5.3 overhead discussion).

use crate::config::DataConfig;
use crate::data::build_task;
use crate::eval::Table;
use crate::runtime::Runtime;

use super::ReproOpts;

pub const TINY_ARCHS: [&str; 6] = ["transformer", "retnet", "mamba2", "gla",
                                   "linattn", "deltanet"];
pub const SMALL_ARCHS: [&str; 4] = ["transformer", "gla", "mamba2",
                                    "deltanet"];

pub const LONG_ARCHS: [&str; 3] = ["transformer", "gla", "deltanet"];

pub fn run(runtime: &Runtime, opts: &ReproOpts) -> crate::Result<()> {
    let steps = opts.steps.clamp(5, 30); // throughput needs few steps
    let mut table = Table::new(
        &format!("Figure 4: training throughput, tokens/sec \
                  (median over {steps} steps)"),
        &["model", "tiny (L=64)", "small (L=128)", "long (L=1024)"]);

    for arch in TINY_ARCHS {
        // offline, only deltanet has a (host) training path — other archs
        // print "-" instead of aborting the whole table
        let opt_col = |preset: &str, allowed: bool| {
            if !allowed {
                return "-".to_string();
            }
            measure(runtime, &format!("{arch}_{preset}"), steps, opts)
                .map(|t| format!("{t:.0}"))
                .unwrap_or_else(|_| "-".into())
        };
        let tiny = opt_col("tiny", true);
        let small = opt_col("small", SMALL_ARCHS.contains(&arch));
        let long = opt_col("long", LONG_ARCHS.contains(&arch));
        table.row(vec![arch.to_string(), tiny, small, long]);
    }
    table.print();
    println!("the paper's crossover: at L=1024 the O(L²) transformer \
              falls behind the linear-time mixers.");
    Ok(())
}

/// Median tokens/sec over `steps` train steps.
pub fn measure(runtime: &Runtime, artifact: &str, steps: usize,
               opts: &ReproOpts) -> crate::Result<f64> {
    use crate::coordinator::Trainer;
    let mut trainer = Trainer::new(runtime, artifact, opts.seed)?;
    let mut task = build_task(&DataConfig::Corpus { seed: opts.seed });
    let tokens = trainer.batch * trainer.seq_len;
    // warmup (compile-cache fill + first-run allocation)
    let b = task.sample(trainer.batch, trainer.seq_len);
    trainer.train_step(&b, 1e-4)?;
    let mut rates = Vec::with_capacity(steps);
    for _ in 0..steps {
        let b = task.sample(trainer.batch, trainer.seq_len);
        let t0 = std::time::Instant::now();
        trainer.train_step(&b, 1e-4)?;
        rates.push(tokens as f64 / t0.elapsed().as_secs_f64());
    }
    rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(rates[rates.len() / 2])
}
