//! Run metrics: throughput meters, loss tracking, JSONL run logs.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// Exponentially-weighted moving average.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Tokens/sec + step-time meter.
pub struct Throughput {
    started: Instant,
    tokens: u64,
    steps: u64,
    step_time: Ewma,
    last_step: Option<Instant>,
    /// Clock origin for the rate: (time of the first recorded step, tokens
    /// already counted at that moment).  Measuring from construction time
    /// understated the rate whenever setup (model init, artifact load)
    /// happened between `Throughput::new()` and the first step.
    first_step: Option<(Instant, u64)>,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput {
            started: Instant::now(),
            tokens: 0,
            steps: 0,
            step_time: Ewma::new(0.1),
            last_step: None,
            first_step: None,
        }
    }

    pub fn record_step(&mut self, tokens: usize) {
        let now = Instant::now();
        if let Some(last) = self.last_step {
            self.step_time.update(now.duration_since(last).as_secs_f64());
        }
        self.last_step = Some(now);
        self.tokens += tokens as u64;
        self.steps += 1;
        if self.first_step.is_none() {
            // steady-state origin: the first step's own tokens (and any
            // cold-start cost inside it) are excluded from the rate
            self.first_step = Some((now, self.tokens));
        }
    }

    /// Steady-state tokens/sec, clocked from the completion of the first
    /// recorded step.  0.0 until a second step lands.
    pub fn tokens_per_sec(&self) -> f64 {
        match self.first_step {
            None => 0.0,
            Some((t0, tok0)) => {
                (self.tokens - tok0) as f64
                    / t0.elapsed().as_secs_f64().max(1e-9)
            }
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn avg_step_time(&self) -> Option<f64> {
        self.step_time.get()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

/// One JSONL record of a training run.  The `Option` fields are emitted
/// only when present (the host engine reports a per-phase breakdown, the
/// artifact engine does not), so old log consumers keep parsing.
#[derive(Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f64,
    pub tokens_per_sec: f64,
    pub elapsed_secs: f64,
    pub grad_norm: Option<f64>,
    pub forward_ms: Option<f64>,
    pub backward_ms: Option<f64>,
    pub optimizer_ms: Option<f64>,
    /// This step's own tokens/sec (unlike the top-level `tokens_per_sec`,
    /// which is the run's steady-state rate).
    pub step_tokens_per_sec: Option<f64>,
    /// Achieved kernel GFLOP/s over the step (see
    /// `StepBreakdown::gflops`).
    pub gflops: Option<f64>,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(self.loss as f64)),
            ("lr", Json::num(self.lr)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
        ];
        let optional = [
            ("grad_norm", self.grad_norm),
            ("forward_ms", self.forward_ms),
            ("backward_ms", self.backward_ms),
            ("optimizer_ms", self.optimizer_ms),
            ("step_tokens_per_sec", self.step_tokens_per_sec),
            ("gflops", self.gflops),
        ];
        for (name, v) in optional {
            if let Some(x) = v {
                fields.push((name, Json::num(x)));
            }
        }
        Json::obj(fields)
    }
}

/// Append-only JSONL logger (None path = in-memory only).  Writes go
/// through a `BufWriter`; call [`Self::flush`] at run boundaries — Drop
/// flushes too, but cannot surface I/O errors.
pub struct RunLog {
    file: Option<std::io::BufWriter<std::fs::File>>,
    pub records: Vec<StepRecord>,
}

impl RunLog {
    pub fn new(path: Option<&Path>) -> crate::Result<Self> {
        let file = match path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                Some(std::io::BufWriter::new(std::fs::File::create(p)?))
            }
            None => None,
        };
        Ok(RunLog { file, records: vec![] })
    }

    pub fn log(&mut self, rec: StepRecord) -> crate::Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", rec.to_json().render())?;
        }
        self.records.push(rec);
        Ok(())
    }

    /// Flush buffered records to disk, surfacing any I/O error.
    pub fn flush(&mut self) -> crate::Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    /// Mean loss of the last `n` records (loss-curve summaries).
    pub fn recent_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }
}

impl Drop for RunLog {
    fn drop(&mut self) {
        if let Some(f) = &mut self.file {
            let _ = f.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..20 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.record_step(100);
        t.record_step(100);
        assert_eq!(t.steps(), 2);
        assert!(t.tokens_per_sec() > 0.0);
    }

    #[test]
    fn throughput_clock_starts_at_first_step() {
        // idle setup time before the first step must not dilute the rate
        let mut t = Throughput::new();
        std::thread::sleep(std::time::Duration::from_millis(25));
        assert_eq!(t.tokens_per_sec(), 0.0); // no steps yet
        t.record_step(100);
        t.record_step(100);
        let steady = t.tokens_per_sec();
        let naive = t.tokens as f64 / t.elapsed_secs();
        assert!(steady > 0.0);
        // naive rate spans the 25ms sleep over 200 tokens; steady spans
        // only the inter-step gap over 100 tokens and must be far higher
        assert!(steady > naive,
                "steady {steady} should beat naive {naive}");
    }

    #[test]
    fn runlog_writes_jsonl() {
        let dir = std::env::temp_dir().join("deltanet_test_log");
        let path = dir.join("run.jsonl");
        let mut log = RunLog::new(Some(&path)).unwrap();
        log.log(StepRecord {
            step: 1, loss: 2.5, lr: 1e-4,
            tokens_per_sec: 10.0, elapsed_secs: 0.1,
            ..Default::default()
        }).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"loss\":2.5"));
        // absent optional fields stay out of the record entirely
        assert!(!text.contains("grad_norm"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn runlog_flush_persists_without_drop() {
        let dir = std::env::temp_dir().join("deltanet_test_log_flush");
        let path = dir.join("run.jsonl");
        let mut log = RunLog::new(Some(&path)).unwrap();
        log.log(StepRecord {
            step: 0, loss: 1.0, lr: 1e-3,
            tokens_per_sec: 5.0, elapsed_secs: 0.01,
            grad_norm: Some(0.75),
            forward_ms: Some(3.0),
            backward_ms: Some(6.0),
            optimizer_ms: Some(1.0),
            step_tokens_per_sec: Some(5.5),
            gflops: Some(0.25),
        }).unwrap();
        log.flush().unwrap();
        // read while `log` is still alive: only flush made this visible
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"grad_norm\":0.75"));
        assert!(text.contains("\"forward_ms\":3"));
        assert!(text.contains("\"step_tokens_per_sec\":5.5"));
        assert!(text.contains("\"gflops\":0.25"));
        drop(log);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recent_loss_window() {
        let mut log = RunLog::new(None).unwrap();
        for (i, l) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            log.log(StepRecord {
                step: i, loss: *l, lr: 0.0,
                tokens_per_sec: 0.0, elapsed_secs: 0.0,
                ..Default::default()
            }).unwrap();
        }
        assert_eq!(log.recent_loss(2), Some(1.5));
        assert_eq!(log.recent_loss(100), Some(2.5));
    }
}
