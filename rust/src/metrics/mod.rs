//! Run metrics: throughput meters, loss tracking, JSONL run logs.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// Exponentially-weighted moving average.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Tokens/sec + step-time meter.
pub struct Throughput {
    started: Instant,
    tokens: u64,
    steps: u64,
    step_time: Ewma,
    last_step: Option<Instant>,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput {
            started: Instant::now(),
            tokens: 0,
            steps: 0,
            step_time: Ewma::new(0.1),
            last_step: None,
        }
    }

    pub fn record_step(&mut self, tokens: usize) {
        let now = Instant::now();
        if let Some(last) = self.last_step {
            self.step_time.update(now.duration_since(last).as_secs_f64());
        }
        self.last_step = Some(now);
        self.tokens += tokens as u64;
        self.steps += 1;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn avg_step_time(&self) -> Option<f64> {
        self.step_time.get()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

/// One JSONL record of a training run.
#[derive(Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f64,
    pub tokens_per_sec: f64,
    pub elapsed_secs: f64,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(self.loss as f64)),
            ("lr", Json::num(self.lr)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
        ])
    }
}

/// Append-only JSONL logger (None path = in-memory only).
pub struct RunLog {
    file: Option<std::fs::File>,
    pub records: Vec<StepRecord>,
}

impl RunLog {
    pub fn new(path: Option<&Path>) -> crate::Result<Self> {
        let file = match path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                Some(std::fs::File::create(p)?)
            }
            None => None,
        };
        Ok(RunLog { file, records: vec![] })
    }

    pub fn log(&mut self, rec: StepRecord) -> crate::Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", rec.to_json().render())?;
        }
        self.records.push(rec);
        Ok(())
    }

    /// Mean loss of the last `n` records (loss-curve summaries).
    pub fn recent_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..20 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.record_step(100);
        t.record_step(100);
        assert_eq!(t.steps(), 2);
        assert!(t.tokens_per_sec() > 0.0);
    }

    #[test]
    fn runlog_writes_jsonl() {
        let dir = std::env::temp_dir().join("deltanet_test_log");
        let path = dir.join("run.jsonl");
        let mut log = RunLog::new(Some(&path)).unwrap();
        log.log(StepRecord {
            step: 1, loss: 2.5, lr: 1e-4,
            tokens_per_sec: 10.0, elapsed_secs: 0.1,
        }).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"loss\":2.5"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recent_loss_window() {
        let mut log = RunLog::new(None).unwrap();
        for (i, l) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            log.log(StepRecord {
                step: i, loss: *l, lr: 0.0,
                tokens_per_sec: 0.0, elapsed_secs: 0.0,
            }).unwrap();
        }
        assert_eq!(log.recent_loss(2), Some(1.5));
        assert_eq!(log.recent_loss(100), Some(2.5));
    }
}
