//! Host-side optimizers for the offline training path: plain SGD (with
//! optional momentum) and AdamW (decoupled weight decay), operating over
//! the model's canonical parameter list.
//!
//! State is kept per parameter tensor, keyed by position in the list, and
//! allocated lazily on the first step so the optimizer does not need the
//! model shapes up front.

use crate::tensor::Mat;

/// SGD with momentum (`momentum = 0` is plain gradient descent).
#[derive(Debug, Clone)]
pub struct Sgd {
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Self {
        Sgd { momentum, vel: vec![] }
    }
}

impl Default for Sgd {
    fn default() -> Self {
        Sgd::new(0.0)
    }
}

/// AdamW: Adam moments + decoupled weight decay.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new() -> Self {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            step: 0,
            m: vec![],
            v: vec![],
        }
    }
}

impl Default for AdamW {
    fn default() -> Self {
        AdamW::new()
    }
}

/// The optimizer choice of the host training step.
#[derive(Debug, Clone)]
pub enum Optimizer {
    Sgd(Sgd),
    AdamW(AdamW),
}

impl Optimizer {
    /// Apply one update.  `params` and `grads` must be the model's
    /// canonical parameter order, and keep that order across steps (the
    /// per-tensor state is positional).
    pub fn step(&mut self, params: &mut [&mut Mat], grads: &[&Mat],
                lr: f32) {
        assert_eq!(params.len(), grads.len(), "one grad per param");
        for (p, g) in params.iter().zip(grads.iter()) {
            assert_eq!((p.rows, p.cols), (g.rows, g.cols), "grad shape");
        }
        match self {
            Optimizer::Sgd(s) => {
                if s.vel.is_empty() && s.momentum != 0.0 {
                    s.vel = params.iter()
                        .map(|p| vec![0.0; p.data.len()]).collect();
                }
                for (i, (p, g)) in
                    params.iter_mut().zip(grads.iter()).enumerate()
                {
                    if s.momentum == 0.0 {
                        for (x, &gx) in p.data.iter_mut().zip(&g.data) {
                            *x -= lr * gx;
                        }
                    } else {
                        for ((x, &gx), vx) in p.data.iter_mut()
                            .zip(&g.data).zip(s.vel[i].iter_mut())
                        {
                            *vx = s.momentum * *vx + gx;
                            *x -= lr * *vx;
                        }
                    }
                }
            }
            Optimizer::AdamW(a) => {
                if a.m.is_empty() {
                    a.m = params.iter()
                        .map(|p| vec![0.0; p.data.len()]).collect();
                    a.v = params.iter()
                        .map(|p| vec![0.0; p.data.len()]).collect();
                }
                a.step += 1;
                let bc1 = 1.0 - a.beta1.powi(a.step as i32);
                let bc2 = 1.0 - a.beta2.powi(a.step as i32);
                for (i, (p, g)) in
                    params.iter_mut().zip(grads.iter()).enumerate()
                {
                    let (ms, vs) = (&mut a.m[i], &mut a.v[i]);
                    for (((x, &gx), mx), vx) in p.data.iter_mut()
                        .zip(&g.data).zip(ms.iter_mut()).zip(vs.iter_mut())
                    {
                        *mx = a.beta1 * *mx + (1.0 - a.beta1) * gx;
                        *vx = a.beta2 * *vx + (1.0 - a.beta2) * gx * gx;
                        let mhat = *mx / bc1;
                        let vhat = *vx / bc2;
                        // decoupled decay: shrink the weight, not the grad
                        *x -= lr
                            * (mhat / (vhat.sqrt() + a.eps)
                               + a.weight_decay * *x);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_descends(mut opt: Optimizer) -> f32 {
        // minimize f(x) = ½‖x‖² from x = (4, −2): grad = x
        let mut p = Mat::from_vec(1, 2, vec![4.0, -2.0]).unwrap();
        for _ in 0..200 {
            let g = p.clone();
            let mut params = [&mut p];
            opt.step(&mut params, &[&g], 0.1);
        }
        p.data.iter().map(|x| x * x).sum::<f32>()
    }

    #[test]
    fn sgd_minimizes_quadratic() {
        assert!(quadratic_descends(Optimizer::Sgd(Sgd::new(0.0))) < 1e-6);
        assert!(quadratic_descends(Optimizer::Sgd(Sgd::new(0.9))) < 1e-6);
    }

    #[test]
    fn adamw_minimizes_quadratic() {
        assert!(quadratic_descends(Optimizer::AdamW(AdamW::new())) < 1e-3);
    }

    #[test]
    fn adamw_weight_decay_shrinks_without_gradient() {
        let mut a = AdamW::new();
        a.weight_decay = 0.1;
        let mut opt = Optimizer::AdamW(a);
        let mut p = Mat::from_vec(1, 1, vec![1.0]).unwrap();
        let zero = Mat::zeros(1, 1);
        for _ in 0..10 {
            let mut params = [&mut p];
            opt.step(&mut params, &[&zero], 0.1);
        }
        assert!(p.data[0] < 1.0 && p.data[0] > 0.8);
    }
}
