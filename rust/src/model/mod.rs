//! Host-side DeltaNet language model: the offline training/serving path.
//!
//! A small repro model in plain Rust — embedding, N DeltaNet sequence-
//! mixing layers (per-head chunkwise forward/backward fanned out over the
//! kernel batch layer), residual connections and a tied-nothing LM head —
//! with a hand-derived backward pass built on `kernels::backward`.  This is
//! what `coordinator::trainer` falls back to when no `.train` artifact is
//! present (the offline build), and what the artifact-free serving demo
//! decodes with.
//!
//! Per layer, for input x ∈ R^{B·L×d} (h heads, d_h = d/h):
//!
//! ```text
//!   q = norm(x W_q),  k = norm(x W_k),  v = x W_v     per-head row L2 norm
//!   β = σ(x W_β + b_β)                                 per head, per token
//!   m = DeltaNet(q, k, v, β)                           chunkwise, per (b,h)
//!   y = m W_o + x                                      residual
//! ```
//!
//! The loss is masked mean cross-entropy over target positions, matching
//! the artifact trainers' convention (`nll_sum / mask_sum`).

pub mod opt;

use std::time::Instant;

use crate::data::Batch;
use crate::kernels::{
    backward_batched_on, forward_batched_on, HeadProblem,
};
use crate::obs;
use crate::tensor::blocked::{matmul, matmul_nt_into, matmul_tn_acc};
use crate::tensor::rng::Rng;
use crate::tensor::{axpy, dot, l2_normalize, softmax, Mat};
use crate::util::threadpool::ThreadPool;
use crate::ensure;

pub use opt::{AdamW, Optimizer, Sgd};

/// Wall-clock of the two phases inside one `loss_and_grads` call,
/// reported by [`HostModel::loss_and_grads_timed`] and surfaced through
/// `StepRecord`'s per-phase fields.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseMillis {
    pub forward_ms: f64,
    pub backward_ms: f64,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Shape of a host model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Chunk length for the chunkwise kernels.
    pub chunk: usize,
}

impl HostModelCfg {
    /// The default offline repro shape: big enough for the MQAR toy task
    /// (vocab ≥ 98), small enough to train in seconds on a laptop.
    pub fn tiny() -> Self {
        HostModelCfg {
            vocab: 128,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            chunk: 16,
        }
    }
}

/// One sequence-mixing layer's parameters.
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    /// [d, h] — β projection.
    pub wb: Mat,
    /// [1, h] — β bias.
    pub bb: Mat,
}

/// Gradients for one layer (same shapes as [`LayerParams`]).
#[derive(Debug, Clone)]
pub struct LayerGrads {
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub wb: Mat,
    pub bb: Mat,
}

/// Full-model gradients in canonical parameter order.
#[derive(Debug, Clone)]
pub struct ModelGrads {
    pub embed: Mat,
    pub layers: Vec<LayerGrads>,
    pub lm_head: Mat,
}

impl ModelGrads {
    fn zeros_like(model: &HostModel) -> Self {
        let zl = |m: &Mat| Mat::zeros(m.rows, m.cols);
        ModelGrads {
            embed: zl(&model.embed),
            layers: model
                .layers
                .iter()
                .map(|l| LayerGrads {
                    wq: zl(&l.wq),
                    wk: zl(&l.wk),
                    wv: zl(&l.wv),
                    wo: zl(&l.wo),
                    wb: zl(&l.wb),
                    bb: zl(&l.bb),
                })
                .collect(),
            lm_head: zl(&model.lm_head),
        }
    }

    /// Tensors in canonical parameter order (matches
    /// [`HostModel::param_entries`]).
    pub fn tensors(&self) -> Vec<&Mat> {
        let mut out = vec![&self.embed];
        for l in &self.layers {
            out.extend([&l.wq, &l.wk, &l.wv, &l.wo, &l.wb, &l.bb]);
        }
        out.push(&self.lm_head);
        out
    }

    /// Global L2 norm over all gradient tensors (clipping / diagnostics).
    pub fn global_norm(&self) -> f32 {
        self.tensors()
            .iter()
            .map(|t| t.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            .sqrt() as f32
    }
}

/// Per-layer forward activations kept for the backward pass.  The mixing
/// problems store the *normalized* q/k the kernels consumed; the stored
/// norms undo the normalization in the backward.
struct LayerCache {
    x_in: Mat,
    problems: Vec<HeadProblem>,
    /// [B·H·L], indexed p·L + t.
    q_norms: Vec<f32>,
    k_norms: Vec<f32>,
    mixed: Mat,
}

/// A host DeltaNet LM: parameters + a worker pool for the head fan-out.
pub struct HostModel {
    pub cfg: HostModelCfg,
    /// [vocab, d]
    pub embed: Mat,
    pub layers: Vec<LayerParams>,
    /// [d, vocab]
    pub lm_head: Mat,
    pool: ThreadPool,
}

impl HostModel {
    /// Fresh model, deterministically initialized under `seed`; `threads`
    /// sizes the worker pool for the per-(batch, head) kernel fan-out.
    pub fn new(cfg: HostModelCfg, seed: u64, threads: usize)
               -> crate::Result<Self> {
        ensure!(cfg.vocab > 0 && cfg.d_model > 0 && cfg.n_layers > 0
                && cfg.n_heads > 0, "empty model shape");
        ensure!(cfg.d_model % cfg.n_heads == 0,
                "d_model {} not divisible by n_heads {}", cfg.d_model,
                cfg.n_heads);
        ensure!(cfg.chunk > 0, "chunk must be > 0");
        let d = cfg.d_model;
        let std = 1.0 / (d as f32).sqrt();
        let mut rng = Rng::new(seed);
        let embed = Mat::random(cfg.vocab, d, &mut rng, 0.1);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerParams {
                wq: Mat::random(d, d, &mut rng, std),
                wk: Mat::random(d, d, &mut rng, std),
                wv: Mat::random(d, d, &mut rng, std),
                wo: Mat::random(d, d, &mut rng, std),
                wb: Mat::random(d, cfg.n_heads, &mut rng, 0.01),
                // b_β = 0 → β starts at ½
                bb: Mat::zeros(1, cfg.n_heads),
            })
            .collect();
        let lm_head = Mat::random(d, cfg.vocab, &mut rng, std);
        Ok(HostModel {
            cfg,
            embed,
            layers,
            lm_head,
            pool: ThreadPool::new(threads.max(1)),
        })
    }

    pub fn param_count(&self) -> usize {
        self.param_entries().iter().map(|(_, m)| m.data.len()).sum()
    }

    /// (name, tensor) pairs in canonical order: embed, per-layer
    /// wq/wk/wv/wo/wb/bb, lm_head.
    pub fn param_entries(&self) -> Vec<(String, &Mat)> {
        let mut out: Vec<(String, &Mat)> =
            vec![("embed".into(), &self.embed)];
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("layers.{i}.wq"), &l.wq));
            out.push((format!("layers.{i}.wk"), &l.wk));
            out.push((format!("layers.{i}.wv"), &l.wv));
            out.push((format!("layers.{i}.wo"), &l.wo));
            out.push((format!("layers.{i}.wb"), &l.wb));
            out.push((format!("layers.{i}.bb"), &l.bb));
        }
        out.push(("lm_head".into(), &self.lm_head));
        out
    }

    /// Mutable counterpart of [`Self::param_entries`] (same order).
    pub fn param_entries_mut(&mut self) -> Vec<(String, &mut Mat)> {
        let mut out: Vec<(String, &mut Mat)> =
            vec![("embed".into(), &mut self.embed)];
        for (i, l) in self.layers.iter_mut().enumerate() {
            out.push((format!("layers.{i}.wq"), &mut l.wq));
            out.push((format!("layers.{i}.wk"), &mut l.wk));
            out.push((format!("layers.{i}.wv"), &mut l.wv));
            out.push((format!("layers.{i}.wo"), &mut l.wo));
            out.push((format!("layers.{i}.wb"), &mut l.wb));
            out.push((format!("layers.{i}.bb"), &mut l.bb));
        }
        out.push(("lm_head".into(), &mut self.lm_head));
        out
    }

    // ------------------------------------------------------------ forward

    fn forward_cached(&self, batch: &Batch)
                      -> crate::Result<(Vec<LayerCache>, Mat)> {
        let (bsz, l) = (batch.batch, batch.seq_len);
        ensure!(bsz > 0 && l > 0, "empty batch");
        let _sp = obs::trace::span_with("model.forward", || {
            vec![("B", bsz as f64), ("L", l as f64)]
        });
        let (d, h) = (self.cfg.d_model, self.cfg.n_heads);
        let dh = d / h;

        // embedding gather over input positions tokens[:, :L]
        let mut x = Mat::zeros(bsz * l, d);
        for b in 0..bsz {
            for t in 0..l {
                let tok = batch.token(b, t);
                ensure!(tok >= 0 && (tok as usize) < self.cfg.vocab,
                        "token {tok} outside vocab {}", self.cfg.vocab);
                x.row_mut(b * l + t)
                    .copy_from_slice(self.embed.row(tok as usize));
            }
        }

        let mut caches = Vec::with_capacity(self.layers.len());
        for (li, layer) in self.layers.iter().enumerate() {
            let _layer_sp = obs::trace::span_with("model.layer", || {
                vec![("layer", li as f64)]
            });
            let q_all = matmul(&x, &layer.wq);
            let k_all = matmul(&x, &layer.wk);
            let v_all = matmul(&x, &layer.wv);
            let mut beta_all = matmul(&x, &layer.wb); // [B·L, h]
            for r in 0..bsz * l {
                for (bx, &bias) in
                    beta_all.row_mut(r).iter_mut().zip(layer.bb.row(0))
                {
                    *bx = sigmoid(*bx + bias);
                }
            }

            // per-(batch, head) problems with normalized q/k
            let mut problems = Vec::with_capacity(bsz * h);
            let mut q_norms = vec![0.0f32; bsz * h * l];
            let mut k_norms = vec![0.0f32; bsz * h * l];
            for b in 0..bsz {
                for hd in 0..h {
                    let p = b * h + hd;
                    let mut qh = Mat::zeros(l, dh);
                    let mut kh = Mat::zeros(l, dh);
                    let mut vh = Mat::zeros(l, dh);
                    let mut betah = vec![0.0f32; l];
                    for t in 0..l {
                        let r = b * l + t;
                        let cols = hd * dh..(hd + 1) * dh;
                        qh.row_mut(t)
                            .copy_from_slice(&q_all.row(r)[cols.clone()]);
                        kh.row_mut(t)
                            .copy_from_slice(&k_all.row(r)[cols.clone()]);
                        vh.row_mut(t)
                            .copy_from_slice(&v_all.row(r)[cols]);
                        q_norms[p * l + t] = l2_normalize(qh.row_mut(t));
                        k_norms[p * l + t] = l2_normalize(kh.row_mut(t));
                        betah[t] = beta_all[(r, hd)];
                    }
                    problems.push(HeadProblem::new(qh, kh, vh, betah));
                }
            }
            // DAG-scheduled over (batch, head, chunk) tasks: even B=1
            // training batches fan out across the whole pool
            let outs =
                forward_batched_on(&self.pool, &problems, self.cfg.chunk);

            let mut mixed = Mat::zeros(bsz * l, d);
            for b in 0..bsz {
                for hd in 0..h {
                    let f = &outs[b * h + hd];
                    for t in 0..l {
                        mixed.row_mut(b * l + t)[hd * dh..(hd + 1) * dh]
                            .copy_from_slice(f.o.row(t));
                    }
                }
            }

            // y = m W_o + x (residual)
            let mut y = matmul(&mixed, &layer.wo);
            for (yy, xx) in y.data.iter_mut().zip(&x.data) {
                *yy += xx;
            }
            caches.push(LayerCache {
                x_in: x,
                problems,
                q_norms,
                k_norms,
                mixed,
            });
            x = y;
        }
        Ok((caches, x))
    }

    /// Masked mean cross-entropy of one batch (forward only).
    pub fn loss(&self, batch: &Batch) -> crate::Result<f32> {
        let (nll, mask, _) = self.evaluate_batch(batch)?;
        Ok(if mask > 0.0 { (nll / mask) as f32 } else { 0.0 })
    }

    /// Forward + backward: masked mean CE loss and full parameter
    /// gradients.
    pub fn loss_and_grads(&self, batch: &Batch)
                          -> crate::Result<(f32, ModelGrads)> {
        let (loss, grads, _) = self.loss_and_grads_timed(batch)?;
        Ok((loss, grads))
    }

    /// [`Self::loss_and_grads`] plus per-phase wall-clock, for step-level
    /// breakdowns in the trainer's log.
    pub fn loss_and_grads_timed(&self, batch: &Batch)
                                -> crate::Result<(f32, ModelGrads,
                                                  PhaseMillis)> {
        let t_fwd = Instant::now();
        let (caches, x_final) = {
            let _fwd_sp = obs::trace::span("train.forward");
            self.forward_cached(batch)?
        };
        let forward_ms = t_fwd.elapsed().as_secs_f64() * 1e3;

        let t_bwd = Instant::now();
        let _bwd_sp = obs::trace::span("train.backward");
        let (bsz, l) = (batch.batch, batch.seq_len);
        let (d, h) = (self.cfg.d_model, self.cfg.n_heads);
        let dh = d / h;

        // loss + dlogits in one pass
        let logits = matmul(&x_final, &self.lm_head);
        let mask_sum: f32 = batch.mask.iter().sum();
        let scale = if mask_sum > 0.0 { 1.0 / mask_sum } else { 0.0 };
        let mut loss = 0.0f64;
        let mut dlogits = Mat::zeros(bsz * l, self.cfg.vocab);
        for b in 0..bsz {
            for t in 0..l {
                let m = batch.mask[b * l + t];
                if m == 0.0 {
                    continue;
                }
                let r = b * l + t;
                let target = batch.token(b, t + 1);
                ensure!(target >= 0 && (target as usize) < self.cfg.vocab,
                        "target {target} outside vocab {}", self.cfg.vocab);
                let target = target as usize;
                let mut p = logits.row(r).to_vec();
                softmax(&mut p);
                loss -= (m * scale) as f64
                    * (p[target].max(1e-12) as f64).ln();
                let w = m * scale;
                let drow = dlogits.row_mut(r);
                for (x, &pj) in drow.iter_mut().zip(&p) {
                    *x = w * pj;
                }
                drow[target] -= w;
            }
        }

        let mut g = ModelGrads::zeros_like(self);
        matmul_tn_acc(&mut g.lm_head, &x_final, &dlogits);
        let mut dx = Mat::zeros(bsz * l, d);
        matmul_nt_into(&mut dx, &dlogits, &self.lm_head, false);

        for li in (0..self.layers.len()).rev() {
            let layer = &self.layers[li];
            let cache = &caches[li];
            let gl = &mut g.layers[li];

            matmul_tn_acc(&mut gl.wo, &cache.mixed, &dx);
            let mut dmixed = Mat::zeros(bsz * l, d);
            matmul_nt_into(&mut dmixed, &dx, &layer.wo, false);

            // per-head output gradients, then chunkwise backward fan-out
            let mut d_os = Vec::with_capacity(bsz * h);
            for b in 0..bsz {
                for hd in 0..h {
                    let mut m = Mat::zeros(l, dh);
                    for t in 0..l {
                        m.row_mut(t).copy_from_slice(
                            &dmixed.row(b * l + t)[hd * dh..(hd + 1) * dh]);
                    }
                    d_os.push(m);
                }
            }
            let head_grads = backward_batched_on(
                &self.pool, &cache.problems, &d_os, None, self.cfg.chunk);

            // undo per-row L2 norm, fold β through its sigmoid, reassemble
            let mut dq_pre = Mat::zeros(bsz * l, d);
            let mut dk_pre = Mat::zeros(bsz * l, d);
            let mut dv_pre = Mat::zeros(bsz * l, d);
            let mut dbpre = Mat::zeros(bsz * l, h);
            for b in 0..bsz {
                for hd in 0..h {
                    let p = b * h + hd;
                    let hg = &head_grads[p];
                    let prob = &cache.problems[p];
                    for t in 0..l {
                        let r = b * l + t;
                        let cols = hd * dh..(hd + 1) * dh;
                        let gq = unnormalize_grad(
                            hg.dq.row(t), prob.q.row(t),
                            cache.q_norms[p * l + t]);
                        dq_pre.row_mut(r)[cols.clone()]
                            .copy_from_slice(&gq);
                        let gk = unnormalize_grad(
                            hg.dk.row(t), prob.k.row(t),
                            cache.k_norms[p * l + t]);
                        dk_pre.row_mut(r)[cols.clone()]
                            .copy_from_slice(&gk);
                        dv_pre.row_mut(r)[cols]
                            .copy_from_slice(hg.dv.row(t));
                        let bt = prob.beta[t];
                        dbpre[(r, hd)] = hg.dbeta[t] * bt * (1.0 - bt);
                    }
                }
            }

            matmul_tn_acc(&mut gl.wq, &cache.x_in, &dq_pre);
            matmul_tn_acc(&mut gl.wk, &cache.x_in, &dk_pre);
            matmul_tn_acc(&mut gl.wv, &cache.x_in, &dv_pre);
            matmul_tn_acc(&mut gl.wb, &cache.x_in, &dbpre);
            for r in 0..bsz * l {
                for (x, &gb) in
                    gl.bb.row_mut(0).iter_mut().zip(dbpre.row(r))
                {
                    *x += gb;
                }
            }

            // dx_in = dx (residual) + every projection's pullback
            matmul_nt_into(&mut dx, &dq_pre, &layer.wq, true);
            matmul_nt_into(&mut dx, &dk_pre, &layer.wk, true);
            matmul_nt_into(&mut dx, &dv_pre, &layer.wv, true);
            matmul_nt_into(&mut dx, &dbpre, &layer.wb, true);
        }

        // embedding scatter-add by token id
        for b in 0..bsz {
            for t in 0..l {
                let tok = batch.token(b, t) as usize;
                axpy(g.embed.row_mut(tok), 1.0, dx.row(b * l + t));
            }
        }
        let backward_ms = t_bwd.elapsed().as_secs_f64() * 1e3;
        Ok((loss as f32, g, PhaseMillis { forward_ms, backward_ms }))
    }

    /// Forward evaluation: (nll_sum, mask_sum, argmax preds [B·L]).
    pub fn evaluate_batch(&self, batch: &Batch)
                          -> crate::Result<(f64, f64, Vec<i32>)> {
        let (_caches, x_final) = self.forward_cached(batch)?;
        let (bsz, l) = (batch.batch, batch.seq_len);
        let logits = matmul(&x_final, &self.lm_head);
        let mut nll_sum = 0.0f64;
        let mut mask_sum = 0.0f64;
        let mut preds = vec![0i32; bsz * l];
        for b in 0..bsz {
            for t in 0..l {
                let r = b * l + t;
                let row = logits.row(r);
                let mut best = 0usize;
                for (j, &x) in row.iter().enumerate() {
                    if x > row[best] {
                        best = j;
                    }
                }
                preds[r] = best as i32;
                let m = batch.mask[r];
                if m == 0.0 {
                    continue;
                }
                let target = batch.token(b, t + 1);
                ensure!(target >= 0 && (target as usize) < self.cfg.vocab,
                        "target {target} outside vocab {}", self.cfg.vocab);
                let mut p = row.to_vec();
                softmax(&mut p);
                nll_sum -= m as f64
                    * (p[target as usize].max(1e-12) as f64).ln();
                mask_sum += m as f64;
            }
        }
        Ok((nll_sum, mask_sum, preds))
    }

    // ------------------------------------------------------------- decode

    /// Fresh zeroed decode states for a batch of `batch` sequences: one
    /// [d_h, d_h] state per (layer, head, sequence), laid out so each
    /// (layer, head) group of `batch` states is contiguous.
    pub fn decode_states(&self, batch: usize) -> Vec<Mat> {
        let dh = self.cfg.d_model / self.cfg.n_heads;
        vec![
            Mat::zeros(dh, dh);
            self.cfg.n_layers * self.cfg.n_heads * batch
        ]
    }

    /// One decode step for the current token of every sequence.  The
    /// sequence-mixing recurrence itself is delegated to `mix` — the
    /// serving path passes `Backend::decode_step` here, so the same engine
    /// drives artifact-free decoding.  Returns flat logits [B · vocab].
    pub fn decode_step<F>(&self, states: &mut [Mat], tokens: &[i32],
                          mut mix: F) -> crate::Result<Vec<f32>>
    where
        F: FnMut(&mut [Mat], &Mat, &Mat, &Mat, &[f32])
            -> crate::Result<Mat>,
    {
        let bsz = tokens.len();
        let _sp = obs::trace::span_with("model.decode_step", || {
            vec![("B", bsz as f64)]
        });
        let (d, h) = (self.cfg.d_model, self.cfg.n_heads);
        let dh = d / h;
        ensure!(states.len() == self.cfg.n_layers * h * bsz,
                "want {} decode states, got {}",
                self.cfg.n_layers * h * bsz, states.len());
        let mut x = Mat::zeros(bsz, d);
        for (b, &tok) in tokens.iter().enumerate() {
            ensure!(tok >= 0 && (tok as usize) < self.cfg.vocab,
                    "token {tok} outside vocab {}", self.cfg.vocab);
            x.row_mut(b).copy_from_slice(self.embed.row(tok as usize));
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let q_all = matmul(&x, &layer.wq);
            let k_all = matmul(&x, &layer.wk);
            let v_all = matmul(&x, &layer.wv);
            let mut beta_all = matmul(&x, &layer.wb);
            for r in 0..bsz {
                for (bx, &bias) in
                    beta_all.row_mut(r).iter_mut().zip(layer.bb.row(0))
                {
                    *bx = sigmoid(*bx + bias);
                }
            }
            let mut mixed = Mat::zeros(bsz, d);
            for hd in 0..h {
                let mut qh = Mat::zeros(bsz, dh);
                let mut kh = Mat::zeros(bsz, dh);
                let mut vh = Mat::zeros(bsz, dh);
                let mut betah = vec![0.0f32; bsz];
                for b in 0..bsz {
                    let cols = hd * dh..(hd + 1) * dh;
                    qh.row_mut(b)
                        .copy_from_slice(&q_all.row(b)[cols.clone()]);
                    kh.row_mut(b)
                        .copy_from_slice(&k_all.row(b)[cols.clone()]);
                    vh.row_mut(b).copy_from_slice(&v_all.row(b)[cols]);
                    l2_normalize(qh.row_mut(b));
                    l2_normalize(kh.row_mut(b));
                    betah[b] = beta_all[(b, hd)];
                }
                let s0 = (li * h + hd) * bsz;
                let out =
                    mix(&mut states[s0..s0 + bsz], &qh, &kh, &vh, &betah)?;
                ensure!((out.rows, out.cols) == (bsz, dh),
                        "mix returned {}x{}, want {bsz}x{dh}", out.rows,
                        out.cols);
                for b in 0..bsz {
                    mixed.row_mut(b)[hd * dh..(hd + 1) * dh]
                        .copy_from_slice(out.row(b));
                }
            }
            let mut y = matmul(&mixed, &layer.wo);
            for (yy, xx) in y.data.iter_mut().zip(&x.data) {
                *yy += xx;
            }
            x = y;
        }
        Ok(matmul(&x, &self.lm_head).data)
    }
}

/// Pull a gradient back through row L2 normalization y = x/‖x‖:
/// dx = (g − (g·y)·y)/‖x‖, identity when the forward skipped the
/// normalization (‖x‖ ≤ 1e-12, the `l2_normalize` guard).
fn unnormalize_grad(g: &[f32], y: &[f32], norm: f32) -> Vec<f32> {
    if norm <= 1e-12 {
        return g.to_vec();
    }
    let gy = dot(g, y);
    g.iter()
        .zip(y)
        .map(|(&gi, &yi)| (gi - gy * yi) / norm)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;
    use crate::data::build_task;
    use crate::kernels::recurrent_step;

    fn tiny() -> HostModel {
        let cfg = HostModelCfg {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            chunk: 4,
        };
        HostModel::new(cfg, 7, 2).unwrap()
    }

    fn tiny_batch(model: &HostModel, seed: u64) -> Batch {
        let mut task = build_task(&DataConfig::Corpus { seed });
        let mut b = task.sample(2, 12);
        // corpus vocab is 128; fold tokens into the tiny model's vocab
        for t in b.tokens.iter_mut() {
            *t %= model.cfg.vocab as i32;
        }
        b
    }

    #[test]
    fn forward_is_deterministic_and_finite() {
        let m = tiny();
        let b = tiny_batch(&m, 1);
        let l1 = m.loss(&b).unwrap();
        let l2 = m.loss(&b).unwrap();
        assert_eq!(l1, l2);
        assert!(l1.is_finite() && l1 > 0.0);
    }

    #[test]
    fn analytic_grads_match_finite_differences() {
        let mut m = tiny();
        let b = tiny_batch(&m, 2);
        let (_, grads) = m.loss_and_grads(&b).unwrap();
        let gt: Vec<Mat> =
            grads.tensors().into_iter().cloned().collect();
        // probe a few entries in every tensor with f32 central differences;
        // ε is large-ish to keep f32 forward noise below the secant slope
        let eps = 1e-2f32;
        let n_params = gt.len();
        for pi in 0..n_params {
            let probes: Vec<usize> = {
                let n = gt[pi].data.len();
                [0, n / 3, n / 2, n - 1].iter().cloned()
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter().collect()
            };
            for idx in probes {
                let x0 = m.param_entries()[pi].1.data[idx];
                m.param_entries_mut()[pi].1.data[idx] = x0 + eps;
                let up = m.loss(&b).unwrap();
                m.param_entries_mut()[pi].1.data[idx] = x0 - eps;
                let down = m.loss(&b).unwrap();
                m.param_entries_mut()[pi].1.data[idx] = x0;
                let fd = (up - down) / (2.0 * eps);
                let a = gt[pi].data[idx];
                let name = &m.param_entries()[pi].0.clone();
                let tol = 2e-3 + 5e-2 * fd.abs().max(a.abs());
                assert!((a - fd).abs() <= tol,
                        "{name}[{idx}]: analytic {a} vs fd {fd}");
            }
        }
    }

    #[test]
    fn training_reduces_loss_on_repeated_batch() {
        let mut m = tiny();
        let b = tiny_batch(&m, 3);
        let mut opt = Optimizer::AdamW(AdamW::new());
        let first = m.loss(&b).unwrap();
        let mut last = first;
        for _ in 0..30 {
            let (loss, grads) = m.loss_and_grads(&b).unwrap();
            assert!(loss.is_finite());
            let gt = grads.tensors();
            let mut params: Vec<&mut Mat> = m
                .param_entries_mut()
                .into_iter()
                .map(|(_, p)| p)
                .collect();
            opt.step(&mut params, &gt, 1e-2);
            last = loss;
        }
        assert!(last < first * 0.7,
                "loss did not drop on a memorizable batch: {first} -> {last}");
    }

    #[test]
    fn decode_step_matches_training_forward() {
        // feeding a sequence token-by-token through decode_step with the
        // host recurrent mixer must reproduce the chunkwise training
        // forward's next-token logits
        let m = tiny();
        let b = tiny_batch(&m, 4);
        let (_, x_final) = m.forward_cached(&b).unwrap();
        let logits_train = matmul(&x_final, &m.lm_head);
        let bsz = b.batch;
        let mut states = m.decode_states(bsz);
        for t in 0..b.seq_len {
            let tokens: Vec<i32> =
                (0..bsz).map(|bi| b.token(bi, t)).collect();
            let logits = m
                .decode_step(&mut states, &tokens, |sts, q, k, v, beta| {
                    let mut out = Mat::zeros(q.rows, v.cols);
                    for (bi, st) in sts.iter_mut().enumerate() {
                        let mut row = vec![0.0f32; v.cols];
                        recurrent_step(st, q.row(bi), k.row(bi),
                                       v.row(bi), beta[bi], &mut row);
                        out.row_mut(bi).copy_from_slice(&row);
                    }
                    Ok(out)
                })
                .unwrap();
            for bi in 0..bsz {
                let want = logits_train.row(bi * b.seq_len + t);
                let got = &logits[bi * m.cfg.vocab..(bi + 1) * m.cfg.vocab];
                for (a, w) in got.iter().zip(want) {
                    let tol = 1e-3 + 1e-3 * w.abs().max(a.abs());
                    assert!((a - w).abs() < tol,
                            "token {t} seq {bi}: {a} vs {w}");
                }
            }
        }
    }

    #[test]
    fn param_entries_align_with_grad_tensors() {
        let m = tiny();
        let b = tiny_batch(&m, 5);
        let (_, grads) = m.loss_and_grads(&b).unwrap();
        let names = m.param_entries();
        let gt = grads.tensors();
        assert_eq!(names.len(), gt.len());
        for ((name, p), g) in names.iter().zip(&gt) {
            assert_eq!((p.rows, p.cols), (g.rows, g.cols), "{name}");
        }
        assert!(m.param_count() > 0);
    }
}
