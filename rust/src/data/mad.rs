//! MAD — Mechanistic Architecture Design benchmark (Poli et al. 2024),
//! the paper's Table 1: six synthetic token-manipulation probes.
//!
//! Faithful reimplementations of the task *mechanics* at this testbed's
//! scale (the MAD spec fixes the probe structure, not absolute sizes):
//!
//!   in-context recall   kv pairs then queries (recall from context)
//!   fuzzy recall        multi-token keys/values (recall with binding)
//!   noisy recall        recall with irrelevant noise tokens interleaved
//!   selective copy      reproduce content tokens, skipping noise, in order
//!   memorize            a FIXED global kv map (recall from weights)
//!   compress            reproduce the full prefix after a trigger token
//!                       (context compression probe)
//!
//! Shared token map: 0 pad, 1 separator/trigger, then task alphabets.

use super::{Batch, TaskGen};
use crate::tensor::rng::Rng;

const KEYS: usize = 16;
const VALS: usize = 16;
const NOISE: usize = 8;

fn key_tok(k: usize) -> i32 {
    2 + k as i32
}

fn val_tok(v: usize) -> i32 {
    (2 + KEYS + v) as i32
}

fn noise_tok(n: usize) -> i32 {
    (2 + KEYS + VALS + n) as i32
}

pub const VOCAB: usize = 2 + KEYS + VALS + NOISE;

pub fn build(task: &str, seed: u64) -> Box<dyn TaskGen> {
    match task {
        "in_context_recall" => Box::new(InContextRecall { rng: Rng::new(seed), noisy: false }),
        "noisy_recall" => Box::new(InContextRecall { rng: Rng::new(seed), noisy: true }),
        "fuzzy_recall" => Box::new(FuzzyRecall { rng: Rng::new(seed) }),
        "selective_copy" => Box::new(SelectiveCopy { rng: Rng::new(seed) }),
        "memorize" => Box::new(Memorize::new(seed)),
        "compress" => Box::new(Compress { rng: Rng::new(seed) }),
        other => panic!("unknown MAD task {other:?}"),
    }
}

pub const ALL_TASKS: [&str; 6] = [
    "compress", "fuzzy_recall", "in_context_recall", "memorize",
    "noisy_recall", "selective_copy",
];

// ---------------------------------------------------------------------------

pub struct InContextRecall {
    rng: Rng,
    noisy: bool,
}

impl TaskGen for InContextRecall {
    fn vocab_required(&self) -> usize {
        VOCAB
    }

    fn name(&self) -> &str {
        if self.noisy { "noisy_recall" } else { "in_context_recall" }
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        let n = ((seq_len - 2) / 4).clamp(2, KEYS); // pairs
        for b in 0..batch {
            let keys = self.rng.sample_distinct(KEYS, n);
            let vals: Vec<usize> = (0..n).map(|_| self.rng.below(VALS)).collect();
            let mut pos = 0;
            for i in 0..n {
                if self.noisy && self.rng.coin(0.3) && pos + 3 < seq_len {
                    out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
                    pos += 1;
                }
                out.set_token(b, pos, key_tok(keys[i]));
                out.set_token(b, pos + 1, val_tok(vals[i]));
                pos += 2;
            }
            out.set_token(b, pos, 1);
            pos += 1;
            while pos + 1 <= seq_len {
                if self.noisy && self.rng.coin(0.3) && pos + 2 <= seq_len {
                    out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
                    pos += 1;
                    continue;
                }
                let i = self.rng.below(n);
                out.set_token(b, pos, key_tok(keys[i]));
                out.set_token(b, pos + 1, val_tok(vals[i]));
                out.set_mask(b, pos);
                pos += 2;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------

/// Fuzzy recall: keys and values are 2-token tuples; the model must bind
/// across multi-token units.
pub struct FuzzyRecall {
    rng: Rng,
}

impl TaskGen for FuzzyRecall {
    fn vocab_required(&self) -> usize {
        VOCAB
    }

    fn name(&self) -> &str {
        "fuzzy_recall"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        let n = ((seq_len - 2) / 8).clamp(2, KEYS / 2);
        for b in 0..batch {
            // 2-token keys: (k1, k2); distinct first components
            let k1s = self.rng.sample_distinct(KEYS, n);
            let k2s: Vec<usize> = (0..n).map(|_| self.rng.below(KEYS)).collect();
            let v1s: Vec<usize> = (0..n).map(|_| self.rng.below(VALS)).collect();
            let v2s: Vec<usize> = (0..n).map(|_| self.rng.below(VALS)).collect();
            let mut pos = 0;
            for i in 0..n {
                out.set_token(b, pos, key_tok(k1s[i]));
                out.set_token(b, pos + 1, key_tok(k2s[i]));
                out.set_token(b, pos + 2, val_tok(v1s[i]));
                out.set_token(b, pos + 3, val_tok(v2s[i]));
                pos += 4;
            }
            out.set_token(b, pos, 1);
            pos += 1;
            while pos + 3 <= seq_len {
                let i = self.rng.below(n);
                out.set_token(b, pos, key_tok(k1s[i]));
                out.set_token(b, pos + 1, key_tok(k2s[i]));
                out.set_token(b, pos + 2, val_tok(v1s[i]));
                out.set_token(b, pos + 3, val_tok(v2s[i]));
                out.set_mask(b, pos + 1); // predict v1 after full key
                out.set_mask(b, pos + 2); // predict v2 after v1
                pos += 4;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------

/// Selective copy: content tokens scattered among noise; after the trigger,
/// reproduce the content tokens in order.
pub struct SelectiveCopy {
    rng: Rng,
}

impl TaskGen for SelectiveCopy {
    fn vocab_required(&self) -> usize {
        VOCAB
    }

    fn name(&self) -> &str {
        "selective_copy"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        let n_content = (seq_len / 4).clamp(2, 12);
        let prefix_len = seq_len - n_content - 1;
        for b in 0..batch {
            let content: Vec<i32> =
                (0..n_content).map(|_| val_tok(self.rng.below(VALS))).collect();
            // choose positions for content within the prefix, in order
            let mut slots = self.rng.sample_distinct(prefix_len, n_content);
            slots.sort_unstable();
            let mut ci = 0;
            for pos in 0..prefix_len {
                if ci < n_content && slots[ci] == pos {
                    out.set_token(b, pos, content[ci]);
                    ci += 1;
                } else {
                    out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
                }
            }
            out.set_token(b, prefix_len, 1); // trigger
            for (i, &c) in content.iter().enumerate() {
                out.set_token(b, prefix_len + 1 + i, c);
                out.set_mask(b, prefix_len + i);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------

/// Memorize: one FIXED random key→value map shared by every sample (drawn
/// from the task seed).  Recall must come from the weights, not the context
/// — DeltaNet's known weak spot in Table 1.
pub struct Memorize {
    map: Vec<usize>,
    rng: Rng,
}

impl Memorize {
    pub fn new(seed: u64) -> Self {
        // The fixed map is derived from the LOW 32 bits only: the train/eval
        // split bumps the high bits (see data::batcher::bump_seed), which
        // must change the sample stream but keep the memorized map — the
        // whole point of the task is recall-from-weights on unseen samples.
        let mut map_rng =
            Rng::new((seed & 0xFFFF_FFFF) ^ 0x4d45_4d4f_5249_5a45);
        let map = (0..KEYS).map(|_| map_rng.below(VALS)).collect();
        Memorize { map, rng: Rng::new(seed) }
    }
}

impl TaskGen for Memorize {
    fn vocab_required(&self) -> usize {
        VOCAB
    }

    fn name(&self) -> &str {
        "memorize"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        for b in 0..batch {
            let mut pos = 0;
            while pos + 1 <= seq_len {
                let k = self.rng.below(KEYS);
                out.set_token(b, pos, key_tok(k));
                out.set_token(b, pos + 1, val_tok(self.map[k]));
                out.set_mask(b, pos);
                pos += 2;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------

/// Compress: random prefix, trigger, then the model reproduces the entire
/// prefix (forces the state to compress the whole context).
pub struct Compress {
    rng: Rng,
}

impl TaskGen for Compress {
    fn vocab_required(&self) -> usize {
        VOCAB
    }

    fn name(&self) -> &str {
        "compress"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let m = (seq_len - 1) / 2;
        let mut out = Batch::new(batch, seq_len);
        for b in 0..batch {
            let prefix: Vec<i32> =
                (0..m).map(|_| val_tok(self.rng.below(VALS))).collect();
            for (i, &t) in prefix.iter().enumerate() {
                out.set_token(b, i, t);
            }
            out.set_token(b, m, 1);
            for (i, &t) in prefix.iter().enumerate() {
                out.set_token(b, m + 1 + i, t);
                out.set_mask(b, m + i);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_build_and_sample() {
        for task in ALL_TASKS {
            let mut g = build(task, 5);
            let b = g.sample(4, 48);
            assert!(b.masked_positions() > 0, "{task} produced no targets");
            let v = g.vocab_required() as i32;
            assert!(b.tokens.iter().all(|&t| t >= 0 && t < v), "{task}");
        }
    }

    #[test]
    fn memorize_map_consistent_across_samples() {
        let mut g = Memorize::new(3);
        let b1 = g.sample(2, 32);
        let b2 = g.sample(2, 32);
        let mut map = std::collections::HashMap::new();
        for b in [&b1, &b2] {
            for bi in 0..2 {
                for pos in 0..32 {
                    if b.mask[bi * 32 + pos] > 0.0 {
                        let k = b.token(bi, pos);
                        let v = b.token(bi, pos + 1);
                        let prev = map.insert(k, v);
                        assert!(prev.is_none() || prev == Some(v),
                                "memorize map changed");
                    }
                }
            }
        }
    }

    #[test]
    fn selective_copy_targets_match_content_order() {
        let mut g = build("selective_copy", 11);
        let b = g.sample(1, 40);
        // find trigger
        let trig = (0..40).find(|&p| b.token(0, p) == 1).unwrap();
        // content tokens in prefix (value-alphabet tokens)
        let lo = val_tok(0);
        let hi = val_tok(VALS - 1);
        let content: Vec<i32> = (0..trig)
            .map(|p| b.token(0, p))
            .filter(|&t| t >= lo && t <= hi)
            .collect();
        for (i, &c) in content.iter().enumerate() {
            assert_eq!(b.token(0, trig + 1 + i), c);
        }
    }

    #[test]
    fn compress_reproduces_prefix() {
        let mut g = build("compress", 13);
        let b = g.sample(1, 21);
        let m = 10;
        assert_eq!(b.token(0, m), 1);
        for i in 0..m {
            assert_eq!(b.token(0, i), b.token(0, m + 1 + i));
        }
    }
}
