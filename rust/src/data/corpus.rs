//! LM pretraining corpus.
//!
//! The paper trains on SlimPajama (15B/100B tokens).  Offline substitution:
//! a procedurally generated "language" with the statistical structure that
//! makes LM training meaningful — Zipfian unigram distribution, sparse
//! bigram transitions (so context helps), sentence/paragraph boundaries —
//! plus a small embedded English text used by the tokenizer tests and the
//! quickstart.  Deterministic under seed; perplexity is well-defined and
//! architecture differences show up exactly as on natural text (the model
//! must learn the transition structure).

use super::{Batch, TaskGen};
use crate::tensor::rng::Rng;

/// A sparse-bigram Markov "language" over `vocab` word ids.
///
/// Construction: each token t has a support set of `fanout` successors with
/// Zipf-distributed weights; token 0 = BOS/period splits sentences.  The
/// entropy rate is controlled by `fanout` — small enough that a trained
/// model beats the unigram baseline by a wide margin.
pub struct MarkovCorpus {
    vocab: usize,
    fanout: usize,
    /// successors[t] = (token ids, cumulative weights)
    successors: Vec<(Vec<i32>, Vec<f32>)>,
    rng: Rng,
    state: i32,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self::with_fanout(vocab, 8, seed)
    }

    pub fn with_fanout(vocab: usize, fanout: usize, seed: u64) -> Self {
        assert!(vocab >= 16);
        // language structure from the LOW 32 bits only: the train/eval split
        // bumps high bits, giving a fresh stream over the SAME language
        let mut structure_rng =
            Rng::new((seed & 0xFFFF_FFFF) ^ 0x434f_5250_5553); // "CORPUS"
        let fanout = fanout.min(vocab - 1);
        let mut successors = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let ids: Vec<i32> = structure_rng
                .sample_distinct(vocab, fanout)
                .into_iter()
                .map(|x| x as i32)
                .collect();
            // Zipfian weights over the support
            let mut cum = Vec::with_capacity(fanout);
            let mut total = 0.0f32;
            for r in 0..fanout {
                total += 1.0 / (1.0 + r as f32);
                cum.push(total);
            }
            successors.push((ids, cum));
        }
        MarkovCorpus {
            vocab,
            fanout,
            successors,
            rng: Rng::new(seed),
            state: 0,
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> i32 {
        let (ids, cum) = &self.successors[self.state as usize];
        let total = *cum.last().unwrap();
        let x = self.rng.uniform() * total;
        let idx = cum.iter().position(|&c| x <= c).unwrap_or(ids.len() - 1);
        self.state = ids[idx];
        self.state
    }

    /// The true conditional distribution's entropy (nats) — the floor any
    /// model's loss can approach on this corpus. Useful in EXPERIMENTS.md.
    pub fn entropy_rate(&self) -> f64 {
        // same Zipf weights for every state
        let mut total = 0.0f64;
        let mut h = 0.0f64;
        for r in 0..self.fanout {
            total += 1.0 / (1.0 + r as f64);
        }
        for r in 0..self.fanout {
            let p = (1.0 / (1.0 + r as f64)) / total;
            h -= p * p.ln();
        }
        h
    }
}

impl TaskGen for MarkovCorpus {
    fn vocab_required(&self) -> usize {
        self.vocab
    }

    fn name(&self) -> &str {
        "corpus"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        for b in 0..batch {
            for pos in 0..=seq_len {
                let t = self.next_token();
                out.set_token(b, pos, t);
            }
            for pos in 0..seq_len {
                out.set_mask(b, pos); // full LM loss
            }
        }
        out
    }
}

/// Small embedded English text (public-domain-style original prose) for the
/// tokenizer tests and quickstart demos.
pub const SAMPLE_TEXT: &str = "\
The delta rule updates a memory by first recalling the value bound to the \
current key, and then writing back an interpolation between the old value \
and the new one. When the writing strength reaches one, the old association \
is erased entirely; when it is zero, the memory is left untouched. A linear \
transformer that adopts this rule can forget precisely, which an additive \
memory cannot. The cost of that precision was, for a long time, sequential \
training. This library exists because the cost has been removed: products \
of generalized Householder matrices admit a compact representation, and \
with it the recurrence splits into chunks that modern hardware can chew \
through in parallel. What follows is an old idea made fast, and a fast \
idea made practical.";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_tokens_in_vocab() {
        let mut c = MarkovCorpus::new(64, 1);
        for _ in 0..10_000 {
            let t = c.next_token();
            assert!((0..64).contains(&t));
        }
    }

    #[test]
    fn transitions_are_sparse() {
        // from any state, only `fanout` distinct successors appear
        let mut c = MarkovCorpus::with_fanout(64, 4, 2);
        use std::collections::{HashMap, HashSet};
        let mut succ: HashMap<i32, HashSet<i32>> = HashMap::new();
        let mut prev = c.state;
        for _ in 0..50_000 {
            let t = c.next_token();
            succ.entry(prev).or_default().insert(t);
            prev = t;
        }
        for (_, s) in succ {
            assert!(s.len() <= 4);
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = MarkovCorpus::new(64, 5);
        let mut b = MarkovCorpus::new(64, 5);
        for _ in 0..1000 {
            assert_eq!(a.next_token(), b.next_token());
        }
    }

    #[test]
    fn batches_fully_masked() {
        let mut c = MarkovCorpus::new(64, 3);
        let b = c.sample(2, 16);
        assert_eq!(b.masked_positions(), 32);
    }

    #[test]
    fn entropy_rate_sane() {
        let c = MarkovCorpus::with_fanout(64, 8, 1);
        let h = c.entropy_rate();
        assert!(h > 0.5 && h < (8f64).ln() + 0.01, "h={h}");
    }
}
