//! Tokenization: byte-level base + a mini BPE trainer.
//!
//! The paper uses the Mistral tokenizer over SlimPajama; offline we provide
//! the same *interface*: train a BPE vocabulary on a corpus, encode text to
//! ids, decode ids to text, round-trip exactly.  Used by the text path of
//! the data tools and exercised heavily in tests; the synthetic Markov
//! corpus path bypasses it (already token ids).

use std::collections::HashMap;

/// Byte-pair-encoding tokenizer over raw bytes.
///
/// Vocabulary layout: ids 0..256 are the raw bytes; ids 256.. are merges in
/// creation order.  Encoding applies merges greedily in rank order (the
/// standard BPE inference rule).
#[derive(Debug, Clone)]
pub struct Bpe {
    /// merge list: (left id, right id) → new id = 256 + index
    merges: Vec<(u32, u32)>,
    rank: HashMap<(u32, u32), u32>,
}

impl Bpe {
    /// Byte-level tokenizer with no merges (vocab = 256).
    pub fn byte_level() -> Self {
        Bpe { merges: vec![], rank: HashMap::new() }
    }

    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    /// Train `n_merges` BPE merges on a corpus.
    pub fn train(corpus: &str, n_merges: usize) -> Self {
        let mut ids: Vec<u32> = corpus.bytes().map(|b| b as u32).collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut rank = HashMap::new();
        for step in 0..n_merges {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts.iter()
                .max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else { break };
            if cnt < 2 {
                break; // nothing worth merging
            }
            let new_id = 256 + step as u32;
            merges.push(pair);
            rank.insert(pair, step as u32);
            // apply the merge
            ids = merge_pass(&ids, pair, new_id);
        }
        Bpe { merges, rank }
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        loop {
            // find the lowest-rank applicable merge
            let mut best: Option<(u32, usize)> = None; // (rank, pos)
            for (i, w) in ids.windows(2).enumerate() {
                if let Some(&r) = self.rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((r, _)) = best else { break };
            let pair = self.merges[r as usize];
            ids = merge_pass(&ids, pair, 256 + r);
        }
        ids
    }

    pub fn decode(&self, ids: &[u32]) -> Vec<u8> {
        let mut bytes = Vec::new();
        for &id in ids {
            self.push_bytes(id, &mut bytes);
        }
        bytes
    }

    pub fn decode_string(&self, ids: &[u32]) -> String {
        String::from_utf8_lossy(&self.decode(ids)).into_owned()
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.push_bytes(l, out);
            self.push_bytes(r, out);
        }
    }
}

fn merge_pass(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SAMPLE_TEXT;

    #[test]
    fn byte_level_roundtrip() {
        let t = Bpe::byte_level();
        let ids = t.encode(SAMPLE_TEXT);
        assert_eq!(ids.len(), SAMPLE_TEXT.len());
        assert_eq!(t.decode_string(&ids), SAMPLE_TEXT);
    }

    #[test]
    fn trained_bpe_roundtrip_and_compresses() {
        let t = Bpe::train(SAMPLE_TEXT, 100);
        assert!(t.vocab_size() > 256);
        let ids = t.encode(SAMPLE_TEXT);
        assert!(ids.len() < SAMPLE_TEXT.len(), "merges should compress");
        assert_eq!(t.decode_string(&ids), SAMPLE_TEXT);
    }

    #[test]
    fn roundtrip_on_unseen_text() {
        let t = Bpe::train(SAMPLE_TEXT, 60);
        let unseen = "Chunkwise parallel training of the delta rule!";
        assert_eq!(t.decode_string(&t.encode(unseen)), unseen);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Bpe::train(SAMPLE_TEXT, 30);
        let s = "naïve façade — ∆-rule ≠ additive";
        assert_eq!(t.decode(&t.encode(s)), s.as_bytes());
    }

    #[test]
    fn merge_pass_merges_all_occurrences() {
        let ids = vec![1, 2, 1, 2, 3, 1, 2];
        let out = merge_pass(&ids, (1, 2), 99);
        assert_eq!(out, vec![99, 99, 3, 99]);
    }

    #[test]
    fn training_deterministic() {
        let a = Bpe::train(SAMPLE_TEXT, 50);
        let b = Bpe::train(SAMPLE_TEXT, 50);
        assert_eq!(a.encode(SAMPLE_TEXT), b.encode(SAMPLE_TEXT));
    }
}
