//! Data pipeline + every synthetic benchmark the paper evaluates on.
//!
//! All generators are deterministic under a seed and produce [`Batch`]es in
//! the exact layout the train/eval artifacts expect: `tokens` [B, L+1] i32
//! (inputs = tokens[:, :-1], targets = tokens[:, 1:]) and a `mask` [B, L]
//! over *target* positions that contribute to the loss / accuracy.

pub mod batcher;
pub mod corpus;
pub mod mad;
pub mod mqar;
pub mod recall;
pub mod regbench;
pub mod tokenizer;

use crate::runtime::HostValue;

/// One batch of sequences in train/eval-artifact layout.
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize, // number of TARGET positions (tokens row = L+1)
    /// [B, L+1] row-major
    pub tokens: Vec<i32>,
    /// [B, L] row-major, 1.0 where the target counts
    pub mask: Vec<f32>,
    /// Optional per-position acceptable-token sets (RegBench-style scoring:
    /// a prediction is correct if it is *any* valid continuation).
    /// Indexed [b * L + pos]; empty vec = only the literal target counts.
    pub accept: Option<Vec<Vec<i32>>>,
}

impl Batch {
    pub fn new(batch: usize, seq_len: usize) -> Self {
        Batch {
            batch,
            seq_len,
            tokens: vec![0; batch * (seq_len + 1)],
            mask: vec![0.0; batch * seq_len],
            accept: None,
        }
    }

    pub fn tokens_value(&self) -> crate::Result<HostValue> {
        HostValue::from_i32(&[self.batch, self.seq_len + 1],
                            self.tokens.clone())
    }

    pub fn mask_value(&self) -> crate::Result<HostValue> {
        HostValue::from_f32(&[self.batch, self.seq_len], self.mask.clone())
    }

    pub fn set_token(&mut self, b: usize, pos: usize, tok: i32) {
        self.tokens[b * (self.seq_len + 1) + pos] = tok;
    }

    pub fn token(&self, b: usize, pos: usize) -> i32 {
        self.tokens[b * (self.seq_len + 1) + pos]
    }

    /// Mark target position `pos` (i.e. the model must predict
    /// tokens[b][pos+1] from prefix tokens[b][..=pos]).
    pub fn set_mask(&mut self, b: usize, pos: usize) {
        self.mask[b * self.seq_len + pos] = 1.0;
    }

    pub fn masked_positions(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Score externally-computed argmax predictions ([B, L] i32) against
    /// this batch: returns (correct, total) over masked positions,
    /// honouring `accept` sets when present.
    pub fn score_preds(&self, preds: &[i32]) -> (usize, usize) {
        assert_eq!(preds.len(), self.batch * self.seq_len);
        let mut correct = 0;
        let mut total = 0;
        for b in 0..self.batch {
            for pos in 0..self.seq_len {
                let i = b * self.seq_len + pos;
                if self.mask[i] == 0.0 {
                    continue;
                }
                total += 1;
                let target = self.tokens[b * (self.seq_len + 1) + pos + 1];
                let p = preds[i];
                let ok = if let Some(acc) = &self.accept {
                    if acc[i].is_empty() { p == target } else { acc[i].contains(&p) }
                } else {
                    p == target
                };
                if ok {
                    correct += 1;
                }
            }
        }
        (correct, total)
    }
}

/// A task that can emit train/eval batches.  All synthetic benchmarks and
/// the LM corpus implement this.
pub trait TaskGen: Send {
    /// Smallest vocab the task's token ids fit in (must be ≤ artifact vocab).
    fn vocab_required(&self) -> usize;
    /// Sample a fresh batch.
    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch;
    fn name(&self) -> &str;
}

/// Build a generator from a [`crate::config::DataConfig`].
pub fn build_task(cfg: &crate::config::DataConfig) -> Box<dyn TaskGen> {
    use crate::config::DataConfig as D;
    match cfg {
        D::Corpus { seed } => Box::new(corpus::MarkovCorpus::new(128, *seed)),
        D::Mqar { num_pairs, seed } =>
            Box::new(mqar::Mqar::new(*num_pairs, *seed)),
        D::Mad { task, seed } => mad::build(task, *seed),
        D::RegBench { seed } => Box::new(regbench::RegBench::new(*seed)),
        D::Recall { style, seed } => Box::new(recall::Recall::new(style, *seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_layout() {
        let mut b = Batch::new(2, 4);
        b.set_token(1, 2, 7);
        assert_eq!(b.token(1, 2), 7);
        assert_eq!(b.tokens.len(), 2 * 5);
        b.set_mask(1, 3);
        assert_eq!(b.masked_positions(), 1);
    }

    #[test]
    fn score_preds_literal_and_accept() {
        let mut b = Batch::new(1, 3);
        // tokens: [5, 6, 7, 8]; mask target positions 0 and 2
        for (i, t) in [5, 6, 7, 8].iter().enumerate() {
            b.set_token(0, i, *t);
        }
        b.set_mask(0, 0); // target 6
        b.set_mask(0, 2); // target 8
        let (c, t) = b.score_preds(&[6, 0, 9]);
        assert_eq!((c, t), (1, 2));
        // with accept sets: position 2 also accepts 9
        let mut acc = vec![vec![]; 3];
        acc[2] = vec![8, 9];
        b.accept = Some(acc);
        let (c, t) = b.score_preds(&[6, 0, 9]);
        assert_eq!((c, t), (2, 2));
    }
}
