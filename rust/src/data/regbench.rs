//! RegBench — in-context language learning (Akyürek et al. 2024), the
//! paper's Figure 3.
//!
//! Each sequence concatenates 10–20 strings sampled from ONE random
//! probabilistic finite automaton (PFA); the model must infer the language
//! on the fly and predict continuations of the final string.  Scoring: a
//! prediction is correct if it is *any* symbol with nonzero probability
//! from the current PFA state (the benchmark's validity criterion), which
//! we express through [`Batch::accept`].
//!
//! Token map: 0 pad, 1 string separator, 2.. symbol alphabet.

use super::{Batch, TaskGen};
use crate::tensor::rng::Rng;

const MAX_SYMBOLS: usize = 18;

/// One random PFA: states × symbols → next state (partial).
#[derive(Debug, Clone)]
pub struct Pfa {
    pub n_states: usize,
    pub n_symbols: usize,
    /// trans[state] = list of (symbol, next_state); nonempty for all states
    pub trans: Vec<Vec<(usize, usize)>>,
}

impl Pfa {
    pub fn random(rng: &mut Rng) -> Self {
        let n_states = rng.range(4, 13);
        let n_symbols = rng.range(4, MAX_SYMBOLS + 1);
        let trans = (0..n_states)
            .map(|_| {
                let deg = rng.range(1, 4.min(n_symbols) + 1);
                let syms = rng.sample_distinct(n_symbols, deg);
                syms.into_iter()
                    .map(|s| (s, rng.below(n_states)))
                    .collect()
            })
            .collect();
        Pfa { n_states, n_symbols, trans }
    }

    /// Random walk of `len` symbols from state 0.
    pub fn walk(&self, len: usize, rng: &mut Rng) -> Vec<usize> {
        let mut state = 0;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            let opts = &self.trans[state];
            let (sym, next) = opts[rng.below(opts.len())];
            out.push(sym);
            state = next;
        }
        out
    }

    /// Symbols with nonzero probability from the state reached by `prefix`
    /// (walked from state 0).  Returns None if the prefix is invalid.
    pub fn valid_next(&self, prefix: &[usize]) -> Option<Vec<usize>> {
        let mut state = 0;
        for &sym in prefix {
            let next = self.trans[state].iter()
                .find(|(s, _)| *s == sym)
                .map(|(_, n)| *n)?;
            state = next;
        }
        Some(self.trans[state].iter().map(|(s, _)| *s).collect())
    }
}

pub struct RegBench {
    rng: Rng,
}

impl RegBench {
    pub fn new(seed: u64) -> Self {
        RegBench { rng: Rng::new(seed) }
    }
}

fn sym_tok(s: usize) -> i32 {
    2 + s as i32
}

impl TaskGen for RegBench {
    fn vocab_required(&self) -> usize {
        2 + MAX_SYMBOLS
    }

    fn name(&self) -> &str {
        "regbench"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        let mut accept = vec![vec![]; batch * seq_len];
        for b in 0..batch {
            let pfa = Pfa::random(&mut self.rng);
            let mut pos = 0;
            let mut cur_string: Vec<usize> = vec![];
            // fill the sequence with separator-delimited walks
            while pos + 1 <= seq_len {
                let remaining = seq_len + 1 - pos;
                if remaining < 3 {
                    break;
                }
                let len = self.rng.range(2, 9.min(remaining - 1).max(3));
                let s = pfa.walk(len, &mut self.rng);
                for (i, &sym) in s.iter().enumerate() {
                    if pos > seq_len {
                        break;
                    }
                    out.set_token(b, pos, sym_tok(sym));
                    // mark targets on continuation positions (pos-1 predicts
                    // this symbol): any valid next symbol is accepted
                    if i > 0 && pos >= 1 && pos - 1 < seq_len {
                        out.set_mask(b, pos - 1);
                        let valid = pfa.valid_next(&s[..i]).unwrap();
                        accept[b * seq_len + pos - 1] =
                            valid.into_iter().map(sym_tok).collect();
                    }
                    pos += 1;
                }
                cur_string = s;
                if pos <= seq_len {
                    out.set_token(b, pos, 1);
                    pos += 1;
                }
            }
            let _ = cur_string;
        }
        out.accept = Some(accept);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pfa_walks_are_valid() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let pfa = Pfa::random(&mut rng);
            let w = pfa.walk(10, &mut rng);
            // every prefix must be walkable and each next symbol valid
            for i in 1..w.len() {
                let valid = pfa.valid_next(&w[..i]).expect("prefix valid");
                assert!(valid.contains(&w[i]), "walk emitted invalid symbol");
            }
        }
    }

    #[test]
    fn accept_sets_contain_targets() {
        let mut g = RegBench::new(7);
        let b = g.sample(4, 64);
        let acc = b.accept.as_ref().unwrap();
        let mut checked = 0;
        for bi in 0..4 {
            for pos in 0..64 {
                let i = bi * 64 + pos;
                if b.mask[i] > 0.0 {
                    let target = b.token(bi, pos + 1);
                    assert!(acc[i].contains(&target),
                            "target must always be acceptable");
                    checked += 1;
                }
            }
        }
        assert!(checked > 20);
    }

    #[test]
    fn different_sequences_use_different_pfas() {
        // (statistically) two rows shouldn't have identical token streams
        let mut g = RegBench::new(3);
        let b = g.sample(2, 64);
        let row0: Vec<i32> = (0..65).map(|p| b.token(0, p)).collect();
        let row1: Vec<i32> = (0..65).map(|p| b.token(1, p)).collect();
        assert_ne!(row0, row1);
    }

    #[test]
    fn perfect_oracle_scores_100() {
        // predictions = literal targets must score 100% under accept sets
        let mut g = RegBench::new(5);
        let b = g.sample(2, 48);
        let mut preds = vec![0i32; 2 * 48];
        for bi in 0..2 {
            for pos in 0..48 {
                preds[bi * 48 + pos] = b.token(bi, pos + 1);
            }
        }
        let (c, t) = b.score_preds(&preds);
        assert_eq!(c, t);
        assert!(t > 0);
    }
}
