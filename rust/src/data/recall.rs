//! Recall-intensive task analogs (paper Table 2's SWDE / SQuAD / FDA).
//!
//! The real suites extract structured values from HTML (SWDE), answer
//! questions over passages (SQuAD), and pull key-value pairs out of PDFs
//! (FDA).  What they all probe is the same mechanism the paper cares
//! about: retrieving a value bound to a key seen once in a long, noisy
//! context.  These generators reproduce that structure synthetically:
//!
//!   swde   — "markup": field markers around kv pairs, heavy template noise
//!   squad  — "passage": (entity, relation, value) facts in fluent filler,
//!            question = (entity, relation), answer = value
//!   fda    — long document, few kv pairs buried at random depths, query
//!            at the very end (stresses retention over distance)
//!
//! Token map: 0 pad, 1 query marker, 2 field-open, 3 field-close,
//! then keys / values / noise alphabets.

use super::{Batch, TaskGen};
use crate::tensor::rng::Rng;

const KEYS: usize = 24;
const VALS: usize = 24;
const NOISE: usize = 16;

fn key_tok(k: usize) -> i32 {
    4 + k as i32
}

fn val_tok(v: usize) -> i32 {
    (4 + KEYS + v) as i32
}

fn noise_tok(n: usize) -> i32 {
    (4 + KEYS + VALS + n) as i32
}

pub const VOCAB: usize = 4 + KEYS + VALS + NOISE;

pub struct Recall {
    style: String,
    rng: Rng,
}

impl Recall {
    pub fn new(style: &str, seed: u64) -> Self {
        assert!(matches!(style, "swde" | "squad" | "fda"),
                "unknown recall style {style:?}");
        Recall { style: style.to_string(), rng: Rng::new(seed) }
    }
}

impl TaskGen for Recall {
    fn vocab_required(&self) -> usize {
        VOCAB
    }

    fn name(&self) -> &str {
        &self.style
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let mut out = Batch::new(batch, seq_len);
        for b in 0..batch {
            match self.style.as_str() {
                "swde" => self.sample_swde(&mut out, b, seq_len),
                "squad" => self.sample_squad(&mut out, b, seq_len),
                "fda" => self.sample_fda(&mut out, b, seq_len),
                _ => unreachable!(),
            }
        }
        out
    }
}

impl Recall {
    /// markup style: [open key value close] cells among template noise,
    /// multiple queries at the end.
    fn sample_swde(&mut self, out: &mut Batch, b: usize, seq_len: usize) {
        let n = ((seq_len / 8).clamp(2, 8)).min(KEYS);
        let keys = self.rng.sample_distinct(KEYS, n);
        let vals: Vec<usize> = (0..n).map(|_| self.rng.below(VALS)).collect();
        let query_zone = 2 * n + 1; // tokens reserved at the end
        let mut pos = 0;
        let mut i = 0;
        while pos + 4 < seq_len - query_zone && i < n {
            if self.rng.coin(0.4) {
                out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
                pos += 1;
                continue;
            }
            out.set_token(b, pos, 2); // field open
            out.set_token(b, pos + 1, key_tok(keys[i]));
            out.set_token(b, pos + 2, val_tok(vals[i]));
            out.set_token(b, pos + 3, 3); // field close
            pos += 4;
            i += 1;
        }
        let written = i;
        while pos < seq_len - query_zone {
            out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
            pos += 1;
        }
        out.set_token(b, pos, 1); // query marker
        pos += 1;
        while pos + 1 <= seq_len && written > 0 {
            let i = self.rng.below(written);
            out.set_token(b, pos, key_tok(keys[i]));
            out.set_token(b, pos + 1, val_tok(vals[i]));
            out.set_mask(b, pos);
            pos += 2;
        }
    }

    /// passage style: facts are (entity, relation, value) triples; the
    /// question repeats (entity, relation) and the answer is the value.
    fn sample_squad(&mut self, out: &mut Batch, b: usize, seq_len: usize) {
        let n = (seq_len / 10).clamp(2, 6);
        let ents = self.rng.sample_distinct(KEYS, n);
        let rels: Vec<usize> = (0..n).map(|_| self.rng.below(KEYS)).collect();
        let vals: Vec<usize> = (0..n).map(|_| self.rng.below(VALS)).collect();
        let mut pos = 0;
        let query_zone = 3 * 2 + 1;
        for i in 0..n {
            if pos + 3 >= seq_len - query_zone {
                break;
            }
            // filler "prose"
            for _ in 0..self.rng.below(3) {
                if pos + 4 < seq_len - query_zone {
                    out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
                    pos += 1;
                }
            }
            out.set_token(b, pos, key_tok(ents[i]));
            out.set_token(b, pos + 1, key_tok(rels[i]));
            out.set_token(b, pos + 2, val_tok(vals[i]));
            pos += 3;
        }
        while pos < seq_len - query_zone {
            out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
            pos += 1;
        }
        out.set_token(b, pos, 1);
        pos += 1;
        // two questions
        for _ in 0..2 {
            if pos + 2 < seq_len + 1 {
                let i = self.rng.below(n);
                out.set_token(b, pos, key_tok(ents[i]));
                out.set_token(b, pos + 1, key_tok(rels[i]));
                out.set_token(b, pos + 2, val_tok(vals[i]));
                out.set_mask(b, pos + 1); // predict value after (ent, rel)
                pos += 3;
            }
        }
    }

    /// long-document style: few pairs at random depths, single query at the
    /// very end — maximal retrieval distance.
    fn sample_fda(&mut self, out: &mut Batch, b: usize, seq_len: usize) {
        let n = 3.min(KEYS);
        let keys = self.rng.sample_distinct(KEYS, n);
        let vals: Vec<usize> = (0..n).map(|_| self.rng.below(VALS)).collect();
        let doc_len = seq_len - 3;
        // noise everywhere
        for pos in 0..doc_len {
            out.set_token(b, pos, noise_tok(self.rng.below(NOISE)));
        }
        // bury the pairs
        let mut slots = self.rng.sample_distinct(doc_len - 1, n);
        slots.sort_unstable();
        // keep pairs non-overlapping
        for w in 0..n {
            let p = slots[w].min(doc_len - 2);
            out.set_token(b, p, key_tok(keys[w]));
            out.set_token(b, p + 1, val_tok(vals[w]));
        }
        out.set_token(b, doc_len, 1);
        let i = self.rng.below(n);
        out.set_token(b, doc_len + 1, key_tok(keys[i]));
        out.set_token(b, doc_len + 2, val_tok(vals[i]));
        out.set_mask(b, doc_len + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_styles_sample_and_mask() {
        for style in ["swde", "squad", "fda"] {
            let mut g = Recall::new(style, 1);
            let b = g.sample(4, 64);
            assert!(b.masked_positions() > 0, "{style}");
            let v = g.vocab_required() as i32;
            assert!(b.tokens.iter().all(|&t| t >= 0 && t < v), "{style}");
        }
    }

    #[test]
    fn fda_query_answer_matches_buried_pair() {
        let mut g = Recall::new("fda", 2);
        let b = g.sample(8, 96);
        let lo_k = key_tok(0);
        let hi_k = key_tok(KEYS - 1);
        for bi in 0..8 {
            for pos in 0..96 {
                if b.mask[bi * 96 + pos] > 0.0 {
                    let qk = b.token(bi, pos);
                    let ans = b.token(bi, pos + 1);
                    assert!(qk >= lo_k && qk <= hi_k);
                    // find the same key earlier; its successor must be ans
                    let found = (0..pos).rev()
                        .find(|&p| b.token(bi, p) == qk)
                        .expect("query key must appear in doc");
                    assert_eq!(b.token(bi, found + 1), ans);
                }
            }
        }
    }

    #[test]
    fn swde_answers_consistent() {
        let mut g = Recall::new("swde", 3);
        let b = g.sample(4, 64);
        for bi in 0..4 {
            let mut map = std::collections::HashMap::new();
            // parse fields: token 2 starts a cell (key, value)
            for pos in 0..62 {
                if b.token(bi, pos) == 2 {
                    map.insert(b.token(bi, pos + 1), b.token(bi, pos + 2));
                }
            }
            for pos in 0..64 {
                if b.mask[bi * 64 + pos] > 0.0 {
                    let k = b.token(bi, pos);
                    assert_eq!(map[&k], b.token(bi, pos + 1));
                }
            }
        }
    }
}
