//! Multi-Query Associative Recall (MQAR; Arora et al. 2023, "Zoology") —
//! the paper's Figure 2 benchmark.
//!
//! A sequence shows N key-value pairs, then issues multiple queries: each
//! query repeats a seen key and the model must emit the associated value.
//! Linear attention with additive updates degrades as N approaches the
//! state capacity; the delta rule keeps retrieval exact.
//!
//! Token map (within `vocab_required()`):
//!   0            padding / filler
//!   1            separator between the KV section and the query section
//!   2 .. 2+K     key alphabet
//!   2+K .. 2+2K  value alphabet
//! Keys within one sequence are distinct, so each query has a unique answer.

use super::{Batch, TaskGen};
use crate::tensor::rng::Rng;

pub struct Mqar {
    pub num_pairs: usize,
    key_space: usize,
    rng: Rng,
}

impl Mqar {
    pub fn new(num_pairs: usize, seed: u64) -> Self {
        // key alphabet larger than the pair count so key identity must be
        // read from context, not memorized; capped at 48 so the full token
        // map (2 + 2·48 = 98) fits the tiny artifact vocab (128)
        Mqar {
            num_pairs,
            key_space: (num_pairs * 4).clamp(8, 48),
            rng: Rng::new(seed),
        }
    }

    fn key_tok(&self, k: usize) -> i32 {
        2 + k as i32
    }

    fn val_tok(&self, v: usize) -> i32 {
        (2 + self.key_space + v) as i32
    }
}

impl TaskGen for Mqar {
    fn vocab_required(&self) -> usize {
        2 + 2 * self.key_space
    }

    fn name(&self) -> &str {
        "mqar"
    }

    fn sample(&mut self, batch: usize, seq_len: usize) -> Batch {
        let n = self.num_pairs;
        assert!(seq_len + 1 >= 2 * n + 3, "seq too short for {n} pairs");
        let mut out = Batch::new(batch, seq_len);
        for b in 0..batch {
            // distinct keys, random values (values may repeat)
            let keys = self.rng.sample_distinct(self.key_space, n);
            let vals: Vec<usize> =
                (0..n).map(|_| self.rng.below(self.key_space)).collect();
            let mut pos = 0;
            for i in 0..n {
                out.set_token(b, pos, self.key_tok(keys[i]));
                out.set_token(b, pos + 1, self.val_tok(vals[i]));
                pos += 2;
            }
            out.set_token(b, pos, 1); // separator
            pos += 1;
            // queries fill the rest: "key value key value ..."
            while pos + 1 <= seq_len {
                let i = self.rng.below(n);
                out.set_token(b, pos, self.key_tok(keys[i]));
                out.set_token(b, pos + 1, self.val_tok(vals[i]));
                out.set_mask(b, pos); // predict the value from the key
                pos += 2;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queries_have_correct_answers() {
        let mut g = Mqar::new(4, 1);
        let b = g.sample(3, 32);
        assert!(b.masked_positions() > 0);
        for bi in 0..3 {
            // reconstruct the kv map from the first 8 tokens
            let mut map = std::collections::HashMap::new();
            for i in 0..4 {
                map.insert(b.token(bi, 2 * i), b.token(bi, 2 * i + 1));
            }
            for pos in 0..32 {
                if b.mask[bi * 32 + pos] > 0.0 {
                    let key = b.token(bi, pos);
                    let val = b.token(bi, pos + 1);
                    assert_eq!(map[&key], val, "query answer mismatch");
                }
            }
        }
    }

    #[test]
    fn keys_distinct_within_sequence() {
        let mut g = Mqar::new(8, 2);
        let b = g.sample(2, 64);
        for bi in 0..2 {
            let keys: Vec<i32> = (0..8).map(|i| b.token(bi, 2 * i)).collect();
            let mut s = keys.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
        }
    }

    #[test]
    fn vocab_bound_respected() {
        let mut g = Mqar::new(4, 3);
        let v = g.vocab_required() as i32;
        let b = g.sample(4, 40);
        assert!(b.tokens.iter().all(|&t| t >= 0 && t < v));
    }

    #[test]
    fn deterministic_under_seed() {
        let a = Mqar::new(4, 9).sample(2, 32);
        let b = Mqar::new(4, 9).sample(2, 32);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.mask, b.mask);
    }
}
