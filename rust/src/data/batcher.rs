//! Batching utilities: deterministic batch streams over a [`TaskGen`] and
//! a held-out split discipline (train stream vs eval stream drawn from
//! independently-seeded generators of the same task).

use super::{Batch, TaskGen};

/// A (train, eval) pair of generators for the same task with disjoint RNG
/// streams — the split discipline every experiment harness uses.
pub struct Split {
    pub train: Box<dyn TaskGen>,
    pub eval: Box<dyn TaskGen>,
}

impl Split {
    pub fn from_config(cfg: &crate::config::DataConfig) -> Self {
        let train = super::build_task(cfg);
        // re-seed the eval stream; only the HIGH bits change so identity
        // that tasks derive from the low bits (e.g. MAD-memorize's fixed
        // map) is shared between the splits
        let eval_cfg = bump_seed(cfg, 0x5eed << 32);
        let eval = super::build_task(&eval_cfg);
        Split { train, eval }
    }
}

fn bump_seed(cfg: &crate::config::DataConfig, delta: u64) -> crate::config::DataConfig {
    use crate::config::DataConfig as D;
    match cfg.clone() {
        D::Corpus { seed } => D::Corpus { seed: seed ^ delta },
        D::Mqar { num_pairs, seed } => D::Mqar { num_pairs, seed: seed ^ delta },
        D::Mad { task, seed } => D::Mad { task, seed: seed ^ delta },
        D::RegBench { seed } => D::RegBench { seed: seed ^ delta },
        D::Recall { style, seed } => D::Recall { style, seed: seed ^ delta },
    }
}

/// Simple prefetching batch stream (synchronous; the PJRT step dominates,
/// generation is micro-seconds — kept synchronous after profiling showed
/// no win from a thread, see EXPERIMENTS.md §Perf).
pub struct BatchStream<'a> {
    gen: &'a mut dyn TaskGen,
    batch: usize,
    seq_len: usize,
}

impl<'a> BatchStream<'a> {
    pub fn new(gen: &'a mut dyn TaskGen, batch: usize, seq_len: usize) -> Self {
        BatchStream { gen, batch, seq_len }
    }
}

impl Iterator for BatchStream<'_> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        Some(self.gen.sample(self.batch, self.seq_len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;

    #[test]
    fn split_streams_differ() {
        let split = Split::from_config(
            &DataConfig::Mqar { num_pairs: 4, seed: 1 });
        let mut tr = split.train;
        let mut ev = split.eval;
        let a = tr.sample(2, 32);
        let b = ev.sample(2, 32);
        assert_ne!(a.tokens, b.tokens, "train and eval must not coincide");
    }

    #[test]
    fn memorize_split_shares_the_map() {
        // MAD-memorize must use the SAME fixed map in train and eval (the
        // point is recall-from-weights on fresh samples)
        let split = Split::from_config(
            &DataConfig::Mad { task: "memorize".into(), seed: 7 });
        let mut tr = split.train;
        let mut ev = split.eval;
        let a = tr.sample(4, 32);
        let b = ev.sample(4, 32);
        assert_ne!(a.tokens, b.tokens, "streams must differ");
        // but key→value bindings must agree across the splits
        let mut map = std::collections::HashMap::new();
        for batch in [&a, &b] {
            for bi in 0..4 {
                for pos in 0..32 {
                    if batch.mask[bi * 32 + pos] > 0.0 {
                        let k = batch.token(bi, pos);
                        let v = batch.token(bi, pos + 1);
                        let prev = map.insert(k, v);
                        assert!(prev.is_none() || prev == Some(v),
                                "map diverged between splits");
                    }
                }
            }
        }
    }

    #[test]
    fn stream_yields_batches() {
        let mut gen = crate::data::mqar::Mqar::new(4, 2);
        let batches: Vec<_> =
            BatchStream::new(&mut gen, 2, 32).take(3).collect();
        assert_eq!(batches.len(), 3);
        assert!(batches.iter().all(|b| b.masked_positions() > 0));
        assert_ne!(batches[0].tokens, batches[1].tokens);
    }
}
