//! [`InstrumentedBackend`]: a decorator that gives any [`Backend`] —
//! PJRT or host — `backend.*` spans and call counters for free.
//!
//! `select_kernel_backend` wraps its selection in this decorator, so every
//! harness driving a `Box<dyn Backend>` shows up in traces at the backend
//! boundary without per-implementation instrumentation.  While tracing is
//! disabled the wrapper costs one relaxed atomic load plus one counter
//! increment per call — all calls here are coarse (per batch / per token),
//! never per chunk.

use std::sync::OnceLock;

use crate::data::Batch;
use crate::obs::{self, metrics::{counter, Counter}};
use crate::runtime::HostValue;
use crate::tensor::Mat;

use super::backend::Backend;
use super::host::KernelForm;

struct BackendCounters {
    runs: &'static Counter,
    prefills: &'static Counter,
    decode_steps: &'static Counter,
    train_steps: &'static Counter,
}

fn backend_counters() -> &'static BackendCounters {
    static M: OnceLock<BackendCounters> = OnceLock::new();
    M.get_or_init(|| BackendCounters {
        runs: counter("backend.run_calls"),
        prefills: counter("backend.prefill_calls"),
        decode_steps: counter("backend.decode_steps"),
        train_steps: counter("backend.train_steps"),
    })
}

fn shape_args(q: &HostValue) -> Vec<(&'static str, f64)> {
    match q.shape() {
        [b, l, d] => {
            vec![("B", *b as f64), ("L", *l as f64), ("D", *d as f64)]
        }
        _ => Vec::new(),
    }
}

/// Wraps an inner backend, adding a span + counter around each trait
/// operation.  `name()` passes through so callers that branch on the
/// backend identity ("host" / "pjrt") are unaffected.
pub struct InstrumentedBackend {
    inner: Box<dyn Backend>,
}

impl InstrumentedBackend {
    pub fn new(inner: Box<dyn Backend>) -> Self {
        InstrumentedBackend { inner }
    }

    /// Unwrap back to the inner backend.
    pub fn into_inner(self) -> Box<dyn Backend> {
        self.inner
    }
}

impl Backend for InstrumentedBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run(&self, form: KernelForm, q: &HostValue, k: &HostValue,
           v: &HostValue, beta: &HostValue)
           -> crate::Result<(HostValue, HostValue)> {
        let _sp = obs::trace::span_with("backend.run", || shape_args(q));
        backend_counters().runs.inc();
        self.inner.run(form, q, k, v, beta)
    }

    fn run_with_chunk(&self, form: KernelForm, chunk: usize, q: &HostValue,
                      k: &HostValue, v: &HostValue, beta: &HostValue)
                      -> crate::Result<(HostValue, HostValue)> {
        let _sp = obs::trace::span_with("backend.run_with_chunk", || {
            let mut args = shape_args(q);
            args.push(("chunk", chunk as f64));
            args
        });
        backend_counters().runs.inc();
        self.inner.run_with_chunk(form, chunk, q, k, v, beta)
    }

    fn prefill(&self, q: &HostValue, k: &HostValue, v: &HostValue,
               beta: &HostValue) -> crate::Result<Vec<Mat>> {
        let _sp = obs::trace::span_with("backend.prefill",
                                        || shape_args(q));
        backend_counters().prefills.inc();
        self.inner.prefill(q, k, v, beta)
    }

    fn decode_step(&self, states: &mut [Mat], q: &Mat, k: &Mat, v: &Mat,
                   beta: &[f32]) -> crate::Result<Mat> {
        let _sp = obs::trace::span_with("backend.decode_step", || {
            vec![("B", states.len() as f64)]
        });
        backend_counters().decode_steps.inc();
        self.inner.decode_step(states, q, k, v, beta)
    }

    fn train_step(&mut self, batch: &Batch, lr: f32) -> crate::Result<f32> {
        let _sp = obs::trace::span_with("backend.train_step", || {
            vec![("B", batch.batch as f64), ("L", batch.seq_len as f64)]
        });
        backend_counters().train_steps.inc();
        self.inner.train_step(batch, lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::HostKernelBackend;
    use crate::reference::random_problem;

    #[test]
    fn wrapper_preserves_name_and_results() {
        let inner: Box<dyn Backend> =
            Box::new(HostKernelBackend::new(2, 8));
        let wrapped = InstrumentedBackend::new(inner);
        assert_eq!(wrapped.name(), "host");

        let (b, l, d) = (2usize, 16usize, 4usize);
        let mut q_all = vec![0f32; b * l * d];
        let mut k_all = vec![0f32; b * l * d];
        let mut v_all = vec![0f32; b * l * d];
        let mut beta_all = vec![0f32; b * l];
        for bi in 0..b {
            let (q, k, v, beta) = random_problem(l, d, d, bi as u64);
            q_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&q.data);
            k_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&k.data);
            v_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&v.data);
            beta_all[bi * l..(bi + 1) * l].copy_from_slice(&beta);
        }
        let qh = HostValue::from_f32(&[b, l, d], q_all).unwrap();
        let kh = HostValue::from_f32(&[b, l, d], k_all).unwrap();
        let vh = HostValue::from_f32(&[b, l, d], v_all).unwrap();
        let bh = HostValue::from_f32(&[b, l], beta_all).unwrap();

        let runs_before = backend_counters().runs.get();
        let (o1, s1) = wrapped
            .run(KernelForm::Chunkwise, &qh, &kh, &vh, &bh)
            .unwrap();
        let direct = HostKernelBackend::new(2, 8);
        let (o2, s2) = direct
            .run(KernelForm::Chunkwise, &qh, &kh, &vh, &bh)
            .unwrap();
        assert_eq!(o1.as_f32().unwrap(), o2.as_f32().unwrap());
        assert_eq!(s1.as_f32().unwrap(), s2.as_f32().unwrap());
        assert!(backend_counters().runs.get() > runs_before);
    }
}
