//! The training coordinator.
//!
//! Two engines behind one interface:
//!
//! * **Artifact** — the compiled `.train` artifact driven step by step
//!   through PJRT: the carried state (params / AdamW moments) lives in XLA
//!   literals, per-step inputs (tokens, mask, lr, step) are written into
//!   pre-allocated literals with `copy_raw_from` (no reallocation on the
//!   hot path), carried outputs are *moved* back into the input slots.
//! * **Host** — the pure-Rust fallback used when no PJRT plugin is linked
//!   in or the `.train` artifact is absent: a `model::HostModel` (chunkwise
//!   forward + hand-derived backward) stepped with host AdamW, routed
//!   through `coordinator::Backend::train_step`.  Only DeltaNet artifacts
//!   fall back — other architectures have no host implementation, and
//!   silently substituting one would fake their numbers.
//!
//! Both engines share the training loop, the evaluation protocol, and the
//! DNCK1 checkpoint container.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use xla::Literal;

use crate::util::error::Context;
use crate::{bail, ensure};

use crate::config::RunConfig;
use crate::data::{Batch, TaskGen};
use crate::kernels::default_threads;
use crate::metrics::{RunLog, StepRecord, Throughput};
use crate::model::{HostModel, HostModelCfg};
use crate::obs;
use crate::runtime::{Executable, HostValue, Manifest, Role, Runtime};

use super::backend::{host_training_backend, Backend};
use super::host::{HostKernelBackend, StepBreakdown};

/// Summary of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    /// Loss of the first/last recorded step; `None` when no steps ran.
    pub first_loss: Option<f32>,
    pub final_loss: Option<f32>,
    pub tokens_per_sec: f64,
    pub elapsed_secs: f64,
    pub evals: Vec<(usize, EvalOutcome)>,
}

/// One evaluation outcome.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutcome {
    /// mean masked next-token NLL (nats)
    pub nll: f64,
    /// exp(nll)
    pub ppl: f64,
    /// masked argmax accuracy in [0,1] (accept-set aware)
    pub accuracy: f64,
}

pub struct Trainer {
    engine: Engine,
    step: usize,
    /// fwd/bwd/opt split of the most recent step (host engine only — the
    /// artifact engine's phases live inside one compiled XLA program).
    last_breakdown: Option<StepBreakdown>,
    /// Health monitor for the artifact engine (the host engine's monitor
    /// lives inside `HostKernelBackend` where it can drop the optimizer
    /// update; the compiled artifact fuses the update into the program,
    /// so here `skip_step` degrades to a warning).
    health: obs::health::HealthMonitor,
    pub batch: usize,
    pub seq_len: usize,
}

enum Engine {
    Artifact(ArtifactTrainer),
    Host(HostTrainer),
}

struct ArtifactTrainer {
    train_exe: Arc<Executable>,
    eval_exe: Option<Arc<Executable>>,
    /// full train-artifact input vector (literals, reused across steps)
    inputs: Vec<Literal>,
    /// output index → input index for carried tensors
    carry: Vec<(usize, usize)>,
    idx_step: usize,
    idx_lr: usize,
    idx_tokens: usize,
    idx_mask: usize,
}

struct HostTrainer {
    /// Host kernel backend with the model + AdamW state attached.
    backend: HostKernelBackend,
}

impl Trainer {
    /// Load `<artifact>.train` (and `.eval` if present) and initialize
    /// parameters from the manifest under `seed`.  When the PJRT backend
    /// or the `.train` artifact is unavailable and the artifact names a
    /// DeltaNet model, falls back to the host training engine.
    pub fn new(runtime: &Runtime, artifact: &str, seed: u64)
               -> crate::Result<Self> {
        let artifact_ready = Runtime::backend_available()
            && runtime.has_artifact(&format!("{artifact}.train"));
        if !artifact_ready && artifact.starts_with("deltanet") {
            return Self::new_host(runtime, artifact, seed);
        }
        Self::new_artifact(runtime, artifact, seed)
    }

    fn new_artifact(runtime: &Runtime, artifact: &str, seed: u64)
                    -> crate::Result<Self> {
        let train_exe = runtime.load(&format!("{artifact}.train"))?;
        let eval_exe = if runtime.has_artifact(&format!("{artifact}.eval")) {
            Some(runtime.load(&format!("{artifact}.eval"))?)
        } else {
            None
        };

        let man = &train_exe.manifest;
        let host_inputs = train_exe.init_inputs(seed)?;
        let inputs: Vec<Literal> = host_inputs.iter()
            .map(|v| v.to_literal())
            .collect::<crate::Result<_>>()?;

        let carry: Vec<(usize, usize)> =
            man.carry_map().into_iter().collect();
        let idx_step = man.input_index("step")?;
        let idx_lr = man.input_index("lr")?;
        let idx_tokens = man.input_index("tokens")?;
        let idx_mask = man.input_index("mask")?;
        let (batch, seq_len) = (man.batch, man.seq_len);

        Ok(Trainer {
            engine: Engine::Artifact(ArtifactTrainer {
                train_exe,
                eval_exe,
                inputs,
                carry,
                idx_step,
                idx_lr,
                idx_tokens,
                idx_mask,
            }),
            step: 0,
            last_breakdown: None,
            health: obs::health::HealthMonitor::from_env(),
            batch,
            seq_len,
        })
    }

    /// Host engine: mirror the artifact's shapes when its manifest is on
    /// disk (only the JSON is needed, not the HLO); default to the tiny
    /// preset otherwise.
    fn new_host(runtime: &Runtime, artifact: &str, seed: u64)
                -> crate::Result<Self> {
        let man_path = runtime.artifacts_dir()
            .join(format!("{artifact}.train.manifest.json"));
        let (cfg, batch, seq_len) = if man_path.exists() {
            let man = Manifest::load(&man_path)?;
            let c = man.config.as_ref()
                .context("train manifest missing model config")?;
            (HostModelCfg {
                vocab: c.vocab_size,
                d_model: c.d_model,
                n_layers: c.n_layers,
                n_heads: c.n_heads,
                chunk: c.chunk_size.max(1),
            }, man.batch, man.seq_len)
        } else {
            (HostModelCfg::tiny(), 8, 64)
        };
        let model = HostModel::new(cfg, seed, default_threads())?;
        Ok(Trainer {
            engine: Engine::Host(HostTrainer {
                backend: host_training_backend(model),
            }),
            step: 0,
            last_breakdown: None,
            health: obs::health::HealthMonitor::from_env(),
            batch,
            seq_len,
        })
    }

    /// Which engine is training: "pjrt" (artifact) or "host".
    pub fn backend_name(&self) -> &'static str {
        match &self.engine {
            Engine::Artifact(_) => "pjrt",
            Engine::Host(_) => "host",
        }
    }

    /// The train artifact's manifest (None on the host engine).
    pub fn manifest(&self) -> Option<&Manifest> {
        match &self.engine {
            Engine::Artifact(a) => Some(&a.train_exe.manifest),
            Engine::Host(_) => None,
        }
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Phase breakdown of the most recent [`Self::train_step`], when the
    /// engine reports one (host only).
    pub fn last_breakdown(&self) -> Option<StepBreakdown> {
        self.last_breakdown
    }

    pub fn param_count(&self) -> usize {
        match &self.engine {
            Engine::Artifact(a) => a.train_exe.manifest.param_count(),
            Engine::Host(h) => {
                h.backend.model().map(|m| m.param_count()).unwrap_or(0)
            }
        }
    }

    /// Run one optimizer step on a batch; returns the loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f64) -> crate::Result<f32> {
        if batch.batch != self.batch || batch.seq_len != self.seq_len {
            bail!("batch shape {}x{} != trainer {}x{}",
                  batch.batch, batch.seq_len, self.batch, self.seq_len);
        }
        self.step += 1;
        let _sp = obs::trace::span_with("train.step", || {
            vec![("step", self.step as f64), ("B", self.batch as f64),
                 ("L", self.seq_len as f64)]
        });
        let loss = match &mut self.engine {
            Engine::Artifact(a) => {
                self.last_breakdown = None;
                let loss = a.train_step(self.step, batch, lr)?;
                // the compiled step already applied its update, so Skip
                // cannot drop it — only Abort stops the run here
                if let obs::health::Verdict::Abort(issue) =
                    self.health.observe(loss, None)
                {
                    bail!("training health abort at step {}: {issue}",
                          self.step);
                }
                obs::flight::record(
                    obs::flight::EventKind::Step,
                    "train.step",
                    &[("step", self.step as f64), ("loss", loss as f64)],
                );
                loss
            }
            // the host path IS the Backend trait's training surface; the
            // detailed entry point records train.* metrics, runs its own
            // health monitor, and emits the flight step event
            Engine::Host(h) => {
                let (loss, bd) =
                    h.backend.train_step_detailed(batch, lr as f32)?;
                self.last_breakdown = Some(bd);
                loss
            }
        };
        Ok(loss)
    }

    /// Full training loop per the run config; evaluates on `eval_task` at
    /// the configured cadence.
    pub fn train(&mut self, cfg: &RunConfig, task: &mut dyn TaskGen,
                 eval_task: Option<&mut dyn TaskGen>)
                 -> crate::Result<TrainReport> {
        let mut log = RunLog::new(cfg.log_path.as_deref())?;
        let mut tp = Throughput::new();
        let mut first_loss = None;
        let mut evals = vec![];
        let mut eval_task = eval_task;

        for s in 0..cfg.steps {
            let lr = cfg.lr.at(s);
            let batch = task.sample(self.batch, self.seq_len);
            let t0 = Instant::now();
            let loss = self.train_step(&batch, lr)?;
            let step_ms = t0.elapsed().as_secs_f64() * 1e3;
            obs::metrics::histogram("train.step_ms").record(step_ms);
            // periodic counter snapshots give the flight recorder a
            // progress trail even when the ring has wrapped past the
            // early steps
            if s % 16 == 0 {
                obs::flight::record_counters(&[
                    "train.steps", "train.tokens",
                    "kernels.forward.flops", "pool.job_panics",
                ]);
            }
            first_loss.get_or_insert(loss);
            tp.record_step(self.batch * self.seq_len);
            let bd = self.last_breakdown;
            log.log(StepRecord {
                step: s,
                loss,
                lr,
                tokens_per_sec: tp.tokens_per_sec(),
                elapsed_secs: tp.elapsed_secs(),
                grad_norm: bd.map(|b| b.grad_norm as f64),
                forward_ms: bd.map(|b| b.forward_ms),
                backward_ms: bd.map(|b| b.backward_ms),
                optimizer_ms: bd.map(|b| b.optimizer_ms),
                step_tokens_per_sec: bd.map(|b| b.tokens_per_sec),
                gflops: bd.map(|b| b.gflops),
            })?;
            let do_eval = cfg.eval_every > 0 && (s + 1) % cfg.eval_every == 0;
            if do_eval {
                if let Some(et) = eval_task.as_deref_mut() {
                    let out = self.evaluate(et, cfg.eval_batches)?;
                    evals.push((s + 1, out));
                }
            }
        }
        if let Some(et) = eval_task.as_deref_mut() {
            let out = self.evaluate(et, cfg.eval_batches)?;
            evals.push((cfg.steps, out));
        }
        if let Some(path) = &cfg.checkpoint_path {
            self.save_checkpoint(path)?;
        }
        log.flush()?;
        Ok(TrainReport {
            steps: cfg.steps,
            first_loss,
            final_loss: log.recent_loss(5),
            tokens_per_sec: tp.tokens_per_sec(),
            elapsed_secs: tp.elapsed_secs(),
            evals,
        })
    }

    /// Evaluate current params on `n_batches` from `task`.
    pub fn evaluate(&self, task: &mut dyn TaskGen, n_batches: usize)
                    -> crate::Result<EvalOutcome> {
        let _sp = obs::trace::span_with("train.eval", || {
            vec![("batches", n_batches as f64)]
        });
        match &self.engine {
            Engine::Artifact(a) => {
                a.evaluate(task, n_batches)
            }
            Engine::Host(h) => {
                let model = h.backend.model()
                    .context("host trainer has no model")?;
                let mut nll_sum = 0.0f64;
                let mut mask_sum = 0.0f64;
                let mut correct = 0usize;
                let mut total = 0usize;
                for _ in 0..n_batches.max(1) {
                    let batch = task.sample(self.batch, self.seq_len);
                    let (nll, ms, preds) = model.evaluate_batch(&batch)?;
                    let (c, t) = batch.score_preds(&preds);
                    nll_sum += nll;
                    mask_sum += ms;
                    correct += c;
                    total += t;
                }
                let nll = nll_sum / mask_sum.max(1.0);
                Ok(EvalOutcome {
                    nll,
                    ppl: nll.exp(),
                    accuracy: correct as f64 / total.max(1) as f64,
                })
            }
        }
    }

    /// Current parameters as (name, HostValue) pairs (names without the
    /// "params." prefix).
    pub fn params(&self) -> crate::Result<Vec<(String, HostValue)>> {
        match &self.engine {
            Engine::Artifact(a) => {
                let man = &a.train_exe.manifest;
                man.inputs_with_role(Role::Param).into_iter()
                    .map(|(i, t)| {
                        let name = t.name.strip_prefix("params.")
                            .unwrap_or(&t.name).to_string();
                        Ok((name, HostValue::from_literal(&a.inputs[i])?))
                    })
                    .collect()
            }
            Engine::Host(h) => {
                let model = h.backend.model()
                    .context("host trainer has no model")?;
                model.param_entries().into_iter()
                    .map(|(name, m)| {
                        Ok((name,
                            HostValue::from_f32(&[m.rows, m.cols],
                                                m.data.clone())?))
                    })
                    .collect()
            }
        }
    }

    /// Param literals by full name (for wiring into decode engines).
    /// Artifact engine only — the host decode path owns its model.
    pub fn param_literals(&self) -> crate::Result<Vec<(String, Literal)>> {
        let Engine::Artifact(a) = &self.engine else {
            bail!("host trainer has no artifact param literals");
        };
        let man = &a.train_exe.manifest;
        man.inputs_with_role(Role::Param).into_iter()
            .map(|(i, t)| Ok((t.name.clone(), a.inputs[i].clone())))
            .collect()
    }

    /// Save params (+ moments on the artifact engine) to a checkpoint.
    ///
    /// Format (own binary container — the vendored xla crate's npy writer
    /// rejects non-u8 literals): magic "DNCK1\n", then per tensor a header
    /// line `name\tndims\tdims...` followed by raw f32 LE.  Host
    /// checkpoints hold parameters only (AdamW moments restart on load).
    pub fn save_checkpoint(&self, path: &Path) -> crate::Result<()> {
        let mut w = Dnck1Writer::create(path)?;
        match &self.engine {
            Engine::Artifact(a) => {
                let man = &a.train_exe.manifest;
                for (i, t) in man.inputs.iter().enumerate() {
                    if matches!(t.role,
                                Role::Param | Role::OptM | Role::OptV) {
                        let data = a.inputs[i].to_vec::<f32>()?;
                        w.tensor(&t.name, &t.shape, &data)?;
                    }
                }
            }
            Engine::Host(h) => {
                let model = h.backend.model()
                    .context("host trainer has no model")?;
                for (name, m) in model.param_entries() {
                    w.tensor(&name, &[m.rows, m.cols], &m.data)?;
                }
            }
        }
        Ok(())
    }

    /// Restore params (and moments, on the artifact engine) from a
    /// checkpoint written by [`Self::save_checkpoint`].
    pub fn load_checkpoint(&mut self, path: &Path) -> crate::Result<()> {
        let by_name = read_dnck1(path)?;
        match &mut self.engine {
            Engine::Artifact(a) => {
                let man = a.train_exe.manifest.clone();
                for (i, t) in man.inputs.iter().enumerate() {
                    if matches!(t.role,
                                Role::Param | Role::OptM | Role::OptV) {
                        let data = by_name.get(&t.name)
                            .with_context(|| format!(
                                "checkpoint missing {}", t.name))?;
                        ensure!(data.len() == t.element_count(),
                                "size mismatch for {}", t.name);
                        a.inputs[i].copy_raw_from(data)?;
                    }
                }
            }
            Engine::Host(h) => {
                let model = h.backend.model_mut()
                    .context("host trainer has no model")?;
                for (name, m) in model.param_entries_mut() {
                    // accept both host names and artifact "params." names
                    let data = by_name.get(&name)
                        .or_else(|| by_name.get(&format!("params.{name}")))
                        .with_context(|| format!(
                            "checkpoint missing {name}"))?;
                    ensure!(data.len() == m.data.len(),
                            "size mismatch for {name}");
                    m.data.copy_from_slice(data);
                }
            }
        }
        Ok(())
    }
}

impl ArtifactTrainer {
    fn train_step(&mut self, step: usize, batch: &Batch, lr: f64)
                  -> crate::Result<f32> {
        self.inputs[self.idx_step].copy_raw_from(&[step as f32])?;
        self.inputs[self.idx_lr].copy_raw_from(&[lr as f32])?;
        self.inputs[self.idx_tokens].copy_raw_from(&batch.tokens)?;
        self.inputs[self.idx_mask].copy_raw_from(&batch.mask)?;

        let mut outs = self.train_exe.execute(&self.inputs)?;
        let man = &self.train_exe.manifest;
        let loss_i = man.output_index("loss")?;
        let loss = outs[loss_i].to_vec::<f32>()?[0];
        // move carried outputs into the input slots (no copy)
        for &(o, i) in &self.carry {
            self.inputs[i] =
                std::mem::replace(&mut outs[o], Literal::scalar(0f32));
        }
        Ok(loss)
    }

    fn evaluate(&self, task: &mut dyn TaskGen, n_batches: usize)
                -> crate::Result<EvalOutcome> {
        let eval_exe = self.eval_exe.as_ref()
            .context("no .eval artifact for this model")?;
        let eman = &eval_exe.manifest;
        let (eb, el) = (eman.batch, eman.seq_len);

        // map current param literals (train inputs) onto eval inputs by name
        let tman = &self.train_exe.manifest;
        let mut by_name: HashMap<&str, usize> = HashMap::new();
        for (i, t) in tman.inputs.iter().enumerate() {
            if t.role == Role::Param {
                by_name.insert(t.name.as_str(), i);
            }
        }

        // build the arg vector ONCE (params cloned a single time, not per
        // batch — §Perf: this was ~30% of eval wall at tiny scale), then
        // overwrite only the data slots per batch
        let mut args: Vec<Literal> = Vec::with_capacity(eman.inputs.len());
        let mut idx_tokens = None;
        let mut idx_mask = None;
        for (ei, spec) in eman.inputs.iter().enumerate() {
            match spec.role {
                Role::Param => {
                    let &i = by_name.get(spec.name.as_str())
                        .with_context(|| format!("missing param {}", spec.name))?;
                    args.push(self.inputs[i].clone());
                }
                Role::Data if spec.name == "tokens" => {
                    idx_tokens = Some(ei);
                    args.push(Literal::create_from_shape(
                        xla::PrimitiveType::S32, &spec.shape));
                }
                Role::Data if spec.name == "mask" => {
                    idx_mask = Some(ei);
                    args.push(Literal::create_from_shape(
                        xla::PrimitiveType::F32, &spec.shape));
                }
                _ => bail!("unexpected eval input {}", spec.name),
            }
        }
        let idx_tokens = idx_tokens.context("eval artifact missing tokens")?;
        let idx_mask = idx_mask.context("eval artifact missing mask")?;

        let mut nll_sum = 0.0f64;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut mask_sum = 0.0f64;
        for _ in 0..n_batches.max(1) {
            let batch = task.sample(eb, el);
            args[idx_tokens].copy_raw_from(&batch.tokens)?;
            args[idx_mask].copy_raw_from(&batch.mask)?;
            let outs = eval_exe.execute(&args)?;
            let nll = outs[eman.output_index("nll_sum")?].to_vec::<f32>()?[0];
            let preds = outs[eman.output_index("preds")?].to_vec::<i32>()?;
            let (c, t) = batch.score_preds(&preds);
            nll_sum += nll as f64;
            correct += c;
            total += t;
            mask_sum += batch.mask.iter().map(|&m| m as f64).sum::<f64>();
        }
        let nll = nll_sum / mask_sum.max(1.0);
        Ok(EvalOutcome {
            nll,
            ppl: nll.exp(),
            accuracy: correct as f64 / total.max(1) as f64,
        })
    }
}

/// Streaming DNCK1 checkpoint writer shared by both engines.
struct Dnck1Writer {
    f: std::io::BufWriter<std::fs::File>,
}

impl Dnck1Writer {
    fn create(path: &Path) -> crate::Result<Self> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"DNCK1\n")?;
        Ok(Dnck1Writer { f })
    }

    fn tensor(&mut self, name: &str, shape: &[usize], data: &[f32])
              -> crate::Result<()> {
        use std::io::Write;
        let dims: Vec<String> =
            shape.iter().map(|d| d.to_string()).collect();
        writeln!(self.f, "{}\t{}\t{}", name, shape.len(), dims.join("\t"))?;
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                data.as_ptr() as *const u8, data.len() * 4)
        };
        self.f.write_all(bytes)?;
        Ok(())
    }
}

/// Read a DNCK1 checkpoint into name → f32 data.
fn read_dnck1(path: &Path) -> crate::Result<HashMap<String, Vec<f32>>> {
    use std::io::{BufRead, Read};
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?);
    let mut magic = String::new();
    r.read_line(&mut magic)?;
    if magic.trim_end() != "DNCK1" {
        bail!("{} is not a deltanet checkpoint", path.display());
    }
    let mut by_name: HashMap<String, Vec<f32>> = HashMap::new();
    loop {
        let mut header = String::new();
        if r.read_line(&mut header)? == 0 {
            break;
        }
        let parts: Vec<&str> = header.trim_end().split('\t').collect();
        if parts.len() < 2 {
            bail!("corrupt checkpoint header {header:?}");
        }
        let name = parts[0].to_string();
        let ndims: usize = parts[1].parse()?;
        if parts.len() != 2 + ndims {
            bail!("corrupt dims in header {header:?}");
        }
        let n: usize = parts[2..].iter()
            .map(|d| d.parse::<usize>().unwrap_or(0))
            .product::<usize>().max(1);
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        by_name.insert(name, data);
    }
    Ok(by_name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, LrSchedule};
    use crate::data::build_task;

    fn host_trainer() -> Trainer {
        // no artifacts dir on disk → host fallback regardless of plugin
        let runtime = Runtime::new("definitely-missing-artifacts").unwrap();
        Trainer::new(&runtime, "deltanet_tiny", 11).unwrap()
    }

    #[test]
    fn host_fallback_engages_for_deltanet_only() {
        let runtime = Runtime::new("definitely-missing-artifacts").unwrap();
        let t = Trainer::new(&runtime, "deltanet_tiny", 1).unwrap();
        assert_eq!(t.backend_name(), "host");
        assert!(t.manifest().is_none());
        assert!(t.param_count() > 0);
        // non-deltanet archs must NOT silently substitute the host model
        assert!(Trainer::new(&runtime, "mamba2_tiny", 1).is_err());
    }

    #[test]
    fn host_training_reduces_mqar_loss() {
        let mut t = host_trainer();
        let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 5 });
        let sched = LrSchedule::Constant { lr: 1e-2 };
        let mut first = None;
        let mut last = 0.0f32;
        for s in 0..25 {
            let b = task.sample(t.batch, t.seq_len);
            let loss = t.train_step(&b, sched.at(s)).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert_eq!(t.step_count(), 25);
        assert!(last < first.unwrap(),
                "host loss did not drop: {first:?} -> {last}");
        let e = t.evaluate(task.as_mut(), 2).unwrap();
        assert!(e.nll.is_finite() && e.ppl > 0.0);
    }

    #[test]
    fn host_checkpoint_roundtrip_restores_params() {
        let dir = std::env::temp_dir().join("deltanet_trainer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("host_ckpt.dnck");

        let mut a = host_trainer();
        let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 5 });
        for _ in 0..3 {
            let b = task.sample(a.batch, a.seq_len);
            a.train_step(&b, 1e-3).unwrap();
        }
        a.save_checkpoint(&path).unwrap();
        let trained = a.params().unwrap();

        let mut b = host_trainer();
        b.load_checkpoint(&path).unwrap();
        let restored = b.params().unwrap();
        assert_eq!(trained.len(), restored.len());
        for ((na, va), (nb, vb)) in trained.iter().zip(&restored) {
            assert_eq!(na, nb);
            assert_eq!(va.as_f32().unwrap(), vb.as_f32().unwrap(), "{na}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn batch_shape_mismatch_rejected() {
        let mut t = host_trainer();
        let mut task = build_task(&DataConfig::Mqar { num_pairs: 4, seed: 5 });
        let b = task.sample(2, 16); // wrong shape vs trainer's 8x64
        assert!(t.train_step(&b, 1e-3).is_err());
    }
}
