//! The training coordinator.
//!
//! Holds the carried state (params / AdamW moments) as XLA literals and
//! drives the compiled `.train` artifact step by step: per-step inputs
//! (tokens, mask, lr, step) are written into pre-allocated literals with
//! `copy_raw_from` (no reallocation on the hot path), carried outputs are
//! *moved* back into the input slots after each step.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use xla::Literal;

use crate::util::error::Context;
use crate::{bail, ensure};

use crate::config::RunConfig;
use crate::data::{Batch, TaskGen};
use crate::metrics::{RunLog, StepRecord, Throughput};
use crate::runtime::{Executable, HostValue, Role, Runtime};

/// Summary of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    pub tokens_per_sec: f64,
    pub elapsed_secs: f64,
    pub evals: Vec<(usize, EvalOutcome)>,
}

/// One evaluation outcome.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutcome {
    /// mean masked next-token NLL (nats)
    pub nll: f64,
    /// exp(nll)
    pub ppl: f64,
    /// masked argmax accuracy in [0,1] (accept-set aware)
    pub accuracy: f64,
}

pub struct Trainer {
    train_exe: Arc<Executable>,
    eval_exe: Option<Arc<Executable>>,
    /// full train-artifact input vector (literals, reused across steps)
    inputs: Vec<Literal>,
    /// output index → input index for carried tensors
    carry: Vec<(usize, usize)>,
    idx_step: usize,
    idx_lr: usize,
    idx_tokens: usize,
    idx_mask: usize,
    step: usize,
    pub batch: usize,
    pub seq_len: usize,
}

impl Trainer {
    /// Load `<artifact>.train` (and `.eval` if present) and initialize
    /// parameters from the manifest under `seed`.
    pub fn new(runtime: &Runtime, artifact: &str, seed: u64) -> crate::Result<Self> {
        let train_exe = runtime.load(&format!("{artifact}.train"))?;
        let eval_exe = if runtime.has_artifact(&format!("{artifact}.eval")) {
            Some(runtime.load(&format!("{artifact}.eval"))?)
        } else {
            None
        };

        let man = &train_exe.manifest;
        let host_inputs = train_exe.init_inputs(seed)?;
        let inputs: Vec<Literal> = host_inputs.iter()
            .map(|v| v.to_literal())
            .collect::<crate::Result<_>>()?;

        let carry: Vec<(usize, usize)> =
            man.carry_map().into_iter().collect();
        let idx_step = man.input_index("step")?;
        let idx_lr = man.input_index("lr")?;
        let idx_tokens = man.input_index("tokens")?;
        let idx_mask = man.input_index("mask")?;
        let (batch, seq_len) = (man.batch, man.seq_len);

        Ok(Trainer {
            train_exe,
            eval_exe,
            inputs,
            carry,
            idx_step,
            idx_lr,
            idx_tokens,
            idx_mask,
            step: 0,
            batch,
            seq_len,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.train_exe.manifest
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn param_count(&self) -> usize {
        self.train_exe.manifest.param_count()
    }

    /// Run one optimizer step on a batch; returns the loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f64) -> crate::Result<f32> {
        if batch.batch != self.batch || batch.seq_len != self.seq_len {
            bail!("batch shape {}x{} != artifact {}x{}",
                  batch.batch, batch.seq_len, self.batch, self.seq_len);
        }
        self.step += 1;
        self.inputs[self.idx_step].copy_raw_from(&[self.step as f32])?;
        self.inputs[self.idx_lr].copy_raw_from(&[lr as f32])?;
        self.inputs[self.idx_tokens].copy_raw_from(&batch.tokens)?;
        self.inputs[self.idx_mask].copy_raw_from(&batch.mask)?;

        let mut outs = self.train_exe.execute(&self.inputs)?;
        let man = &self.train_exe.manifest;
        let loss_i = man.output_index("loss")?;
        let loss = outs[loss_i].to_vec::<f32>()?[0];
        if !loss.is_finite() {
            bail!("non-finite loss at step {}", self.step);
        }
        // move carried outputs into the input slots (no copy)
        for &(o, i) in &self.carry {
            self.inputs[i] = std::mem::replace(&mut outs[o], Literal::scalar(0f32));
        }
        Ok(loss)
    }

    /// Full training loop per the run config; evaluates on `eval_task` at
    /// the configured cadence.
    pub fn train(&mut self, cfg: &RunConfig, task: &mut dyn TaskGen,
                 eval_task: Option<&mut dyn TaskGen>)
                 -> crate::Result<TrainReport> {
        let mut log = RunLog::new(cfg.log_path.as_deref())?;
        let mut tp = Throughput::new();
        let mut first_loss = None;
        let mut evals = vec![];
        let mut eval_task = eval_task;

        for s in 0..cfg.steps {
            let lr = cfg.lr.at(s);
            let batch = task.sample(self.batch, self.seq_len);
            let loss = self.train_step(&batch, lr)?;
            first_loss.get_or_insert(loss);
            tp.record_step(self.batch * self.seq_len);
            log.log(StepRecord {
                step: s,
                loss,
                lr,
                tokens_per_sec: tp.tokens_per_sec(),
                elapsed_secs: tp.elapsed_secs(),
            })?;
            let do_eval = cfg.eval_every > 0 && (s + 1) % cfg.eval_every == 0;
            if do_eval {
                if let Some(et) = eval_task.as_deref_mut() {
                    let out = self.evaluate(et, cfg.eval_batches)?;
                    evals.push((s + 1, out));
                }
            }
        }
        if let Some(et) = eval_task.as_deref_mut() {
            let out = self.evaluate(et, cfg.eval_batches)?;
            evals.push((cfg.steps, out));
        }
        if let Some(path) = &cfg.checkpoint_path {
            self.save_checkpoint(path)?;
        }
        Ok(TrainReport {
            steps: cfg.steps,
            first_loss: first_loss.unwrap_or(f32::NAN),
            final_loss: log.recent_loss(5).unwrap_or(f32::NAN),
            tokens_per_sec: tp.tokens_per_sec(),
            elapsed_secs: tp.elapsed_secs(),
            evals,
        })
    }

    /// Evaluate current params on `n_batches` from `task`.
    pub fn evaluate(&self, task: &mut dyn TaskGen, n_batches: usize)
                    -> crate::Result<EvalOutcome> {
        let eval_exe = self.eval_exe.as_ref()
            .context("no .eval artifact for this model")?;
        let eman = &eval_exe.manifest;
        let (eb, el) = (eman.batch, eman.seq_len);

        // map current param literals (train inputs) onto eval inputs by name
        let tman = &self.train_exe.manifest;
        let mut by_name: HashMap<&str, usize> = HashMap::new();
        for (i, t) in tman.inputs.iter().enumerate() {
            if t.role == Role::Param {
                by_name.insert(t.name.as_str(), i);
            }
        }

        // build the arg vector ONCE (params cloned a single time, not per
        // batch — §Perf: this was ~30% of eval wall at tiny scale), then
        // overwrite only the data slots per batch
        let mut args: Vec<Literal> = Vec::with_capacity(eman.inputs.len());
        let mut idx_tokens = None;
        let mut idx_mask = None;
        for (ei, spec) in eman.inputs.iter().enumerate() {
            match spec.role {
                Role::Param => {
                    let &i = by_name.get(spec.name.as_str())
                        .with_context(|| format!("missing param {}", spec.name))?;
                    args.push(self.inputs[i].clone());
                }
                Role::Data if spec.name == "tokens" => {
                    idx_tokens = Some(ei);
                    args.push(Literal::create_from_shape(
                        xla::PrimitiveType::S32, &spec.shape));
                }
                Role::Data if spec.name == "mask" => {
                    idx_mask = Some(ei);
                    args.push(Literal::create_from_shape(
                        xla::PrimitiveType::F32, &spec.shape));
                }
                _ => bail!("unexpected eval input {}", spec.name),
            }
        }
        let idx_tokens = idx_tokens.context("eval artifact missing tokens")?;
        let idx_mask = idx_mask.context("eval artifact missing mask")?;

        let mut nll_sum = 0.0f64;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut mask_sum = 0.0f64;
        for _ in 0..n_batches.max(1) {
            let batch = task.sample(eb, el);
            args[idx_tokens].copy_raw_from(&batch.tokens)?;
            args[idx_mask].copy_raw_from(&batch.mask)?;
            let outs = eval_exe.execute(&args)?;
            let nll = outs[eman.output_index("nll_sum")?].to_vec::<f32>()?[0];
            let preds = outs[eman.output_index("preds")?].to_vec::<i32>()?;
            let (c, t) = batch.score_preds(&preds);
            nll_sum += nll as f64;
            correct += c;
            total += t;
            mask_sum += batch.mask.iter().map(|&m| m as f64).sum::<f64>();
        }
        let nll = nll_sum / mask_sum.max(1.0);
        Ok(EvalOutcome {
            nll,
            ppl: nll.exp(),
            accuracy: correct as f64 / total.max(1) as f64,
        })
    }

    /// Current parameters as (name, HostValue) pairs (names without the
    /// "params." prefix).
    pub fn params(&self) -> crate::Result<Vec<(String, HostValue)>> {
        let man = &self.train_exe.manifest;
        man.inputs_with_role(Role::Param).into_iter()
            .map(|(i, t)| {
                let name = t.name.strip_prefix("params.")
                    .unwrap_or(&t.name).to_string();
                Ok((name, HostValue::from_literal(&self.inputs[i])?))
            })
            .collect()
    }

    /// Param literals by full name (for wiring into decode engines).
    pub fn param_literals(&self) -> crate::Result<Vec<(String, Literal)>> {
        let man = &self.train_exe.manifest;
        man.inputs_with_role(Role::Param).into_iter()
            .map(|(i, t)| Ok((t.name.clone(), self.inputs[i].clone())))
            .collect()
    }

    /// Save params (+ moments) to a checkpoint.
    ///
    /// Format (own binary container — the vendored xla crate's npy writer
    /// rejects non-u8 literals): magic "DNCK1\n", then per tensor a
    /// JSON-ish header line `name\tndims\tdims...` followed by raw f32 LE.
    pub fn save_checkpoint(&self, path: &Path) -> crate::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let man = &self.train_exe.manifest;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"DNCK1\n")?;
        for (i, t) in man.inputs.iter().enumerate() {
            if matches!(t.role, Role::Param | Role::OptM | Role::OptV) {
                let data = self.inputs[i].to_vec::<f32>()?;
                let dims: Vec<String> =
                    t.shape.iter().map(|d| d.to_string()).collect();
                writeln!(f, "{}\t{}\t{}", t.name, t.shape.len(),
                         dims.join("\t"))?;
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8, data.len() * 4)
                };
                f.write_all(bytes)?;
            }
        }
        Ok(())
    }

    /// Restore params/moments from a checkpoint written by
    /// [`Self::save_checkpoint`].
    pub fn load_checkpoint(&mut self, path: &Path) -> crate::Result<()> {
        use std::io::{BufRead, Read};
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?);
        let mut magic = String::new();
        r.read_line(&mut magic)?;
        if magic.trim_end() != "DNCK1" {
            bail!("{} is not a deltanet checkpoint", path.display());
        }
        let mut by_name: HashMap<String, Vec<f32>> = HashMap::new();
        loop {
            let mut header = String::new();
            if r.read_line(&mut header)? == 0 {
                break;
            }
            let parts: Vec<&str> = header.trim_end().split('\t').collect();
            if parts.len() < 2 {
                bail!("corrupt checkpoint header {header:?}");
            }
            let name = parts[0].to_string();
            let ndims: usize = parts[1].parse()?;
            if parts.len() != 2 + ndims {
                bail!("corrupt dims in header {header:?}");
            }
            let n: usize = parts[2..].iter()
                .map(|d| d.parse::<usize>().unwrap_or(0))
                .product::<usize>().max(1);
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            by_name.insert(name, data);
        }
        let man = self.train_exe.manifest.clone();
        for (i, t) in man.inputs.iter().enumerate() {
            if matches!(t.role, Role::Param | Role::OptM | Role::OptV) {
                let data = by_name.get(&t.name)
                    .with_context(|| format!("checkpoint missing {}", t.name))?;
                ensure!(data.len() == t.element_count(),
                        "size mismatch for {}", t.name);
                self.inputs[i].copy_raw_from(data)?;
            }
        }
        Ok(())
    }
}
