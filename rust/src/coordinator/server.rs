//! Serving front-end over the decode engine (std threads; tokio is not
//! available in the offline build — documented in DESIGN.md §Substitutions).
//!
//! A minimal but real request path: clients submit `GenRequest`s through an
//! mpsc queue; a dedicated engine thread drains the queue into fixed-size
//! groups (static batching, vLLM-router style admission), runs batched
//! recurrent decoding, and resolves each request's reply channel with the
//! generated tokens plus a latency breakdown.  New requests join at group
//! boundaries — the admission policy the bench harness sweeps.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::err;

use super::generate::{DecodeEngine, Sampling};

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    /// time from submission to batch start
    pub queue_ms: f64,
    /// decode time attributed to THIS request: the batch's decode wall
    /// time scaled by this request's share of decode steps (a short
    /// request in a group with a long one doesn't inherit the long tail)
    pub decode_ms: f64,
}

struct Pending {
    req: GenRequest,
    submitted: Instant,
    reply: mpsc::Sender<crate::Result<GenResponse>>,
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub tokens_generated: usize,
    /// per-request sums (for mean latency)
    pub total_queue_ms: f64,
    pub total_decode_ms: f64,
    /// wall time spent decoding, counted once per batch (for throughput)
    pub batch_decode_ms: f64,
    pub batches: usize,
}

impl ServeStats {
    pub fn mean_latency_ms(&self) -> f64 {
        (self.total_queue_ms + self.total_decode_ms)
            / self.requests.max(1) as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / (self.batch_decode_ms / 1e3).max(1e-9)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }
}

/// A handle to a submitted request; `wait()` blocks for the response.
pub struct Ticket {
    rx: mpsc::Receiver<crate::Result<GenResponse>>,
}

impl Ticket {
    pub fn wait(self) -> crate::Result<GenResponse> {
        self.rx.recv().map_err(|_| err!("engine dropped reply"))?
    }
}

pub struct ServeEngine {
    tx: Option<mpsc::Sender<Pending>>,
    stats: Arc<Mutex<ServeStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    /// Spawn the engine loop on a dedicated thread.  PJRT handles are not
    /// `Send`, so the decode engine is constructed INSIDE the worker via
    /// `factory` (build the runtime + engine there).  `group_timeout` is
    /// how long the batcher waits to fill a group before running a partial
    /// one.
    pub fn spawn<F>(factory: F, sampling: Sampling, group_timeout: Duration)
                    -> Self
    where
        F: FnOnce() -> crate::Result<DecodeEngine> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Pending>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats2 = stats.clone();

        let worker = std::thread::spawn(move || {
            let mut engine = match factory() {
                Ok(e) => e,
                Err(e) => {
                    // drain the queue, failing every request
                    let msg = format!("engine init failed: {e:#}");
                    while let Ok(p) = rx.recv() {
                        let _ = p.reply.send(Err(err!("{msg}")));
                    }
                    return;
                }
            };
            let cap = engine.batch;
            while let Ok(first) = rx.recv() {
                // collect a group: block on the first request, then fill
                // until timeout or capacity
                let mut group = vec![first];
                let deadline = Instant::now() + group_timeout;
                while group.len() < cap {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(p) => group.push(p),
                        Err(_) => break,
                    }
                }
                let t0 = Instant::now();
                let prompts: Vec<Vec<i32>> =
                    group.iter().map(|p| p.req.prompt.clone()).collect();
                let max_new =
                    group.iter().map(|p| p.req.max_new).max().unwrap_or(0);
                let result = engine.generate(&prompts, max_new, sampling, 0);
                let decode_ms = t0.elapsed().as_secs_f64() * 1e3;

                let mut st = stats2.lock().unwrap();
                st.batches += 1;
                st.batch_decode_ms += decode_ms;
                match result {
                    Ok(gens) => {
                        let mut done = Vec::with_capacity(group.len());
                        let mut steps = Vec::with_capacity(group.len());
                        for (p, g) in group.into_iter().zip(gens) {
                            let mut tokens = g;
                            tokens.truncate(p.req.max_new);
                            // decode steps this request occupied the batch
                            steps.push(p.req.prompt.len() + tokens.len());
                            done.push((p, tokens));
                        }
                        let shares = attribute_decode_ms(decode_ms, &steps);
                        for ((p, tokens), decode_ms_r)
                            in done.into_iter().zip(shares) {
                            let queue_ms = t0.duration_since(p.submitted)
                                .as_secs_f64() * 1e3;
                            st.requests += 1;
                            st.tokens_generated += tokens.len();
                            st.total_queue_ms += queue_ms;
                            st.total_decode_ms += decode_ms_r;
                            let _ = p.reply.send(Ok(GenResponse {
                                tokens,
                                queue_ms,
                                decode_ms: decode_ms_r,
                            }));
                        }
                    }
                    Err(e) => {
                        let msg = format!("decode failed: {e:#}");
                        for p in group {
                            let _ = p.reply.send(Err(err!("{msg}")));
                        }
                    }
                }
            }
        });

        ServeEngine { tx: Some(tx), stats, worker: Some(worker) }
    }

    /// Submit a request; returns a ticket to wait on.
    pub fn submit(&self, req: GenRequest) -> crate::Result<Ticket> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.as_ref().unwrap()
            .send(Pending { req, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| err!("engine stopped"))?;
        Ok(Ticket { rx: reply_rx })
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop accepting requests and join the engine thread.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.stats.lock().unwrap().clone()
    }
}

/// Split a batch's decode wall time across its requests in proportion to
/// the decode steps each occupied (prompt + generated tokens).  The longest
/// request gets the full batch time — it was on the critical path the whole
/// way; shorter riders get their share, not the stragglers' tail.
fn attribute_decode_ms(batch_ms: f64, steps: &[usize]) -> Vec<f64> {
    let max_steps = steps.iter().copied().max().unwrap_or(0).max(1);
    steps.iter()
        .map(|&s| batch_ms * s as f64 / max_steps as f64)
        .collect()
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let st = ServeStats {
            requests: 4,
            tokens_generated: 64,
            total_queue_ms: 4.0,
            total_decode_ms: 36.0,
            batch_decode_ms: 16.0,
            batches: 2,
        };
        assert!((st.mean_latency_ms() - 10.0).abs() < 1e-9);
        assert!((st.tokens_per_sec() - 4000.0).abs() < 1.0);
        assert!((st.mean_batch_occupancy() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_time_attributed_by_step_share() {
        // batch took 100ms; request 0 drove all 50 steps, request 1 only 10
        let shares = attribute_decode_ms(100.0, &[50, 10]);
        assert!((shares[0] - 100.0).abs() < 1e-9);
        assert!((shares[1] - 20.0).abs() < 1e-9);
        // degenerate groups don't divide by zero
        assert!(attribute_decode_ms(5.0, &[]).is_empty());
        assert_eq!(attribute_decode_ms(5.0, &[0]), vec![0.0]);
    }
}
