//! Serving front-end over the decode engine (std threads; tokio is not
//! available in the offline build — documented in DESIGN.md §Substitutions).
//!
//! A minimal but real request path: clients submit `GenRequest`s through an
//! mpsc queue; a dedicated engine thread drains the queue into fixed-size
//! groups (static batching, vLLM-router style admission), runs batched
//! recurrent decoding, and resolves each request's reply channel with the
//! generated tokens plus a latency breakdown.  New requests join at group
//! boundaries — the admission policy the bench harness sweeps.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::err;
use crate::obs::{self, export::MetricsServer};
use crate::obs::metrics::{
    counter, gauge, histogram, Counter, Gauge, Histogram,
};

use super::generate::{DecodeEngine, DecodeRoute, Sampling};

/// Cached handles for the serving path's metrics (`serve.*`).
struct ServeMetrics {
    requests: &'static Counter,
    request_failures: &'static Counter,
    tokens: &'static Counter,
    batches: &'static Counter,
    queue_depth: &'static Gauge,
    queue_ms: &'static Histogram,
    decode_ms: &'static Histogram,
    batch_decode_ms: &'static Histogram,
}

fn metrics() -> &'static ServeMetrics {
    static M: OnceLock<ServeMetrics> = OnceLock::new();
    M.get_or_init(|| ServeMetrics {
        requests: counter("serve.requests"),
        request_failures: counter("serve.request_failures"),
        tokens: counter("serve.tokens"),
        batches: counter("serve.batches"),
        queue_depth: gauge("serve.queue_depth"),
        queue_ms: histogram("serve.queue_ms"),
        decode_ms: histogram("serve.decode_ms"),
        batch_decode_ms: histogram("serve.batch_decode_ms"),
    })
}

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    /// time from submission to batch start
    pub queue_ms: f64,
    /// decode time attributed to THIS request: the batch's decode wall
    /// time split proportionally to each request's share of decode steps,
    /// so the per-request attributions partition the batch's wall time (a
    /// short request in a group with a long one doesn't inherit the tail)
    pub decode_ms: f64,
}

struct Pending {
    req: GenRequest,
    submitted: Instant,
    reply: mpsc::Sender<crate::Result<GenResponse>>,
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub tokens_generated: usize,
    /// per-request sums (for mean latency)
    pub total_queue_ms: f64,
    pub total_decode_ms: f64,
    /// wall time spent decoding, counted once per batch (for throughput)
    pub batch_decode_ms: f64,
    pub batches: usize,
}

impl ServeStats {
    pub fn mean_latency_ms(&self) -> f64 {
        (self.total_queue_ms + self.total_decode_ms)
            / self.requests.max(1) as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / (self.batch_decode_ms / 1e3).max(1e-9)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }
}

/// A handle to a submitted request; `wait()` blocks for the response.
pub struct Ticket {
    rx: mpsc::Receiver<crate::Result<GenResponse>>,
}

impl Ticket {
    pub fn wait(self) -> crate::Result<GenResponse> {
        self.rx.recv().map_err(|_| err!("engine dropped reply"))?
    }
}

pub struct ServeEngine {
    tx: Option<mpsc::Sender<Pending>>,
    stats: Arc<Mutex<ServeStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    /// Spawn the engine loop on a dedicated thread.  PJRT handles are not
    /// `Send`, so the decode engine is constructed INSIDE the worker via
    /// `factory` (build the runtime + engine there).  `group_timeout` is
    /// how long the batcher waits to fill a group before running a partial
    /// one.
    pub fn spawn<F>(factory: F, sampling: Sampling, group_timeout: Duration)
                    -> Self
    where
        F: FnOnce() -> crate::Result<DecodeEngine> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Pending>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats2 = stats.clone();

        let worker = std::thread::spawn(move || {
            let mut engine = match factory() {
                Ok(e) => e,
                Err(e) => {
                    // drain the queue, failing every request
                    let msg = format!("engine init failed: {e:#}");
                    while let Ok(p) = rx.recv() {
                        metrics().queue_depth.add(-1);
                        metrics().request_failures.inc();
                        let _ = p.reply.send(Err(err!("{msg}")));
                    }
                    return;
                }
            };
            let cap = engine.batch;
            while let Ok(first) = rx.recv() {
                metrics().queue_depth.add(-1);
                // collect a group: block on the first request, then fill
                // until timeout or capacity
                let mut group = vec![first];
                let deadline = Instant::now() + group_timeout;
                while group.len() < cap {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(p) => {
                            metrics().queue_depth.add(-1);
                            group.push(p);
                        }
                        Err(_) => break,
                    }
                }
                let t0 = Instant::now();
                let prompts: Vec<Vec<i32>> =
                    group.iter().map(|p| p.req.prompt.clone()).collect();
                let max_new =
                    group.iter().map(|p| p.req.max_new).max().unwrap_or(0);
                let result = {
                    let _sp = obs::trace::span_with("serve.batch", || {
                        vec![("requests", group.len() as f64),
                             ("max_new", max_new as f64)]
                    });
                    engine.generate(&prompts, max_new, sampling, 0)
                };
                let decode_ms = t0.elapsed().as_secs_f64() * 1e3;
                metrics().batches.inc();
                metrics().batch_decode_ms.record(decode_ms);

                let mut st = stats2.lock().unwrap();
                st.batches += 1;
                st.batch_decode_ms += decode_ms;
                match result {
                    Ok(gens) => {
                        let mut done = Vec::with_capacity(group.len());
                        let mut steps = Vec::with_capacity(group.len());
                        for (p, g) in group.into_iter().zip(gens) {
                            let mut tokens = g;
                            tokens.truncate(p.req.max_new);
                            // decode steps this request occupied the batch
                            steps.push(p.req.prompt.len() + tokens.len());
                            done.push((p, tokens));
                        }
                        let shares = attribute_decode_ms(decode_ms, &steps);
                        for ((p, tokens), decode_ms_r)
                            in done.into_iter().zip(shares) {
                            let queue_ms = t0.duration_since(p.submitted)
                                .as_secs_f64() * 1e3;
                            st.requests += 1;
                            st.tokens_generated += tokens.len();
                            st.total_queue_ms += queue_ms;
                            st.total_decode_ms += decode_ms_r;
                            metrics().requests.inc();
                            metrics().tokens.add(tokens.len() as u64);
                            metrics().queue_ms.record(queue_ms);
                            metrics().decode_ms.record(decode_ms_r);
                            let _ = p.reply.send(Ok(GenResponse {
                                tokens,
                                queue_ms,
                                decode_ms: decode_ms_r,
                            }));
                        }
                    }
                    Err(e) => {
                        let msg = format!("decode failed: {e:#}");
                        metrics().request_failures.add(group.len() as u64);
                        for p in group {
                            let _ = p.reply.send(Err(err!("{msg}")));
                        }
                    }
                }
            }
        });

        ServeEngine { tx: Some(tx), stats, worker: Some(worker) }
    }

    /// Resolve the decode route for `artifact` under `artifacts_dir` and
    /// spawn the engine loop on it: PJRT decode artifact when present and
    /// the backend is linked in, the pure-Rust host model otherwise — so
    /// serving works end to end with no artifacts on disk.  Returns the
    /// resolved [`DecodeRoute`] alongside the handle so callers can size
    /// prompts to `route.vocab` / report `route.backend` without probing
    /// the artifact directory themselves.
    pub fn spawn_auto(artifacts_dir: &Path, artifact: &str, seed: u64,
                      sampling: Sampling, group_timeout: Duration)
                      -> crate::Result<(Self, DecodeRoute)> {
        let route = DecodeRoute::resolve(artifacts_dir, artifact)?;
        let worker_route = route.clone();
        let engine = Self::spawn(
            move || worker_route.build(seed), sampling, group_timeout);
        Ok((engine, route))
    }

    /// Submit a request; returns a ticket to wait on.
    pub fn submit(&self, req: GenRequest) -> crate::Result<Ticket> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.as_ref().unwrap()
            .send(Pending { req, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| err!("engine stopped"))?;
        metrics().queue_depth.add(1);
        Ok(Ticket { rx: reply_rx })
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Text rendering of the global metrics snapshot (`serve.*` histograms
    /// included) — the payload behind `GET /metrics`.
    pub fn metrics_text(&self) -> String {
        obs::metrics::snapshot().render_text()
    }

    /// JSON rendering of the global metrics snapshot
    /// (`GET /metrics.json`).
    pub fn metrics_json(&self) -> String {
        obs::metrics::snapshot().to_json().render()
    }

    /// Start the HTTP metrics endpoint (e.g. `"127.0.0.1:0"`); serves the
    /// global registry, so `serve.*` latency histograms show up live.
    pub fn serve_metrics(&self, addr: &str) -> crate::Result<MetricsServer> {
        obs::export::serve_metrics(addr)
    }

    /// Stop accepting requests and join the engine thread.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.stats.lock().unwrap().clone()
    }
}

/// Split a batch's decode wall time across its requests in proportion to
/// the decode steps each occupied (prompt + generated tokens).  The shares
/// partition the batch's wall time exactly — summing per-request decode_ms
/// over a run reproduces total decode wall time, so cost accounting adds
/// up (the earlier max-normalized scheme double-counted the critical path).
fn attribute_decode_ms(batch_ms: f64, steps: &[usize]) -> Vec<f64> {
    let total: usize = steps.iter().sum();
    if total == 0 {
        return vec![0.0; steps.len()];
    }
    steps.iter()
        .map(|&s| batch_ms * s as f64 / total as f64)
        .collect()
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_auto_serves_host_route_without_artifacts() {
        let dir = std::env::temp_dir().join("deltanet_spawn_auto_test");
        std::fs::create_dir_all(&dir).unwrap();
        let (serve, route) = ServeEngine::spawn_auto(
            &dir, "deltanet_tiny", 0, Sampling::Greedy,
            Duration::from_millis(1)).unwrap();
        assert_eq!(route.backend, "host");
        let tickets: Vec<Ticket> = (0..3)
            .map(|i| serve.submit(GenRequest {
                prompt: vec![1 + i, 2, 3],
                max_new: 4,
            }).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.tokens.iter()
                .all(|&t| (t as usize) < route.vocab));
        }
        let st = serve.shutdown();
        assert_eq!(st.requests, 3);
    }

    #[test]
    fn stats_math() {
        let st = ServeStats {
            requests: 4,
            tokens_generated: 64,
            total_queue_ms: 4.0,
            total_decode_ms: 36.0,
            batch_decode_ms: 16.0,
            batches: 2,
        };
        assert!((st.mean_latency_ms() - 10.0).abs() < 1e-9);
        assert!((st.tokens_per_sec() - 4000.0).abs() < 1.0);
        assert!((st.mean_batch_occupancy() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_time_attributed_by_step_share() {
        // batch took 100ms over 60 total steps; request 0 drove 50 of
        // them, request 1 the other 10 — shares partition the 100ms
        let shares = attribute_decode_ms(100.0, &[50, 10]);
        assert!((shares[0] - 100.0 * 50.0 / 60.0).abs() < 1e-9);
        assert!((shares[1] - 100.0 * 10.0 / 60.0).abs() < 1e-9);
        assert!((shares.iter().sum::<f64>() - 100.0).abs() < 1e-9);
        // degenerate groups don't divide by zero
        assert!(attribute_decode_ms(5.0, &[]).is_empty());
        assert_eq!(attribute_decode_ms(5.0, &[0]), vec![0.0]);
    }

    #[test]
    fn prop_decode_shares_partition_batch_time() {
        use crate::util::prop;
        prop::check("decode shares partition batch time", 300, |rng| {
            let n = prop::usize_in(rng, 1, 17);
            let steps: Vec<usize> = (0..n)
                .map(|_| if rng.coin(0.25) { 0 } else { rng.range(1, 400) })
                .collect();
            let batch_ms = rng.uniform() as f64 * 500.0;
            let shares = attribute_decode_ms(batch_ms, &steps);
            if shares.len() != steps.len() {
                return Err(format!("len {} != {}", shares.len(), steps.len()));
            }
            for (i, (&sh, &st)) in shares.iter().zip(&steps).enumerate() {
                if sh < 0.0 {
                    return Err(format!("negative share {sh} at {i}"));
                }
                if st == 0 && sh != 0.0 {
                    return Err(format!("zero-step request got {sh}ms"));
                }
            }
            let total_steps: usize = steps.iter().sum();
            let want = if total_steps == 0 { 0.0 } else { batch_ms };
            let sum: f64 = shares.iter().sum();
            if (sum - want).abs() > 1e-9 {
                return Err(format!("shares sum {sum} != {want}"));
            }
            Ok(())
        });
    }
}
