//! Host kernel backend: the batched chunkwise/recurrent DeltaNet kernels
//! exposed under the *kernel-artifact signature*, so coordinator paths
//! (repro harnesses, benches, decode experiments) can run the paper's
//! algorithm with no PJRT backend present.
//!
//! The Fig-1 kernel artifacts take `q,k,v: [B,L,D]` + `beta: [B,L]` and
//! return `(o: [B,L,D], state: [B,D,D])`.  [`HostKernelBackend::run`]
//! accepts and returns exactly that layout; internally the B sequences are
//! fanned out over the scoped worker pool, one chunkwise (or recurrent)
//! forward per sequence.  [`HostKernelBackend::decode_step`] is the host
//! analogue of the `.decode` artifact's sequence-mixing step: it advances
//! one token for every sequence in the batch against carried per-sequence
//! states (constant memory in sequence length).

use std::time::Instant;

use crate::data::Batch;
use crate::kernels::{
    chunkwise::recurrent_step, forward_batched_on, map_batched_on,
    HeadProblem,
};
use crate::model::{AdamW, HostModel, Optimizer};
use crate::obs;
use crate::obs::health::{HealthConfig, HealthMonitor, Verdict};
use crate::runtime::HostValue;
use crate::tensor::Mat;
use crate::util::error::Context;
use crate::util::threadpool::ThreadPool;
use crate::{bail, ensure};

/// Which form of the kernel to run (the Fig-1 comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelForm {
    Recurrent,
    Chunkwise,
}

/// Wall-clock and gradient diagnostics of one training step, surfaced in
/// the trainer's `StepRecord` and the `train.*` histograms.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepBreakdown {
    pub forward_ms: f64,
    pub backward_ms: f64,
    pub optimizer_ms: f64,
    /// Global L2 norm over all gradient tensors.
    pub grad_norm: f32,
    /// Tokens processed per wall-clock second of this step (forward +
    /// backward + optimizer).
    pub tokens_per_sec: f64,
    /// Achieved kernel compute rate over the step, in GFLOP/s: the delta
    /// of the `kernels.*.flops` counters divided by the step wall time.
    /// Compared against a machine peak this is the roofline position of
    /// the training loop.
    pub gflops: f64,
}

pub struct HostKernelBackend {
    pool: ThreadPool,
    chunk: usize,
    /// Model + optimizer state backing `Backend::train_step` (attached
    /// via [`Self::with_model`]; `None` for pure kernel workloads).
    model: Option<(HostModel, Optimizer)>,
    /// Training health monitor: classifies every step's (loss, grad norm)
    /// before the optimizer applies the update.  The default policy
    /// (abort on NaN/Inf/spike) preserves the old bare "non-finite loss"
    /// bail, now with rolling context and a flight-recorder trail.
    health: HealthMonitor,
}

impl HostKernelBackend {
    /// `threads` worker threads, chunk length `chunk` for the chunkwise
    /// form.
    pub fn new(threads: usize, chunk: usize) -> Self {
        assert!(chunk > 0, "chunk must be positive");
        HostKernelBackend {
            pool: ThreadPool::new(threads),
            chunk,
            model: None,
            health: HealthMonitor::from_env(),
        }
    }

    /// Replace the health-monitor configuration (policy + detector
    /// thresholds); resets the monitor's rolling state.
    pub fn set_health(&mut self, cfg: HealthConfig) {
        self.health = HealthMonitor::new(cfg);
    }

    pub fn health(&self) -> &HealthMonitor {
        &self.health
    }

    /// Attach a host DeltaNet model (with fresh AdamW state) so the
    /// backend can serve `Backend::train_step` — the offline replacement
    /// for a `.train` artifact.
    pub fn with_model(mut self, model: HostModel) -> Self {
        self.model = Some((model, Optimizer::AdamW(AdamW::new())));
        self
    }

    pub fn model(&self) -> Option<&HostModel> {
        self.model.as_ref().map(|(m, _)| m)
    }

    pub fn model_mut(&mut self) -> Option<&mut HostModel> {
        self.model.as_mut().map(|(m, _)| m)
    }

    /// One AdamW step of the attached model on `batch`; returns the loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f32)
                      -> crate::Result<f32> {
        self.train_step_detailed(batch, lr).map(|(loss, _)| loss)
    }

    /// [`Self::train_step`] plus the per-phase wall-clock breakdown and
    /// gradient norm; also feeds the `train.*` metrics.
    pub fn train_step_detailed(&mut self, batch: &Batch, lr: f32)
                               -> crate::Result<(f32, StepBreakdown)> {
        let (model, opt) = self
            .model
            .as_mut()
            .context("no host model attached \
                      (HostKernelBackend::with_model)")?;
        let flops_before = kernel_flops_total();
        let t_step = Instant::now();
        let (loss, grads, phases) = model.loss_and_grads_timed(batch)?;
        let grad_norm = grads.global_norm();
        // classify the step BEFORE the optimizer touches the params, so
        // SkipStep can actually drop a poisoned update
        let verdict = self.health.observe(loss, Some(grad_norm));
        let skip_update = match &verdict {
            Verdict::Abort(issue) => {
                bail!("training health abort at step {}: {issue}",
                      self.health.steps_seen());
            }
            Verdict::Skip(_) => true,
            Verdict::Ok | Verdict::Warn(_) => false,
        };
        let t_opt = Instant::now();
        if !skip_update {
            let _opt_sp = obs::trace::span("train.optimizer");
            let gt = grads.tensors();
            let mut params: Vec<&mut Mat> = model
                .param_entries_mut()
                .into_iter()
                .map(|(_, p)| p)
                .collect();
            opt.step(&mut params, &gt, lr);
        }
        let optimizer_ms = t_opt.elapsed().as_secs_f64() * 1e3;
        let step_s = t_step.elapsed().as_secs_f64();
        let tokens = (batch.batch * batch.seq_len) as f64;
        let tokens_per_sec = if step_s > 0.0 { tokens / step_s } else { 0.0 };
        let gflops = if step_s > 0.0 {
            (kernel_flops_total() - flops_before) as f64 / step_s / 1e9
        } else {
            0.0
        };

        obs::metrics::counter("train.steps").inc();
        obs::metrics::counter("train.tokens")
            .add((batch.batch * batch.seq_len) as u64);
        obs::metrics::histogram("train.forward_ms")
            .record(phases.forward_ms);
        obs::metrics::histogram("train.backward_ms")
            .record(phases.backward_ms);
        obs::metrics::histogram("train.optimizer_ms").record(optimizer_ms);
        obs::metrics::histogram("train.tokens_per_sec")
            .record(tokens_per_sec);
        obs::metrics::histogram("train.gflops").record(gflops);
        obs::flight::record(
            obs::flight::EventKind::Step,
            "train.step",
            &[("step", self.health.steps_seen() as f64),
              ("loss", loss as f64),
              ("grad_norm", grad_norm as f64),
              ("ms", step_s * 1e3)],
        );

        Ok((loss, StepBreakdown {
            forward_ms: phases.forward_ms,
            backward_ms: phases.backward_ms,
            optimizer_ms,
            grad_norm,
            tokens_per_sec,
            gflops,
        }))
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Run the batched forward under the kernel-artifact signature:
    /// `q,k,v: [B,L,D]` f32, `beta: [B,L]` f32 →
    /// `(o: [B,L,D], state: [B,D,D])`, using the backend's chunk length.
    pub fn run(&self, form: KernelForm, q: &HostValue, k: &HostValue,
               v: &HostValue, beta: &HostValue)
               -> crate::Result<(HostValue, HostValue)> {
        self.run_with_chunk(form, self.chunk, q, k, v, beta)
    }

    /// [`Self::run`] with an explicit chunk length — lets chunk-size
    /// sweeps reuse one backend (and its worker pool) across calls.
    pub fn run_with_chunk(&self, form: KernelForm, chunk: usize,
                          q: &HostValue, k: &HostValue, v: &HostValue,
                          beta: &HostValue)
                          -> crate::Result<(HostValue, HostValue)> {
        let (b, l, d) = batched_dims(q)?;
        for (name, t) in [("k", k), ("v", v)] {
            ensure!(t.shape() == q.shape(),
                    "{name} shape {:?} != q shape {:?}", t.shape(), q.shape());
        }
        ensure!(beta.shape() == &[b, l][..],
                "beta shape {:?} != [{b}, {l}]", beta.shape());

        let qd = q.as_f32()?;
        let kd = k.as_f32()?;
        let vd = v.as_f32()?;
        let bd = beta.as_f32()?;

        let seq_mat = |data: &[f32], bi: usize| -> crate::Result<Mat> {
            Mat::from_vec(l, d, data[bi * l * d..(bi + 1) * l * d].to_vec())
        };
        let problems: Vec<HeadProblem> = (0..b)
            .map(|bi| -> crate::Result<HeadProblem> {
                Ok(HeadProblem::new(
                    seq_mat(qd, bi)?,
                    seq_mat(kd, bi)?,
                    seq_mat(vd, bi)?,
                    bd[bi * l..(bi + 1) * l].to_vec(),
                ))
            })
            .collect::<crate::Result<_>>()?;

        let outs = match form {
            // DAG-scheduled over every (batch, head, chunk) task, so a
            // single long sequence still uses the whole pool
            KernelForm::Chunkwise => {
                forward_batched_on(&self.pool, &problems, chunk)
            }
            // scalar recurrence per sequence, still fanned out over the
            // pool — the Fig-1 baseline with the same parallel budget
            KernelForm::Recurrent => {
                map_batched_on(&self.pool, &problems, |p| {
                    crate::reference::delta_recurrent(&p.q, &p.k, &p.v,
                                                      &p.beta, None)
                })
            }
        };

        let mut o_all = vec![0.0f32; b * l * d];
        let mut s_all = vec![0.0f32; b * d * d];
        for (bi, f) in outs.iter().enumerate() {
            o_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&f.o.data);
            s_all[bi * d * d..(bi + 1) * d * d]
                .copy_from_slice(&f.state.data);
        }
        Ok((HostValue::from_f32(&[b, l, d], o_all)?,
            HostValue::from_f32(&[b, d, d], s_all)?))
    }

    /// Chunkwise prefill: consume a prompt segment per sequence and return
    /// the carried states ([B] mats of [D, D]) for subsequent
    /// [`Self::decode_step`] calls — the prefill/decode contract of the
    /// serving path, on the host.
    pub fn prefill(&self, q: &HostValue, k: &HostValue, v: &HostValue,
                   beta: &HostValue) -> crate::Result<Vec<Mat>> {
        let (b, _, d) = batched_dims(q)?;
        let (_, state) = self.run(KernelForm::Chunkwise, q, k, v, beta)?;
        let sd = state.as_f32()?;
        (0..b)
            .map(|bi| {
                Mat::from_vec(d, d, sd[bi * d * d..(bi + 1) * d * d].to_vec())
            })
            .collect()
    }

    /// One recurrent decode step for a whole batch: `q,k,v: [B, D]` rows
    /// for the current token of each sequence, `beta: [B]`; `states` are
    /// advanced in place and the per-sequence outputs `[B, D]` returned.
    pub fn decode_step(&self, states: &mut [Mat], q: &Mat, k: &Mat,
                       v: &Mat, beta: &[f32]) -> crate::Result<Mat> {
        let b = states.len();
        ensure!(q.rows == b && k.rows == b && v.rows == b && beta.len() == b,
                "decode step wants one row per sequence ({b})");
        let _sp = obs::trace::span_with("host.decode_step", || {
            vec![("B", b as f64)]
        });
        let mut out = Mat::zeros(b, v.cols);
        self.pool.scope(|s| {
            // one job per sequence: disjoint &mut state and output rows
            for (bi, (st, orow)) in states
                .iter_mut()
                .zip(out.data.chunks_mut(v.cols))
                .enumerate()
            {
                s.spawn(move || {
                    recurrent_step(st, q.row(bi), k.row(bi), v.row(bi),
                                   beta[bi], orow);
                });
            }
        });
        Ok(out)
    }
}

/// Total FLOPs recorded by the kernel work counters so far (forward +
/// backward + recurrent); the delta across a step, over its wall time,
/// is the achieved compute rate reported in [`StepBreakdown::gflops`].
fn kernel_flops_total() -> u64 {
    obs::metrics::counter("kernels.forward.flops").get()
        + obs::metrics::counter("kernels.backward.flops").get()
        + obs::metrics::counter("kernels.recurrent.flops").get()
}

fn batched_dims(q: &HostValue) -> crate::Result<(usize, usize, usize)> {
    match q.shape() {
        [b, l, d] => Ok((*b, *l, *d)),
        other => bail!("expected [B, L, D] tensor, got shape {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::{delta_recurrent, random_problem};

    fn batched_inputs(b: usize, l: usize, d: usize)
                      -> (HostValue, HostValue, HostValue, HostValue,
                          Vec<(Mat, Mat, Mat, Vec<f32>)>) {
        let mut q_all = vec![0f32; b * l * d];
        let mut k_all = vec![0f32; b * l * d];
        let mut v_all = vec![0f32; b * l * d];
        let mut beta_all = vec![0f32; b * l];
        let mut problems = vec![];
        for bi in 0..b {
            let (q, k, v, beta) = random_problem(l, d, d, 300 + bi as u64);
            q_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&q.data);
            k_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&k.data);
            v_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&v.data);
            beta_all[bi * l..(bi + 1) * l].copy_from_slice(&beta);
            problems.push((q, k, v, beta));
        }
        (HostValue::from_f32(&[b, l, d], q_all).unwrap(),
         HostValue::from_f32(&[b, l, d], k_all).unwrap(),
         HostValue::from_f32(&[b, l, d], v_all).unwrap(),
         HostValue::from_f32(&[b, l], beta_all).unwrap(),
         problems)
    }

    #[test]
    fn both_forms_match_the_oracle_batched() {
        let (b, l, d) = (4usize, 64usize, 8usize);
        let (q, k, v, beta, problems) = batched_inputs(b, l, d);
        let backend = HostKernelBackend::new(4, 16);
        for form in [KernelForm::Chunkwise, KernelForm::Recurrent] {
            let (o, s) = backend.run(form, &q, &k, &v, &beta).unwrap();
            assert_eq!(o.shape(), &[b, l, d]);
            assert_eq!(s.shape(), &[b, d, d]);
            let od = o.as_f32().unwrap();
            let sd = s.as_f32().unwrap();
            for (bi, (pq, pk, pv, pb)) in problems.iter().enumerate() {
                let want = delta_recurrent(pq, pk, pv, pb, None);
                let got_o = Mat::from_vec(
                    l, d, od[bi * l * d..(bi + 1) * l * d].to_vec()).unwrap();
                let got_s = Mat::from_vec(
                    d, d, sd[bi * d * d..(bi + 1) * d * d].to_vec()).unwrap();
                assert!(got_o.allclose(&want.o, 1e-4, 1e-4),
                        "{form:?} seq {bi} output");
                assert!(got_s.allclose(&want.state, 1e-4, 1e-4),
                        "{form:?} seq {bi} state");
            }
        }
    }

    #[test]
    fn prefill_then_decode_matches_full_forward() {
        let (b, l, d) = (3usize, 32usize, 8usize);
        let (q, k, v, beta, problems) = batched_inputs(b, l, d);
        let backend = HostKernelBackend::new(2, 8);
        // prefill on the first half...
        let half = l / 2;
        let take = |t: &HostValue, n: usize| -> HostValue {
            let td = t.as_f32().unwrap();
            let mut out = vec![0f32; b * n * d];
            for bi in 0..b {
                out[bi * n * d..(bi + 1) * n * d].copy_from_slice(
                    &td[bi * l * d..bi * l * d + n * d]);
            }
            HostValue::from_f32(&[b, n, d], out).unwrap()
        };
        let beta_half = {
            let bd = beta.as_f32().unwrap();
            let mut out = vec![0f32; b * half];
            for bi in 0..b {
                out[bi * half..(bi + 1) * half]
                    .copy_from_slice(&bd[bi * l..bi * l + half]);
            }
            HostValue::from_f32(&[b, half], out).unwrap()
        };
        let mut states = backend
            .prefill(&take(&q, half), &take(&k, half), &take(&v, half),
                     &beta_half)
            .unwrap();
        // ...then decode the second half token by token
        for t in half..l {
            let row = |m: &Mat| m.row(t).to_vec();
            let qs = Mat::from_rows(
                problems.iter().map(|(pq, ..)| row(pq)).collect()).unwrap();
            let ks = Mat::from_rows(
                problems.iter().map(|(_, pk, ..)| row(pk)).collect()).unwrap();
            let vs = Mat::from_rows(
                problems.iter().map(|(_, _, pv, _)| row(pv)).collect())
                .unwrap();
            let bs: Vec<f32> =
                problems.iter().map(|(.., pb)| pb[t]).collect();
            let out = backend.decode_step(&mut states, &qs, &ks, &vs, &bs)
                .unwrap();
            for (bi, (pq, pk, pv, pb)) in problems.iter().enumerate() {
                let want = delta_recurrent(pq, pk, pv, pb, None);
                for (a, w) in out.row(bi).iter().zip(want.o.row(t)) {
                    assert!((a - w).abs() < 1e-3,
                            "seq {bi} token {t}: {a} vs {w}");
                }
            }
        }
    }

    #[test]
    fn shape_mismatches_rejected() {
        let (q, k, v, _, _) = batched_inputs(2, 16, 4);
        let backend = HostKernelBackend::new(1, 8);
        let bad_beta = HostValue::from_f32(&[2, 8], vec![0.5; 16]).unwrap();
        assert!(backend.run(KernelForm::Chunkwise, &q, &k, &v, &bad_beta)
            .is_err());
        let flat = HostValue::from_f32(&[2, 64], vec![0.0; 128]).unwrap();
        assert!(backend.run(KernelForm::Chunkwise, &flat, &k, &v, &bad_beta)
            .is_err());
    }
}
