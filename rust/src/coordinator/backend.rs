//! The `Backend` trait: one compute contract for every coordinator path.
//!
//! Before this trait, each harness carried its own ad-hoc branching between
//! the PJRT artifact path and the host kernel path (`repro::fig1` matched on
//! artifact errors per cell, serving required a `.decode` artifact, training
//! required a `.train` artifact).  Now a single `Box<dyn Backend>` is picked
//! up front and every consumer — `DecodeEngine`, `coordinator::server`,
//! `coordinator::trainer`, the repro harnesses — drives the same five
//! operations:
//!
//! | op              | PJRT artifact path          | host kernel path      |
//! |-----------------|-----------------------------|-----------------------|
//! | `run`           | `kernel_*` HLO execution    | `kernels::batch`      |
//! | `prefill`       | chunkwise run, split states | same, host kernels    |
//! | `decode_step`   | (via `.decode` artifacts)   | `recurrent_step` pool |
//! | `train_step`    | (via `.train` artifacts)    | `model::HostModel`    |
//!
//! The PJRT impl covers the kernel-artifact surface (`run`/`prefill`);
//! decode/train on PJRT stay with their dedicated artifact engines
//! (`DecodeEngine::new`, `Trainer`), which this trait's host impls mirror.

use std::path::Path;

use crate::data::Batch;
use crate::kernels::default_threads;
use crate::model::HostModel;
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Mat;
use crate::{bail, ensure};

use super::host::{HostKernelBackend, KernelForm};
use super::instrument::InstrumentedBackend;

/// A compute backend for the DeltaNet sequence-mixing kernels plus the
/// optional training step.  Object-safe: harnesses hold `Box<dyn Backend>`.
pub trait Backend {
    /// Short stable identifier ("host" / "pjrt") for logs and tables.
    fn name(&self) -> &'static str;

    /// Batched forward under the kernel-artifact signature:
    /// `q,k,v: [B,L,D]`, `beta: [B,L]` → `(o: [B,L,D], state: [B,D,D])`,
    /// at the backend's default chunk length.
    fn run(&self, form: KernelForm, q: &HostValue, k: &HostValue,
           v: &HostValue, beta: &HostValue)
           -> crate::Result<(HostValue, HostValue)>;

    /// [`Backend::run`] with an explicit chunk length (chunk-size sweeps).
    fn run_with_chunk(&self, form: KernelForm, chunk: usize, q: &HostValue,
                      k: &HostValue, v: &HostValue, beta: &HostValue)
                      -> crate::Result<(HostValue, HostValue)>;

    /// Consume a prompt segment per sequence (chunkwise) and return the
    /// carried `[D, D]` state per sequence for [`Backend::decode_step`].
    fn prefill(&self, q: &HostValue, k: &HostValue, v: &HostValue,
               beta: &HostValue) -> crate::Result<Vec<Mat>> {
        let (_, state) = self.run(KernelForm::Chunkwise, q, k, v, beta)?;
        let sd = state.as_f32()?;
        let (b, d) = match state.shape() {
            [b, d, d2] if d == d2 => (*b, *d),
            other => bail!("prefill expected [B,D,D] state, got {other:?}"),
        };
        (0..b)
            .map(|bi| {
                Mat::from_vec(d, d,
                              sd[bi * d * d..(bi + 1) * d * d].to_vec())
            })
            .collect()
    }

    /// Advance every sequence one token: `q,k,v: [B, D]` rows, `beta: [B]`;
    /// `states` updated in place, per-sequence outputs `[B, D]` returned.
    fn decode_step(&self, states: &mut [Mat], q: &Mat, k: &Mat, v: &Mat,
                   beta: &[f32]) -> crate::Result<Mat>;

    /// One optimizer step on a batch; returns the loss.  Backends without
    /// a training path (or without a model attached) error cleanly.
    fn train_step(&mut self, batch: &Batch, lr: f32) -> crate::Result<f32>;
}

impl Backend for HostKernelBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn run(&self, form: KernelForm, q: &HostValue, k: &HostValue,
           v: &HostValue, beta: &HostValue)
           -> crate::Result<(HostValue, HostValue)> {
        HostKernelBackend::run(self, form, q, k, v, beta)
    }

    fn run_with_chunk(&self, form: KernelForm, chunk: usize, q: &HostValue,
                      k: &HostValue, v: &HostValue, beta: &HostValue)
                      -> crate::Result<(HostValue, HostValue)> {
        HostKernelBackend::run_with_chunk(self, form, chunk, q, k, v, beta)
    }

    fn prefill(&self, q: &HostValue, k: &HostValue, v: &HostValue,
               beta: &HostValue) -> crate::Result<Vec<Mat>> {
        HostKernelBackend::prefill(self, q, k, v, beta)
    }

    fn decode_step(&self, states: &mut [Mat], q: &Mat, k: &Mat, v: &Mat,
                   beta: &[f32]) -> crate::Result<Mat> {
        HostKernelBackend::decode_step(self, states, q, k, v, beta)
    }

    fn train_step(&mut self, batch: &Batch, lr: f32) -> crate::Result<f32> {
        HostKernelBackend::train_step(self, batch, lr)
    }
}

/// The PJRT artifact path behind the [`Backend`] contract.  `run` derives
/// the kernel artifact name from the input shapes
/// (`kernel_{form}_L{l}_d{d}_C{c}_B{b}` — the exporter's naming scheme) and
/// executes it; decode/train report that they live in the dedicated
/// artifact engines.
pub struct PjrtBackend {
    runtime: Runtime,
    chunk: usize,
}

impl PjrtBackend {
    pub fn new(runtime: Runtime, chunk: usize) -> crate::Result<Self> {
        ensure!(chunk > 0, "chunk must be > 0");
        Ok(PjrtBackend { runtime, chunk })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(&self, form: KernelForm, q: &HostValue, k: &HostValue,
           v: &HostValue, beta: &HostValue)
           -> crate::Result<(HostValue, HostValue)> {
        self.run_with_chunk(form, self.chunk, q, k, v, beta)
    }

    fn run_with_chunk(&self, form: KernelForm, chunk: usize, q: &HostValue,
                      k: &HostValue, v: &HostValue, beta: &HostValue)
                      -> crate::Result<(HostValue, HostValue)> {
        let (b, l, d) = match q.shape() {
            [b, l, d] => (*b, *l, *d),
            other => bail!("expected [B, L, D] tensor, got shape {other:?}"),
        };
        let form_s = match form {
            KernelForm::Recurrent => "recurrent",
            KernelForm::Chunkwise => "chunkwise",
        };
        let name = format!("kernel_{form_s}_L{l}_d{d}_C{chunk}_B{b}");
        let exe = self.runtime.load(&name)?;
        let args = [q, k, v, beta]
            .iter()
            .map(|t| t.to_literal())
            .collect::<crate::Result<Vec<_>>>()?;
        let outs = exe.execute(&args)?;
        let man = &exe.manifest;
        let oi = man.output_index("o").unwrap_or(0);
        let si = man.output_index("state").unwrap_or(1);
        ensure!(outs.len() > oi.max(si),
                "{name} returned {} outputs", outs.len());
        Ok((HostValue::from_literal(&outs[oi])?,
            HostValue::from_literal(&outs[si])?))
    }

    fn decode_step(&self, _states: &mut [Mat], _q: &Mat, _k: &Mat,
                   _v: &Mat, _beta: &[f32]) -> crate::Result<Mat> {
        bail!("pjrt kernel backend has no single-step path; build a \
               DecodeEngine from a .decode artifact")
    }

    fn train_step(&mut self, _batch: &Batch, _lr: f32)
                  -> crate::Result<f32> {
        bail!("pjrt kernel backend does not train; drive a .train \
               artifact through coordinator::Trainer")
    }
}

/// One backend decision for a whole harness: the PJRT artifact path when a
/// real PJRT plugin is linked in, the host kernel backend otherwise (the
/// offline build — `Runtime::backend_available()` is false under the `xla`
/// shim, where artifact execution cannot succeed).
///
/// The selection is wrapped in [`InstrumentedBackend`], so every trait call
/// gets a `backend.*` span + counter; `name()` still reports the inner
/// backend's identity.
pub fn select_kernel_backend(artifacts_dir: &Path, chunk: usize)
                             -> crate::Result<Box<dyn Backend>> {
    let inner: Box<dyn Backend> = if Runtime::backend_available() {
        Box::new(PjrtBackend::new(Runtime::new(artifacts_dir)?, chunk)?)
    } else {
        Box::new(HostKernelBackend::new(default_threads(), chunk))
    };
    Ok(Box::new(InstrumentedBackend::new(inner)))
}

/// Host backend preloaded with a freshly initialized DeltaNet model, ready
/// for [`Backend::train_step`] — the artifact-free training entry point.
pub fn host_training_backend(model: HostModel) -> HostKernelBackend {
    let chunk = model.cfg.chunk;
    HostKernelBackend::new(default_threads(), chunk).with_model(model)
}
