//! Batched recurrent decoding — the constant-memory inference path that is
//! the whole point of linear-attention models (no KV cache for DeltaNet
//! layers; state is a fixed d_k×d_v matrix per head).
//!
//! Two engines behind one interface:
//!
//! * **Artifact** — the `.decode` artifact steps a whole batch one token
//!   forward: (params, state, token[B], pos) → (logits[B,V], state').
//! * **Host** — a `model::HostModel` steps the same contract in pure Rust,
//!   with the per-head delta-rule recurrence routed through
//!   `coordinator::Backend::decode_step`, so serving works with no
//!   artifacts on disk.
//!
//! The engine owns sampling and the prompt/generation bookkeeping: rows of
//! a batch may have prompts of different lengths — all rows step together
//! from pos 0, each row feeds prompt tokens until its prompt is exhausted,
//! then feeds its own previous sample (standard static-batch decoding).

use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};

use xla::Literal;

use crate::bail;
use crate::util::error::Context;

use crate::kernels::default_threads;
use crate::model::{HostModel, HostModelCfg};
use crate::obs::{self, metrics::{counter, Counter}};
use crate::runtime::{Executable, Manifest, Role, Runtime};
use crate::tensor::rng::Rng;
use crate::tensor::Mat;

use super::backend::Backend;
use super::host::HostKernelBackend;

fn decode_tokens_counter() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    *C.get_or_init(|| counter("decode.tokens"))
}

/// Sampling policy.
#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    Greedy,
    /// temperature > 0; top_k = 0 disables the filter
    TopK { temperature: f32, k: usize },
}

/// A resolved serving route: which decode engine serves (`"pjrt"` when the
/// backend is linked in AND the `.decode` artifact exists on disk, `"host"`
/// otherwise) plus the shape callers need BEFORE the engine exists — the
/// engine itself is typically built inside a serving thread because PJRT
/// handles are not `Send` (see [`super::ServeEngine::spawn_auto`]).
///
/// This is the ROADMAP "serving demo works with no artifacts" routing in
/// one place: resolve once, size prompts to `vocab`, then `build` on
/// whichever thread will own the engine.
#[derive(Debug, Clone)]
pub struct DecodeRoute {
    /// `"pjrt"` (artifact) or `"host"` — matches
    /// [`DecodeEngine::backend_name`] of the engine `build` produces.
    pub backend: &'static str,
    pub vocab: usize,
    pub batch: usize,
    pub max_seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    artifacts_dir: PathBuf,
    artifact: String,
}

impl DecodeRoute {
    /// Probe `artifacts_dir` for `{artifact}.decode.manifest.json` and pick
    /// the engine: the compiled artifact when it exists and a real PJRT
    /// backend is linked in, the pure-Rust host model otherwise.  Errors
    /// only on a present-but-broken manifest — absence routes to host.
    pub fn resolve(artifacts_dir: &Path, artifact: &str) -> crate::Result<Self> {
        let man_path = artifacts_dir
            .join(format!("{artifact}.decode.manifest.json"));
        if Runtime::backend_available() && man_path.exists() {
            let man = Manifest::load(&man_path)?;
            let cfg = man.config.as_ref()
                .context("decode manifest missing model config")?;
            Ok(DecodeRoute {
                backend: "pjrt",
                vocab: cfg.vocab_size,
                batch: man.batch,
                max_seq_len: cfg.max_seq_len,
                d_model: cfg.d_model,
                n_heads: cfg.n_heads,
                artifacts_dir: artifacts_dir.to_path_buf(),
                artifact: artifact.to_string(),
            })
        } else {
            let cfg = HostModelCfg::tiny();
            Ok(DecodeRoute {
                backend: "host",
                vocab: cfg.vocab,
                batch: 8,
                max_seq_len: 64,
                d_model: cfg.d_model,
                n_heads: cfg.n_heads,
                artifacts_dir: artifacts_dir.to_path_buf(),
                artifact: artifact.to_string(),
            })
        }
    }

    /// Build the engine this route resolved to.  Call on the thread that
    /// will own the engine (PJRT handles are not `Send`); the route itself
    /// is `Clone + Send`, so it can cross into a worker first.
    pub fn build(&self, seed: u64) -> crate::Result<DecodeEngine> {
        match self.backend {
            "pjrt" => {
                let rt = Runtime::new(&self.artifacts_dir)?;
                DecodeEngine::new(&rt, &self.artifact, seed)
            }
            _ => {
                let model = HostModel::new(
                    HostModelCfg::tiny(), seed, default_threads())?;
                Ok(DecodeEngine::host(model, self.batch, self.max_seq_len))
            }
        }
    }
}

pub struct DecodeEngine {
    inner: Inner,
    pub batch: usize,
    pub vocab: usize,
    pub max_seq_len: usize,
}

enum Inner {
    Artifact {
        exe: Arc<Executable>,
        /// full decode input vector (params + state + token + pos)
        inputs: Vec<Literal>,
        carry: Vec<(usize, usize)>, // output idx -> input idx (state)
        idx_token: usize,
        idx_pos: usize,
        state_inputs: Vec<usize>,
    },
    Host {
        model: HostModel,
        backend: HostKernelBackend,
        /// `[d_h, d_h]` per (layer, head, sequence), layout
        /// `(layer*H + head)*batch + b` (see `HostModel::decode_states`)
        states: Vec<Mat>,
    },
}

impl DecodeEngine {
    /// Build from an artifact; params default to manifest init under `seed`
    /// (use [`Self::set_params`] to install trained weights).
    pub fn new(runtime: &Runtime, artifact: &str, seed: u64) -> crate::Result<Self> {
        let exe = runtime.load(&format!("{artifact}.decode"))?;
        let man = &exe.manifest;
        let host = exe.init_inputs(seed)?;
        let inputs: Vec<Literal> = host.iter()
            .map(|v| v.to_literal())
            .collect::<crate::Result<_>>()?;
        let carry = man.carry_map().into_iter().collect();
        let idx_token = man.input_index("token")?;
        let idx_pos = man.input_index("pos")?;
        let state_inputs = man.inputs_with_role(Role::State)
            .into_iter().map(|(i, _)| i).collect();
        let vocab = man.config.as_ref()
            .map(|c| c.vocab_size)
            .context("decode artifact missing model config")?;
        let batch = man.batch;
        let max_seq_len = man.config.as_ref().unwrap().max_seq_len;
        Ok(DecodeEngine {
            inner: Inner::Artifact {
                exe,
                inputs,
                carry,
                idx_token,
                idx_pos,
                state_inputs,
            },
            batch,
            vocab,
            max_seq_len,
        })
    }

    /// Build around a host model — the artifact-free serving path.  The
    /// engine owns the model; its parameters ARE the weights served.
    pub fn host(model: HostModel, batch: usize, max_seq_len: usize) -> Self {
        let vocab = model.cfg.vocab;
        let states = model.decode_states(batch);
        let backend =
            HostKernelBackend::new(default_threads(), model.cfg.chunk);
        DecodeEngine {
            inner: Inner::Host { model, backend, states },
            batch,
            vocab,
            max_seq_len,
        }
    }

    /// Which engine decodes: "pjrt" (artifact) or "host".
    pub fn backend_name(&self) -> &'static str {
        match &self.inner {
            Inner::Artifact { .. } => "pjrt",
            Inner::Host { .. } => "host",
        }
    }

    /// Install trained parameters (full names, e.g. "params.embed").
    /// Artifact engine only — the host engine owns its model's weights.
    pub fn set_params(&mut self, params: &[(String, Literal)]) -> crate::Result<()> {
        let Inner::Artifact { exe, inputs, .. } = &mut self.inner else {
            bail!("host decode engine owns its parameters");
        };
        let man = exe.manifest.clone();
        for (name, lit) in params {
            let i = man.input_index(name)?;
            inputs[i] = lit.clone();
        }
        Ok(())
    }

    /// Zero all recurrent state (start fresh sequences).
    pub fn reset_state(&mut self) -> crate::Result<()> {
        match &mut self.inner {
            Inner::Artifact { exe, inputs, state_inputs, .. } => {
                let man = exe.manifest.clone();
                for &i in state_inputs.iter() {
                    let spec = &man.inputs[i];
                    let zeros = vec![0f32; spec.element_count()];
                    inputs[i].copy_raw_from(&zeros)?;
                }
            }
            Inner::Host { states, .. } => {
                for m in states.iter_mut() {
                    m.data.fill(0.0);
                }
            }
        }
        Ok(())
    }

    /// One decode step: feed `tokens` ([batch] ids) at position `pos`,
    /// return flattened logits [batch * vocab].
    pub fn step(&mut self, tokens: &[i32], pos: usize) -> crate::Result<Vec<f32>> {
        if tokens.len() != self.batch {
            bail!("decode batch is {}, got {} tokens", self.batch, tokens.len());
        }
        if pos >= self.max_seq_len {
            bail!("pos {} exceeds decode cache bound {}", pos, self.max_seq_len);
        }
        let _sp = obs::trace::span("decode.step");
        decode_tokens_counter().add(self.batch as u64);
        match &mut self.inner {
            Inner::Artifact { exe, inputs, carry, idx_token, idx_pos, .. } => {
                inputs[*idx_token].copy_raw_from(tokens)?;
                inputs[*idx_pos].copy_raw_from(&[pos as i32])?;
                let mut outs = exe.execute(inputs)?;
                let man = &exe.manifest;
                let logits = outs[man.output_index("logits")?].to_vec::<f32>()?;
                for &(o, i) in carry.iter() {
                    inputs[i] =
                        std::mem::replace(&mut outs[o], Literal::scalar(0f32));
                }
                Ok(logits)
            }
            Inner::Host { model, backend, states } => {
                // route the delta-rule recurrence through the Backend trait
                model.decode_step(states, tokens, |sts, q, k, v, beta| {
                    Backend::decode_step(backend, sts, q, k, v, beta)
                })
            }
        }
    }

    /// Generate continuations for a batch of prompts (token ids).  Returns
    /// one Vec per row containing ONLY the newly generated tokens.
    pub fn generate(&mut self, prompts: &[Vec<i32>], max_new: usize,
                    sampling: Sampling, seed: u64)
                    -> crate::Result<Vec<Vec<i32>>> {
        if prompts.len() > self.batch {
            bail!("{} prompts > engine batch {}", prompts.len(), self.batch);
        }
        if prompts.iter().any(|p| p.is_empty()) {
            bail!("empty prompt");
        }
        self.reset_state()?;
        let mut rng = Rng::new(seed);
        let n = prompts.len();
        let _sp = obs::trace::span_with("decode.generate", || {
            vec![("prompts", n as f64), ("max_new", max_new as f64)]
        });
        let max_prompt = prompts.iter().map(|p| p.len()).max().unwrap();
        let total_steps = (max_prompt + max_new).min(self.max_seq_len);

        let mut generated: Vec<Vec<i32>> = vec![vec![]; n];
        let mut feed = vec![0i32; self.batch];
        for (b, p) in prompts.iter().enumerate() {
            feed[b] = p[0];
        }
        for pos in 0..total_steps {
            let logits = self.step(&feed, pos)?;
            for b in 0..n {
                let next_pos = pos + 1;
                let row = &logits[b * self.vocab..(b + 1) * self.vocab];
                if next_pos < prompts[b].len() {
                    // still consuming the prompt
                    feed[b] = prompts[b][next_pos];
                } else if generated[b].len() < max_new {
                    let tok = sample_from(row, sampling, &mut rng);
                    generated[b].push(tok);
                    feed[b] = tok;
                }
            }
            if (0..n).all(|b| generated[b].len() >= max_new) {
                break;
            }
        }
        Ok(generated)
    }
}

/// Sample a token id from a logits row.
pub fn sample_from(logits: &[f32], sampling: Sampling, rng: &mut Rng) -> i32 {
    match sampling {
        Sampling::Greedy => argmax(logits) as i32,
        Sampling::TopK { temperature, k } => {
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k > 0 && k < logits.len() {
                idx.sort_unstable_by(|&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap()
                });
                idx.truncate(k);
            }
            let t = temperature.max(1e-4);
            let mx = idx.iter().map(|&i| logits[i]).fold(f32::MIN, f32::max);
            let weights: Vec<f32> = idx.iter()
                .map(|&i| ((logits[i] - mx) / t).exp())
                .collect();
            idx[rng.categorical(&weights)] as i32
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::HostModelCfg;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0, -2.0]), 1);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(1);
        let l = vec![0.0, 10.0, 5.0];
        assert_eq!(sample_from(&l, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Rng::new(2);
        let l = vec![0.0, 10.0, 9.0, -50.0];
        for _ in 0..100 {
            let t = sample_from(
                &l, Sampling::TopK { temperature: 1.0, k: 2 }, &mut rng);
            assert!(t == 1 || t == 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(3);
        let l = vec![1.0, 2.0, 3.0];
        let hits = (0..200)
            .filter(|_| sample_from(
                &l, Sampling::TopK { temperature: 0.01, k: 0 }, &mut rng) == 2)
            .count();
        assert!(hits > 195);
    }

    fn host_engine() -> DecodeEngine {
        let model = HostModel::new(HostModelCfg::tiny(), 3, 2).unwrap();
        DecodeEngine::host(model, 4, 32)
    }

    #[test]
    fn host_engine_generates_without_artifacts() {
        let mut eng = host_engine();
        assert_eq!(eng.backend_name(), "host");
        assert_eq!(eng.vocab, HostModelCfg::tiny().vocab);
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 2, 3], vec![4, 5], vec![6], vec![7, 8, 9]];
        let gens = eng.generate(&prompts, 6, Sampling::Greedy, 0).unwrap();
        assert_eq!(gens.len(), 4);
        for g in &gens {
            assert_eq!(g.len(), 6);
            assert!(g.iter().all(|&t| (t as usize) < eng.vocab));
        }
    }

    #[test]
    fn host_engine_decode_is_deterministic_after_reset() {
        let mut eng = host_engine();
        let toks = [1i32, 2, 3, 4];
        let a = eng.step(&toks, 0).unwrap();
        eng.reset_state().unwrap();
        let b = eng.step(&toks, 0).unwrap();
        assert_eq!(a, b);
        // rejects the artifact-only param override
        assert!(eng.set_params(&[]).is_err());
    }

    #[test]
    fn route_falls_back_to_host_without_artifacts() {
        // an empty dir has no decode manifest — must route to host with
        // the tiny-model shape, and build a working engine from it
        let dir = std::env::temp_dir().join("deltanet_route_test_empty");
        std::fs::create_dir_all(&dir).unwrap();
        let route = DecodeRoute::resolve(&dir, "deltanet_tiny").unwrap();
        assert_eq!(route.backend, "host");
        let tiny = HostModelCfg::tiny();
        assert_eq!(route.vocab, tiny.vocab);
        assert_eq!(route.d_model, tiny.d_model);
        assert_eq!(route.n_heads, tiny.n_heads);
        assert_eq!(route.batch, 8);
        assert_eq!(route.max_seq_len, 64);
        let mut eng = route.build(0).unwrap();
        assert_eq!(eng.backend_name(), "host");
        assert_eq!(eng.vocab, route.vocab);
        assert_eq!(eng.batch, route.batch);
        let gens = eng.generate(&[vec![1, 2, 3]], 4,
                                Sampling::Greedy, 0).unwrap();
        assert_eq!(gens[0].len(), 4);
    }

    #[test]
    fn route_is_send_for_worker_handoff() {
        // spawn_auto ships the route into the serving thread
        fn assert_send<T: Send + 'static>() {}
        assert_send::<DecodeRoute>();
    }

    #[test]
    fn host_engine_bounds_checked() {
        let mut eng = host_engine();
        assert!(eng.step(&[1, 2], 0).is_err()); // wrong batch
        assert!(eng.step(&[1, 2, 3, 4], 32).is_err()); // pos out of range
    }
}
