//! L3 coordination: the training loop, the evaluator driver, the batched
//! recurrent-decoding engine, and the async serving front-end.
//!
//! The coordinator owns everything the paper's §D recipe puts outside the
//! compiled step function: LR scheduling, data, logging, checkpoints,
//! batching policy — while the compiled artifacts own fwd+bwd+AdamW.

pub mod generate;
pub mod server;
pub mod trainer;

pub use generate::DecodeEngine;
pub use server::{ServeEngine, ServeStats};
pub use trainer::{EvalOutcome, TrainReport, Trainer};
