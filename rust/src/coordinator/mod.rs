//! L3 coordination: the training loop, the evaluator driver, the batched
//! recurrent-decoding engine, and the async serving front-end.
//!
//! The coordinator owns everything the paper's §D recipe puts outside the
//! compiled step function: LR scheduling, data, logging, checkpoints,
//! batching policy — while the compiled artifacts own fwd+bwd+AdamW.
//!
//! Two compute backends feed these paths:
//!   * the PJRT runtime executing AOT artifacts (`crate::runtime`), and
//!   * the batched host kernel backend (`host`), which exposes the
//!     chunkwise/recurrent DeltaNet kernels under the kernel-artifact
//!     signature so repro harnesses, benches and decode experiments run
//!     with no accelerator toolchain present.

pub mod backend;
pub mod generate;
pub mod host;
pub mod instrument;
pub mod server;
pub mod trainer;

pub use backend::{
    host_training_backend, select_kernel_backend, Backend, PjrtBackend,
};
pub use generate::{DecodeEngine, DecodeRoute};
pub use host::{HostKernelBackend, KernelForm, StepBreakdown};
pub use instrument::InstrumentedBackend;
pub use server::{ServeEngine, ServeStats};
pub use trainer::{EvalOutcome, TrainReport, Trainer};
