//! `deltanet` — the L3 coordinator CLI.
//!
//! Self-contained after `make artifacts`: loads AOT-compiled HLO artifacts
//! via PJRT and never touches Python.
//!
//! ```text
//! deltanet train      --artifact deltanet_tiny --task mqar --steps 300
//! deltanet eval       --artifact deltanet_tiny --task mqar
//! deltanet generate   --artifact deltanet_tiny --prompt 1,2,3 --max-new 16
//! deltanet serve-demo --artifact deltanet_tiny --requests 32
//! deltanet reproduce  fig1|fig2|fig3|fig4|tab1|tab2|tab3|ablate|chunks|all
//! deltanet inspect    [--artifact NAME]
//! ```

use std::path::PathBuf;

use deltanet::config::{DataConfig, LrSchedule, RunConfig};
use deltanet::Context;
use deltanet::coordinator::generate::Sampling;
use deltanet::coordinator::server::GenRequest;
use deltanet::coordinator::{DecodeEngine, ServeEngine, Trainer};
use deltanet::data::batcher::Split;
use deltanet::repro::{self, ReproOpts};
use deltanet::runtime::Runtime;
use deltanet::util::args::Args;

const USAGE: &str = "\
deltanet — DeltaNet (NeurIPS 2024) Rust+JAX+Pallas reproduction

USAGE: deltanet <command> [--artifacts DIR] [options]

COMMANDS:
  train       --artifact NAME --task TASK --steps N [--seed S]
              [--eval-every N] [--log PATH] [--checkpoint PATH]
              [--resume PATH]
  eval        --artifact NAME --task TASK [--batches N] [--checkpoint PATH]
  generate    --artifact NAME --prompt 1,2,3 --max-new N [--temperature T]
              [--checkpoint PATH]
  serve-demo  --artifact NAME [--requests N] [--max-new N]
  reproduce   fig1|fig2|fig3|fig4|tab1|tab2|tab3|ablate|chunks|all
              [--steps N] [--seed S] [--eval-batches N]
  inspect     [--artifact NAME]
  trace-check PATH   validate an observability artifact: a Chrome
                     trace-event JSON (DELTANET_TRACE), a flight-recorder
                     dump (FLIGHT_*.json / /flight.json), or a metrics
                     snapshot (/metrics.json) — schema + monotonic
                     timestamps
  bench-diff  CURRENT.json [--baseline PATH] [--threshold X] [--json OUT]
              [--warn-only]
              compare a BENCH_*.json report against the committed baseline
              (rust/benches/baselines/<name> by default); exits non-zero
              on regression unless --warn-only

TASKS: corpus | mqar | mqar:<pairs> | mad:<task> | regbench | recall:<style>
  mad tasks: compress fuzzy_recall in_context_recall memorize noisy_recall
             selective_copy
  recall styles: swde squad fda

Set DELTANET_TRACE=out.json to record a hierarchical span trace of any
command; open the file at https://ui.perfetto.dev.  The flight recorder
is always on (DELTANET_FLIGHT=off disables): any panic dumps the last
events + metrics to FLIGHT_<run>.json (DELTANET_RUN_ID, DELTANET_FLIGHT_DIR,
DELTANET_FLIGHT_EVENTS configure it).  DELTANET_HEALTH=warn|skip|abort
sets the training health policy (window/spike/plateau knobs:
DELTANET_HEALTH_WINDOW, DELTANET_HEALTH_SPIKE, DELTANET_HEALTH_PLATEAU)";

fn parse_task(task: &str, seed: u64) -> deltanet::Result<DataConfig> {
    Ok(match task {
        "corpus" => DataConfig::Corpus { seed },
        "mqar" => DataConfig::Mqar { num_pairs: 8, seed },
        "regbench" => DataConfig::RegBench { seed },
        t if t.starts_with("mad:") =>
            DataConfig::Mad { task: t[4..].to_string(), seed },
        t if t.starts_with("recall:") =>
            DataConfig::Recall { style: t[7..].to_string(), seed },
        t if t.starts_with("mqar:") =>
            DataConfig::Mqar { num_pairs: t[5..].parse()?, seed },
        other => deltanet::bail!("unknown task {other:?}\n\n{USAGE}"),
    })
}

fn main() -> deltanet::Result<()> {
    let args = Args::from_env(&["warn-only"])?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    deltanet::obs::trace::init_from_env();
    deltanet::obs::flight::init_from_env();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let runtime = Runtime::new(&artifacts).context("creating PJRT runtime")?;
    let seed: u64 = args.get_parse("seed", 0)?;

    match cmd {
        "train" => {
            let artifact = args.get_or("artifact", "deltanet_tiny");
            let task = args.get_or("task", "corpus");
            let steps: usize = args.get_parse("steps", 300)?;
            let data = parse_task(&task, seed)?;
            let mut trainer = Trainer::new(&runtime, &artifact, seed)?;
            if let Some(ckpt) = args.get("resume") {
                trainer.load_checkpoint(std::path::Path::new(ckpt))?;
                println!("resumed from {ckpt}");
            }
            println!("training {artifact} on {task}: {} params, {}x{} batch",
                     trainer.param_count(), trainer.batch, trainer.seq_len);
            let cfg = RunConfig {
                artifact: artifact.clone(),
                artifacts_dir: artifacts.clone(),
                steps,
                seed,
                lr: LrSchedule::paper_default(steps),
                data: data.clone(),
                eval_every: args.get_parse("eval-every", 0)?,
                eval_batches: 8,
                log_path: args.get("log").map(PathBuf::from),
                checkpoint_path: args.get("checkpoint").map(PathBuf::from),
            };
            let split = Split::from_config(&data);
            let mut train_task = split.train;
            let mut eval_task = split.eval;
            let report = trainer.train(&cfg, train_task.as_mut(),
                                       Some(eval_task.as_mut()))?;
            let fmt_loss = |l: Option<f32>| match l {
                Some(v) => format!("{v:.4}"),
                None => "n/a".to_string(),
            };
            println!("loss {} -> {} | {:.0} tok/s | {:.1}s",
                     fmt_loss(report.first_loss),
                     fmt_loss(report.final_loss),
                     report.tokens_per_sec, report.elapsed_secs);
            for (step, e) in &report.evals {
                println!("  eval@{step}: ppl {:.3} acc {:.1}%",
                         e.ppl, 100.0 * e.accuracy);
            }
        }
        "eval" => {
            let artifact = args.get_or("artifact", "deltanet_tiny");
            let task = args.get_or("task", "corpus");
            let data = parse_task(&task, seed)?;
            let mut trainer = Trainer::new(&runtime, &artifact, seed)?;
            if let Some(ckpt) = args.get("checkpoint") {
                trainer.load_checkpoint(std::path::Path::new(ckpt))?;
            }
            let mut task_gen = deltanet::data::build_task(&data);
            let batches: usize = args.get_parse("batches", 8)?;
            let e = trainer.evaluate(task_gen.as_mut(), batches)?;
            println!("{artifact} on {task}: nll {:.4} ppl {:.3} acc {:.1}%",
                     e.nll, e.ppl, 100.0 * e.accuracy);
        }
        "generate" => {
            let artifact = args.get_or("artifact", "deltanet_tiny");
            let mut engine = DecodeEngine::new(&runtime, &artifact, 0)?;
            if let Some(ckpt) = args.get("checkpoint") {
                let mut t = Trainer::new(&runtime, &artifact, 0)?;
                t.load_checkpoint(std::path::Path::new(ckpt))?;
                engine.set_params(&t.param_literals()?)?;
            }
            let prompt: Vec<i32> = args.get_or("prompt", "1,2,3").split(',')
                .map(|s| s.trim().parse::<i32>().context("prompt token"))
                .collect::<deltanet::Result<_>>()?;
            let temperature: f32 = args.get_parse("temperature", 0.0)?;
            let max_new: usize = args.get_parse("max-new", 16)?;
            let sampling = if temperature > 0.0 {
                Sampling::TopK { temperature, k: 0 }
            } else {
                Sampling::Greedy
            };
            let out = engine.generate(&[prompt.clone()], max_new,
                                      sampling, seed)?;
            println!("prompt: {prompt:?}");
            println!("generated: {:?}", out[0]);
        }
        "serve-demo" => {
            let artifact = args.get_or("artifact", "deltanet_tiny");
            let requests: usize = args.get_parse("requests", 32)?;
            let max_new: usize = args.get_parse("max-new", 16)?;
            // DecodeRoute picks the engine (the engine itself is built
            // inside the serving thread — PJRT handles are not Send) and
            // reports the vocab to size prompts against
            let (serve, route) = ServeEngine::spawn_auto(
                &artifacts, &artifact, 0, Sampling::Greedy,
                std::time::Duration::from_millis(5))?;
            if route.backend == "host" {
                println!("no decode artifact — serving the host engine");
            }
            let vocab = route.vocab as i32;
            let tickets: Vec<_> = (0..requests)
                .map(|i| {
                    let prompt: Vec<i32> = (0..4 + (i % 5))
                        .map(|j| ((i + j) as i32) % vocab)
                        .collect();
                    serve.submit(GenRequest { prompt, max_new })
                })
                .collect::<deltanet::Result<_>>()?;
            let mut ok = 0;
            for t in tickets {
                let resp = t.wait()?;
                deltanet::ensure!(resp.tokens.len() <= max_new);
                ok += 1;
            }
            let st = serve.shutdown();
            println!("served {ok}/{requests} requests in {} batches \
                      (mean occupancy {:.1})",
                     st.batches, st.mean_batch_occupancy());
            println!("mean latency {:.1} ms | decode throughput {:.0} tok/s",
                     st.mean_latency_ms(), st.tokens_per_sec());
        }
        "reproduce" => {
            let which = args.positional.get(1)
                .map(|s| s.as_str()).unwrap_or("all");
            let opts = ReproOpts {
                steps: args.get_parse("steps", 300)?,
                seed,
                eval_batches: args.get_parse("eval-batches", 8)?,
                lr_peak: args.get_parse("lr-peak", 1e-3)?,
            };
            if which == "chunks" {
                repro::fig1::chunk_sweep(&runtime, &opts)?;
            } else {
                repro::run(&runtime, which, &opts)?;
            }
        }
        "trace-check" => {
            let path = args.positional.get(1)
                .context("usage: deltanet trace-check PATH")?;
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?;
            let j = deltanet::util::json::Json::parse(&text)
                .with_context(|| format!("{path} is not valid JSON"))?;
            // dispatch on the document shape: span trace, flight dump,
            // or metrics snapshot
            if j.get("traceEvents").is_some() {
                check_trace(&j, path)?;
            } else if j.get("schema").and_then(|s| s.as_str().ok())
                == Some(deltanet::obs::flight::SCHEMA)
            {
                check_flight(&j, path)?;
            } else if j.get("counters").is_some()
                && j.get("histograms").is_some()
            {
                check_metrics_snapshot(&j, path)?;
            } else {
                deltanet::bail!(
                    "{path}: unrecognized document — expected traceEvents \
                     (span trace), schema {:?} (flight dump), or \
                     counters/gauges/histograms (metrics snapshot)",
                    deltanet::obs::flight::SCHEMA);
            }
        }
        "bench-diff" => {
            use deltanet::obs::regress;
            let current = args.positional.get(1).context(
                "usage: deltanet bench-diff CURRENT.json [--baseline PATH] \
                 [--threshold X] [--json OUT] [--warn-only]")?;
            let cur_path = std::path::Path::new(current);
            let cur = regress::load_report(cur_path)?;
            let base_path = match args.get("baseline") {
                Some(p) => PathBuf::from(p),
                None => regress::default_baseline_path(cur_path)?,
            };
            if !base_path.exists() {
                // bootstrap-friendly: a missing baseline is advice to
                // commit one, not a failure
                println!("bench-diff: no baseline at {} — commit the \
                          current report there to start gating",
                         base_path.display());
                return Ok(());
            }
            let base = regress::load_report(&base_path)?;
            let threshold = match args.get("threshold") {
                Some(t) => Some(t.parse::<f64>()
                    .context("bad --threshold value")?),
                None => None,
            };
            let d = regress::diff(&cur, &base, threshold);
            print!("{}", d.render_text());
            if let Some(out) = args.get("json") {
                std::fs::write(out, d.to_json().render() + "\n")?;
                println!("machine report: {out}");
            }
            let n = d.regressions();
            if n > 0 {
                if args.has("warn-only") {
                    println!("bench-diff: {n} regression(s) vs {} \
                              (warn-only)", base_path.display());
                } else {
                    deltanet::bail!("bench-diff: {n} regression(s) vs {}",
                                    base_path.display());
                }
            } else {
                println!("bench-diff: no regressions vs {}",
                         base_path.display());
            }
        }
        "inspect" => match args.get("artifact") {
            Some(name) => {
                let exe = runtime.load(name)?;
                let m = &exe.manifest;
                println!("{} ({}): {} inputs, {} outputs, {} params, \
                          batch {} × seq {} | compile {:.2}s",
                         m.name, m.kind, m.inputs.len(), m.outputs.len(),
                         m.param_count(), m.batch, m.seq_len,
                         exe.compile_time.as_secs_f64());
                if let Some(cfg) = &m.config {
                    println!("  arch={} d={} layers={} heads={} chunk={}",
                             cfg.arch, cfg.d_model, cfg.n_layers,
                             cfg.n_heads, cfg.chunk_size);
                }
            }
            None => {
                for name in runtime.list_artifacts()? {
                    println!("{name}");
                }
            }
        },
        other => {
            deltanet::bail!("unknown command {other:?}\n\n{USAGE}");
        }
    }
    if let Some(path) = deltanet::obs::trace::write_trace_from_env()? {
        println!("trace written to {} (open at https://ui.perfetto.dev)",
                 path.display());
    }
    Ok(())
}

// ---------------------------------------------------- trace-check validators

use deltanet::util::json::Json;

/// Chrome trace-event document (DELTANET_TRACE output).
fn check_trace(j: &Json, path: &str) -> deltanet::Result<()> {
    let events = j.get("traceEvents")
        .context("missing traceEvents key")?
        .as_arr()?;
    let mut spans = 0usize;
    for (i, e) in events.iter().enumerate() {
        let ph = e.get("ph")
            .with_context(|| format!("event {i} missing ph"))?
            .as_str()?;
        e.get("name")
            .with_context(|| format!("event {i} missing name"))?
            .as_str()?;
        match ph {
            "X" => {
                e.get("ts")
                    .with_context(|| format!("event {i} missing ts"))?
                    .as_f64()?;
                e.get("dur")
                    .with_context(|| format!("event {i} missing dur"))?
                    .as_f64()?;
                spans += 1;
            }
            "M" => {}
            other => deltanet::bail!("event {i} has unexpected phase {other:?}"),
        }
    }
    deltanet::ensure!(spans > 0,
                      "{path} contains no span events — the traced \
                       run recorded nothing");
    println!("{path}: OK trace ({spans} spans, {} events)", events.len());
    Ok(())
}

/// Flight-recorder dump (FLIGHT_*.json or the /flight.json payload):
/// strictly increasing seq, non-decreasing timestamps, known kinds,
/// numeric-or-null field values, metrics snapshot attached.
fn check_flight(j: &Json, path: &str) -> deltanet::Result<()> {
    const KINDS: [&str; 7] = ["span_open", "span_close", "step", "counter",
                              "health", "panic", "mark"];
    j.get("run").context("flight dump missing run id")?.as_str()?;
    let events = j.get("events")
        .context("flight dump missing events array")?
        .as_arr()?;
    let mut last_seq = 0u64;
    let mut last_ts = f64::NEG_INFINITY;
    for (i, e) in events.iter().enumerate() {
        let seq = e.get("seq")
            .with_context(|| format!("event {i} missing seq"))?
            .as_u64()?;
        deltanet::ensure!(seq > last_seq,
                          "event {i}: seq {seq} not strictly increasing \
                           (previous {last_seq})");
        last_seq = seq;
        let ts = e.get("ts_us")
            .with_context(|| format!("event {i} missing ts_us"))?
            .as_f64()?;
        // ring slots are snapshotted, not fenced against each other, so
        // allow a small clock skew between adjacent writers
        deltanet::ensure!(ts >= last_ts - 1e4,
                          "event {i}: ts_us {ts} ran backwards vs {last_ts}");
        last_ts = last_ts.max(ts);
        let kind = e.get("kind")
            .with_context(|| format!("event {i} missing kind"))?
            .as_str()?;
        deltanet::ensure!(KINDS.contains(&kind),
                          "event {i}: unknown kind {kind:?}");
        e.get("name")
            .with_context(|| format!("event {i} missing name"))?
            .as_str()?;
        match e.get("fields") {
            Some(Json::Obj(m)) => {
                for (k, v) in m {
                    deltanet::ensure!(
                        matches!(v, Json::Num(_) | Json::Null),
                        "event {i}: field {k:?} is not numeric or null");
                }
            }
            _ => deltanet::bail!("event {i} missing fields object"),
        }
    }
    let metrics = j.get("metrics")
        .context("flight dump missing metrics snapshot")?;
    check_metrics_snapshot(metrics, "(embedded metrics)")?;
    println!("{path}: OK flight dump ({} events, last seq {last_seq})",
             events.len());
    Ok(())
}

/// Metrics snapshot (/metrics.json or the flight dump's `metrics` key):
/// numeric counters/gauges, histogram quantiles ordered p50 ≤ p95 ≤ p99.
fn check_metrics_snapshot(j: &Json, path: &str) -> deltanet::Result<()> {
    for section in ["counters", "gauges"] {
        match j.get(section) {
            Some(Json::Obj(m)) => {
                for (k, v) in m {
                    v.as_f64().with_context(
                        || format!("{section}.{k} is not a number"))?;
                }
            }
            _ => deltanet::bail!("metrics snapshot missing {section} object"),
        }
    }
    let hists = match j.get("histograms") {
        Some(Json::Obj(m)) => m,
        _ => deltanet::bail!("metrics snapshot missing histograms object"),
    };
    for (name, h) in hists {
        let f = |key: &str| -> deltanet::Result<f64> {
            h.get(key)
                .with_context(|| format!("histogram {name} missing {key}"))?
                .as_f64()
        };
        f("count")?;
        f("mean_ms")?;
        let (p50, p95, p99) = (f("p50_ms")?, f("p95_ms")?, f("p99_ms")?);
        let max = f("max_ms")?;
        deltanet::ensure!(p50 <= p95 && p95 <= p99 && p99 <= max + 1e-9,
                          "histogram {name}: quantiles out of order \
                           (p50 {p50}, p95 {p95}, p99 {p99}, max {max})");
    }
    println!("{path}: OK metrics snapshot ({} counters, {} histograms)",
             match j.get("counters") { Some(Json::Obj(m)) => m.len(), _ => 0 },
             hists.len());
    Ok(())
}
