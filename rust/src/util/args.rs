//! Tiny CLI argument parser: `--flag value` pairs + positionals.

use std::collections::HashMap;

use crate::bail;
use crate::util::error::Context;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `switch_names` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I, switch_names: &[&str]) -> crate::Result<Args> {
        let mut positional = vec![];
        let mut flags = HashMap::new();
        let mut switches = vec![];
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    switches.push(name.to_string());
                } else {
                    let v = it.next()
                        .with_context(|| format!("--{name} needs a value"))?;
                    flags.insert(name.to_string(), v);
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { positional, flags, switches })
    }

    pub fn from_env(switch_names: &[&str]) -> crate::Result<Args> {
        Self::parse(std::env::args().skip(1), switch_names)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T)
        -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(e) => bail!("bad value for --{name}: {e}"),
            },
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_positionals_switches() {
        let a = Args::parse(argv("train --steps 100 --quick --name=x pos2"),
                            &["quick"]).unwrap();
        assert_eq!(a.positional, vec!["train", "pos2"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("name"), Some("x"));
        assert!(a.has("quick"));
    }

    #[test]
    fn typed_access_and_defaults() {
        let a = Args::parse(argv("--steps 42"), &[]).unwrap();
        assert_eq!(a.get_parse("steps", 0usize).unwrap(), 42);
        assert_eq!(a.get_parse("missing", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("steps", 0).is_ok());
        let bad = Args::parse(argv("--steps abc"), &[]).unwrap();
        assert!(bad.get_parse::<usize>("steps", 0).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("--flag"), &[]).is_err());
    }
}
