//! Bench harness (criterion is unavailable offline): warmup + repeated
//! timing with median/p10/p90, printed in a stable grep-able format used by
//! `cargo bench` targets and EXPERIMENTS.md, plus JSON reports the CI
//! bench-smoke job archives (`BENCH_<suite>.json` at the repo root) so the
//! perf trajectory is tracked per PR.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<48} median {:>10.3} ms   p10 {:>10.3} ms   p90 {:>10.3} ms   ({} reps)",
            self.name, self.median_s * 1e3, self.p10_s * 1e3,
            self.p90_s * 1e3, self.reps);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("reps", Json::num(self.reps as f64)),
            ("median_s", Json::num(self.median_s)),
            ("p10_s", Json::num(self.p10_s)),
            ("p90_s", Json::num(self.p90_s)),
        ])
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        Ok(BenchResult {
            name: v.req("name")?.as_str()?.to_string(),
            reps: v.req("reps")?.as_usize()?,
            median_s: v.req("median_s")?.as_f64()?,
            p10_s: v.req("p10_s")?.as_f64()?,
            p90_s: v.req("p90_s")?.as_f64()?,
        })
    }
}

fn summarize(name: &str, mut times: Vec<f64>) -> BenchResult {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        reps: times.len(),
        median_s: q(0.5),
        p10_s: q(0.1),
        p90_s: q(0.9),
    };
    r.print();
    r
}

/// Time `f` with `warmup` unrecorded calls then `reps` recorded ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F)
                         -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, times)
}

/// Fallible variant: aborts the bench on the first error.
pub fn bench_result<F>(name: &str, warmup: usize, reps: usize, mut f: F)
                       -> crate::Result<BenchResult>
where
    F: FnMut() -> crate::Result<()>,
{
    for _ in 0..warmup {
        f()?;
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(summarize(name, times))
}

/// Repository root: parent of the crate dir (`rust/`), falling back to the
/// current directory for out-of-tree checkouts.
pub fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Serialize a bench suite to `BENCH_<suite>.json` at the repo root and
/// return the path (CI uploads these as artifacts).
pub fn write_report(suite: &str, results: &[BenchResult])
                    -> crate::Result<PathBuf> {
    let path = repo_root().join(format!("BENCH_{suite}.json"));
    let json = Json::obj(vec![
        ("suite", Json::str(suite)),
        ("results",
         Json::Arr(results.iter().map(BenchResult::to_json).collect())),
    ]);
    std::fs::write(&path, json.render() + "\n")?;
    Ok(path)
}

/// True when the bench should run a reduced problem set (CI smoke job sets
/// `DELTANET_BENCH_SMOKE=1`).
pub fn smoke_mode() -> bool {
    std::env::var_os("DELTANET_BENCH_SMOKE").is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_ordering() {
        let r = bench("t", 1, 11, || std::thread::sleep(
            std::time::Duration::from_micros(100)));
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
        assert!(r.median_s >= 50e-6);
    }

    #[test]
    fn fallible_propagates() {
        let e = bench_result("t", 0, 1, || crate::bail!("boom"));
        assert!(e.is_err());
    }

    #[test]
    fn json_roundtrip() {
        let r = BenchResult {
            name: "kernel_x".into(),
            reps: 5,
            median_s: 0.125,
            p10_s: 0.1,
            p90_s: 0.2,
        };
        let back =
            BenchResult::from_json(&Json::parse(&r.to_json().render())
                .unwrap()).unwrap();
        assert_eq!(back.name, "kernel_x");
        assert_eq!(back.reps, 5);
        assert!((back.median_s - 0.125).abs() < 1e-12);
    }

    #[test]
    fn report_written_at_repo_root() {
        let r = BenchResult {
            name: "t".into(),
            reps: 1,
            median_s: 1.0,
            p10_s: 1.0,
            p90_s: 1.0,
        };
        let path = write_report("selftest", &[r]).unwrap();
        assert!(path.ends_with("BENCH_selftest.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.req("suite").unwrap().as_str().unwrap(), "selftest");
        assert_eq!(v.req("results").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }
}
