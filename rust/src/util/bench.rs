//! Bench harness (criterion is unavailable offline): warmup + repeated
//! timing with median/p10/p90, printed in a stable grep-able format used by
//! `cargo bench` targets and EXPERIMENTS.md.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<48} median {:>10.3} ms   p10 {:>10.3} ms   p90 {:>10.3} ms   ({} reps)",
            self.name, self.median_s * 1e3, self.p10_s * 1e3,
            self.p90_s * 1e3, self.reps);
    }
}

/// Time `f` with `warmup` unrecorded calls then `reps` recorded ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F)
                         -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        reps,
        median_s: q(0.5),
        p10_s: q(0.1),
        p90_s: q(0.9),
    };
    r.print();
    r
}

/// Fallible variant: aborts the bench on the first error.
pub fn bench_result<F>(name: &str, warmup: usize, reps: usize, mut f: F)
                       -> anyhow::Result<BenchResult>
where
    F: FnMut() -> anyhow::Result<()>,
{
    for _ in 0..warmup {
        f()?;
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        reps,
        median_s: q(0.5),
        p10_s: q(0.1),
        p90_s: q(0.9),
    };
    r.print();
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_ordering() {
        let r = bench("t", 1, 11, || std::thread::sleep(
            std::time::Duration::from_micros(100)));
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
        assert!(r.median_s >= 50e-6);
    }

    #[test]
    fn fallible_propagates() {
        let e = bench_result("t", 0, 1, || anyhow::bail!("boom"));
        assert!(e.is_err());
    }
}
