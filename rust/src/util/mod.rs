//! In-tree utilities replacing unavailable external crates (offline build):
//! JSON, CLI argument parsing, bench timing, property-test harness, and a
//! small thread pool.

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod threadpool;
