//! In-tree utilities replacing unavailable external crates (offline build):
//! error handling (anyhow), JSON (serde), CLI argument parsing, bench
//! timing (criterion), a property-test harness (proptest), and a scoped
//! thread pool (rayon).

pub mod args;
pub mod bench;
pub mod error;
pub mod json;
pub mod prop;
pub mod threadpool;
