//! Property-test harness (proptest is unavailable offline): seeded random
//! case generation with failure reporting that names the reproducing seed.

use crate::tensor::rng::Rng;

/// Run `cases` random property checks.  `f` gets a per-case RNG; return
/// Err(description) to fail.  Panics with the reproducing seed on failure.
pub fn check<F>(name: &str, cases: usize, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_seeded(name, 0xda7a, cases, f)
}

pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name:?} failed on case {case} \
                    (seed {seed:#x}): {msg}");
        }
    }
}

/// Helpers for common generator patterns.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    rng.range(lo, hi)
}

pub fn f32_vec(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * std).collect()
}

/// β-like vector in (0,1).
pub fn unit_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| 1.0 / (1.0 + (-rng.normal()).exp())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("x+0==x", 50, |rng| {
            let x = rng.normal();
            if x + 0.0 == x { Ok(()) } else { Err(format!("x={x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn failing_property_reports_seed() {
        check("always-false", 3, |_| Err("nope".into()));
    }

    #[test]
    fn unit_vec_in_range() {
        let mut rng = Rng::new(1);
        assert!(unit_vec(&mut rng, 100).iter()
            .all(|&b| b > 0.0 && b < 1.0));
    }
}
