//! Minimal JSON parser + serializer (this build is fully offline; serde is
//! not available, so the manifest/config/metrics plumbing uses this).
//!
//! Supports the full JSON grammar needed by the artifact manifests and run
//! configs: objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::bail;
use crate::util::error::Context;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key).with_context(|| format!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> crate::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> crate::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> crate::Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_u64(&self) -> crate::Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> crate::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> crate::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    // ----------------------------------------------------------- parsing
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -------------------------------------------------------- serializing
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut v = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self.bytes.get(self.pos + 1..self.pos + 5)
                                .context("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code)
                                .context("bad \\u escape")?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse().context("bad number")?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.req("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().req("d").unwrap().as_f64().unwrap(),
                   -2.5);
        // render → parse is stable
        let re = Json::parse(&v.render()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{"name":"t","inputs":[{"name":"params.embed",
            "shape":[64,32],"dtype":"f32","role":"param",
            "init":"normal:0.02"}]}"#;
        let v = Json::parse(src).unwrap();
        let inp = &v.req("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.req("dtype").unwrap().as_str().unwrap(), "f32");
        let shape: Vec<usize> = inp.req("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![64, 32]);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cA\n");
        let s = Json::str("x\"y\nz");
        assert_eq!(Json::parse(&s.render()).unwrap(), s);
    }

    #[test]
    fn numbers() {
        for (src, want) in [("0", 0.0), ("-1", -1.0), ("2.5", 2.5),
                            ("1e3", 1000.0), ("-2.5e-1", -0.25)] {
            assert_eq!(Json::parse(src).unwrap().as_f64().unwrap(), want);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo ∆""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∆");
    }
}
