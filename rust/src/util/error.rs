//! Crate-local error handling replacing the external `anyhow` dependency
//! (offline build — no external crates).
//!
//! Mirrors the subset of the anyhow API the crate uses:
//!   * [`Error`] — a message-carrying error; any `std::error::Error`
//!     converts into it (so `?` works on io/parse/xla results),
//!   * [`Result`] — alias with `Error` as the default error type,
//!   * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!     and `Option`,
//!   * `bail!` / `ensure!` / `err!` macros (exported at the crate root).
//!
//! Context is accumulated as an `outer: inner` message chain, matching how
//! the coordinator formats errors for operators (`{e:#}` and `{e}` render
//! the same chain).

use std::fmt;

/// A boxed-free, message-chained error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }

    /// Wrap with an outer context layer: `ctx: self`.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does NOT implement std::error::Error — exactly like
// anyhow — which is what makes this blanket conversion coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` / `Option`).
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            e.context(ctx)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            e.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)).into())
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn std_errors_convert_and_chain() {
        let e = io_fail().unwrap_err();
        let msg = format!("{e}");
        assert!(msg.starts_with("reading config: "), "got {msg:?}");
        // alternate formatting renders the same chain
        assert_eq!(format!("{e:#}"), msg);
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        let y: Option<u32> = Some(7);
        assert_eq!(y.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(4).unwrap(), 8);
        assert!(format!("{}", f(-1).unwrap_err()).contains("negative"));
        assert!(format!("{}", f(101).unwrap_err()).contains("too big"));
        let e = err!("ad-hoc {}", 5);
        assert_eq!(format!("{e}"), "ad-hoc 5");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f(x: i32) -> Result<()> {
            ensure!(x == 3);
            Ok(())
        }
        assert!(f(3).is_ok());
        assert!(format!("{}", f(4).unwrap_err()).contains("x == 3"));
    }
}
