//! Worker pool over std::thread (tokio/rayon unavailable offline), used by
//! the batched host kernels, the serving engine and parallel data
//! generation.
//!
//! Three submission APIs:
//!   * [`ThreadPool::execute`] — fire-and-forget (legacy surface),
//!   * [`ThreadPool::submit`]  — returns a [`JobHandle`] that can be
//!     `join()`ed and reports whether the job panicked,
//!   * [`ThreadPool::scope`]   — crossbeam-style scope: jobs may borrow
//!     from the caller's stack; the scope joins every spawned job before
//!     returning (this is the fan-out primitive the kernel layer uses).
//!
//! Workers catch panics from jobs, so a panicking job can no longer kill a
//! worker thread and wedge the pool (the old behaviour: after any worker
//! death, `execute` would eventually panic on a closed channel and the
//! only completion barrier was `Drop`).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::flight;
use crate::obs::metrics::{counter, gauge, Counter, Gauge};

/// A job panic is both a counter bump and a flight-recorder event, so a
/// post-mortem dump shows *when* the pool lost a job relative to the
/// surrounding train steps (the process-wide panic hook separately
/// records the panic site itself).
fn note_job_panic() {
    pool_metrics().job_panics.inc();
    flight::record(flight::EventKind::Panic, "pool.job_panic", &[]);
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pool-wide observability handles, interned once (all pools share them).
struct PoolMetrics {
    /// jobs enqueued but not yet picked up by a worker
    queue_depth: &'static Gauge,
    /// live worker threads across all pools
    workers: &'static Gauge,
    jobs_completed: &'static Counter,
    job_panics: &'static Counter,
    /// cumulative wall time workers spent executing jobs (utilization =
    /// busy_us / (workers × elapsed))
    busy_us: &'static Counter,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        queue_depth: gauge("pool.queue_depth"),
        workers: gauge("pool.workers"),
        jobs_completed: counter("pool.jobs_completed"),
        job_panics: counter("pool.job_panics"),
        busy_us: counter("pool.busy_us"),
    })
}

/// Fixed-size thread pool executing boxed jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<_> = (0..n.max(1))
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("pool-w{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            // a panicking job must not kill the worker;
                            // panics are surfaced through JobHandle / scope
                            Ok(job) => {
                                let m = pool_metrics();
                                m.queue_depth.add(-1);
                                let t = Instant::now();
                                let ok =
                                    catch_unwind(AssertUnwindSafe(job))
                                        .is_ok();
                                m.busy_us
                                    .add(t.elapsed().as_micros() as u64);
                                m.jobs_completed.inc();
                                if !ok {
                                    note_job_panic();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawning pool worker thread")
            })
            .collect();
        pool_metrics().workers.add(workers.len() as i64);
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    fn send(&self, job: Job) {
        // workers are panic-proof, so the channel can only close on Drop;
        // &self guarantees the pool (and tx) is still alive here
        pool_metrics().queue_depth.add(1);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("pool workers exited");
    }

    /// Fire-and-forget execution (completion barrier: `submit`/`scope`, or
    /// dropping the pool).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.send(Box::new(f));
    }

    /// Run a job and hand back a joinable handle.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> JobHandle {
        let state = Arc::new(JobState::default());
        let s2 = state.clone();
        self.send(Box::new(move || {
            let ok = catch_unwind(AssertUnwindSafe(f)).is_ok();
            if !ok {
                // the worker-level catch sees Ok (this wrapper caught it),
                // so count the panic here
                note_job_panic();
            }
            *s2.done.lock().unwrap() = Some(ok);
            s2.cv.notify_all();
        }));
        JobHandle { state }
    }

    /// Run a group of jobs that may borrow from the enclosing stack frame.
    /// Every job spawned on the scope is complete when `scope` returns; if
    /// any job panicked (and the closure itself did not), `scope` panics.
    ///
    /// Do not call `scope` from inside a pool job: with all workers busy
    /// waiting on inner scopes the pool can deadlock.
    pub fn scope<'env, R>(
        &self,
        f: impl FnOnce(&Scope<'_, 'env>) -> R,
    ) -> R {
        let scope = Scope {
            pool: self,
            state: Arc::new(ScopeState::default()),
            _env: std::marker::PhantomData,
        };
        let out = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // join spawned jobs even if the closure panicked — the jobs borrow
        // from the caller's frame and must not outlive it
        scope.wait_all();
        let panics = scope.state.panics.load(Ordering::SeqCst);
        match out {
            Err(p) => resume_unwind(p),
            Ok(r) => {
                assert!(panics == 0, "{panics} scoped job(s) panicked");
                r
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        pool_metrics().workers.add(-(self.workers.len() as i64));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[derive(Default)]
struct JobState {
    /// None = running, Some(ok) = finished
    done: Mutex<Option<bool>>,
    cv: Condvar,
}

/// Handle to a submitted job.
pub struct JobHandle {
    state: Arc<JobState>,
}

/// The joined job panicked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobPanicked;

impl std::fmt::Display for JobPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("pool job panicked")
    }
}

impl std::error::Error for JobPanicked {}

impl JobHandle {
    /// True once the job has finished (without blocking).
    pub fn is_done(&self) -> bool {
        self.state.done.lock().unwrap().is_some()
    }

    /// Block until the job finishes; `Err` if it panicked.
    pub fn join(self) -> Result<(), JobPanicked> {
        let mut g = self.state.done.lock().unwrap();
        while g.is_none() {
            g = self.state.cv.wait(g).unwrap();
        }
        if g.unwrap() {
            Ok(())
        } else {
            Err(JobPanicked)
        }
    }
}

#[derive(Default)]
struct ScopeState {
    pending: Mutex<usize>,
    cv: Condvar,
    panics: AtomicUsize,
}

/// Spawning surface handed to the closure of [`ThreadPool::scope`].
/// Invariant in `'env` so borrowed data cannot be shortened under it.
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    state: Arc<ScopeState>,
    _env: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Spawn a job that may borrow data living at least as long as the
    /// scope ('env).
    pub fn spawn<F: FnOnce() + Send + 'env>(&self, f: F) {
        *self.state.pending.lock().unwrap() += 1;
        let state = self.state.clone();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: `scope` joins every spawned job (wait_all) before it
        // returns, on both the normal and panicking path, so the 'env
        // borrows captured by `job` strictly outlive its execution.  The
        // Scope type is invariant in 'env, preventing lifetime shrinking.
        let job: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        self.pool.send(Box::new(move || {
            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                state.panics.fetch_add(1, Ordering::SeqCst);
                note_job_panic();
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.cv.notify_all();
            }
        }));
    }

    fn wait_all(&self) {
        let mut pending = self.state.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.state.cv.wait(pending).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_is_joinable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<JobHandle> = (0..32)
            .map(|_| {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // the join IS the barrier — no drop needed
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    /// Regression: a panicking job used to kill its worker thread; enough
    /// of them wedged the pool and made `execute` panic on a dead channel.
    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        // more panicking jobs than workers
        let handles: Vec<JobHandle> =
            (0..8).map(|_| pool.submit(|| panic!("job boom"))).collect();
        for h in handles {
            assert_eq!(h.join(), Err(JobPanicked));
        }
        // pool still fully functional afterwards
        let counter = Arc::new(AtomicUsize::new(0));
        let hs: Vec<JobHandle> = (0..16)
            .map(|_| {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_joins_and_allows_stack_borrows() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 128];
        pool.scope(|s| {
            for (i, x) in data.iter_mut().enumerate() {
                s.spawn(move || {
                    *x = i * 2;
                });
            }
        });
        // all writes are complete and visible after scope returns
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn pool_metrics_count_completed_jobs() {
        // global monotone counter: assert on the delta (other tests may
        // run pools concurrently, so >= not ==)
        let before = pool_metrics().jobs_completed.get();
        let pool = ThreadPool::new(2);
        let hs: Vec<JobHandle> =
            (0..10).map(|_| pool.submit(|| {})).collect();
        for h in hs {
            h.join().unwrap();
        }
        drop(pool);
        assert!(pool_metrics().jobs_completed.get() >= before + 10);
    }

    #[test]
    fn scope_with_no_jobs_is_fine() {
        let pool = ThreadPool::new(1);
        let r = pool.scope(|_| 42);
        assert_eq!(r, 42);
    }

    #[test]
    #[should_panic(expected = "scoped job")]
    fn scope_propagates_job_panics() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|| {});
            s.spawn(|| panic!("inner boom"));
        });
    }
}
