//! Worker pool over std::thread (tokio/rayon unavailable offline), used by
//! the batched host kernels, the serving engine and parallel data
//! generation.
//!
//! Four submission APIs:
//!   * [`ThreadPool::execute`] — fire-and-forget (legacy surface),
//!   * [`ThreadPool::submit`]  — returns a [`JobHandle`] that can be
//!     `join()`ed and reports whether the job panicked,
//!   * [`ThreadPool::scope`]   — crossbeam-style scope: jobs may borrow
//!     from the caller's stack; the scope joins every spawned job before
//!     returning (the embarrassingly-parallel fan-out primitive),
//!   * [`ThreadPool::run_dag`] — executes a [`TaskDag`] of dependent
//!     tasks with per-task granularity: a task is enqueued the moment its
//!     last dependency finishes (wave scheduling without a global phase
//!     barrier).  This is what the sequence-parallel chunkwise kernels
//!     schedule their phase-A/B/C tasks on.
//!
//! Workers catch panics from jobs, so a panicking job can no longer kill a
//! worker thread and wedge the pool (the old behaviour: after any worker
//! death, `execute` would eventually panic on a closed channel and the
//! only completion barrier was `Drop`).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::flight;
use crate::obs::metrics::{counter, gauge, Counter, Gauge};

/// A job panic is both a counter bump and a flight-recorder event, so a
/// post-mortem dump shows *when* the pool lost a job relative to the
/// surrounding train steps (the process-wide panic hook separately
/// records the panic site itself).
fn note_job_panic() {
    pool_metrics().job_panics.inc();
    flight::record(flight::EventKind::Panic, "pool.job_panic", &[]);
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pool-wide observability handles, interned once (all pools share them).
struct PoolMetrics {
    /// jobs enqueued but not yet picked up by a worker
    queue_depth: &'static Gauge,
    /// live worker threads across all pools
    workers: &'static Gauge,
    jobs_completed: &'static Counter,
    job_panics: &'static Counter,
    /// cumulative wall time workers spent executing jobs (utilization =
    /// busy_us / (workers × elapsed))
    busy_us: &'static Counter,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        queue_depth: gauge("pool.queue_depth"),
        workers: gauge("pool.workers"),
        jobs_completed: counter("pool.jobs_completed"),
        job_panics: counter("pool.job_panics"),
        busy_us: counter("pool.busy_us"),
    })
}

/// Fixed-size thread pool executing boxed jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<_> = (0..n.max(1))
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("pool-w{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            // a panicking job must not kill the worker;
                            // panics are surfaced through JobHandle / scope
                            Ok(job) => {
                                let m = pool_metrics();
                                m.queue_depth.add(-1);
                                let t = Instant::now();
                                let ok =
                                    catch_unwind(AssertUnwindSafe(job))
                                        .is_ok();
                                m.busy_us
                                    .add(t.elapsed().as_micros() as u64);
                                m.jobs_completed.inc();
                                if !ok {
                                    note_job_panic();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawning pool worker thread")
            })
            .collect();
        pool_metrics().workers.add(workers.len() as i64);
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    fn send(&self, job: Job) {
        // workers are panic-proof, so the channel can only close on Drop;
        // &self guarantees the pool (and tx) is still alive here
        pool_metrics().queue_depth.add(1);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("pool workers exited");
    }

    /// Fire-and-forget execution (completion barrier: `submit`/`scope`, or
    /// dropping the pool).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.send(Box::new(f));
    }

    /// Run a job and hand back a joinable handle.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> JobHandle {
        let state = Arc::new(JobState::default());
        let s2 = state.clone();
        self.send(Box::new(move || {
            let ok = catch_unwind(AssertUnwindSafe(f)).is_ok();
            if !ok {
                // the worker-level catch sees Ok (this wrapper caught it),
                // so count the panic here
                note_job_panic();
            }
            *s2.done.lock().unwrap() = Some(ok);
            s2.cv.notify_all();
        }));
        JobHandle { state }
    }

    /// Run a group of jobs that may borrow from the enclosing stack frame.
    /// Every job spawned on the scope is complete when `scope` returns; if
    /// any job panicked (and the closure itself did not), `scope` panics.
    ///
    /// Do not call `scope` from inside a pool job: with all workers busy
    /// waiting on inner scopes the pool can deadlock.
    pub fn scope<'env, R>(
        &self,
        f: impl FnOnce(&Scope<'_, 'env>) -> R,
    ) -> R {
        let scope = Scope {
            pool: self,
            state: Arc::new(ScopeState::default()),
            _env: std::marker::PhantomData,
        };
        let out = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // join spawned jobs even if the closure panicked — the jobs borrow
        // from the caller's frame and must not outlive it
        scope.wait_all();
        let panics = scope.state.panics.load(Ordering::SeqCst);
        match out {
            Err(p) => resume_unwind(p),
            Ok(r) => {
                assert!(panics == 0, "{panics} scoped job(s) panicked");
                r
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        pool_metrics().workers.add(-(self.workers.len() as i64));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[derive(Default)]
struct JobState {
    /// None = running, Some(ok) = finished
    done: Mutex<Option<bool>>,
    cv: Condvar,
}

/// Handle to a submitted job.
pub struct JobHandle {
    state: Arc<JobState>,
}

/// The joined job panicked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobPanicked;

impl std::fmt::Display for JobPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("pool job panicked")
    }
}

impl std::error::Error for JobPanicked {}

impl JobHandle {
    /// True once the job has finished (without blocking).
    pub fn is_done(&self) -> bool {
        self.state.done.lock().unwrap().is_some()
    }

    /// Block until the job finishes; `Err` if it panicked.
    pub fn join(self) -> Result<(), JobPanicked> {
        let mut g = self.state.done.lock().unwrap();
        while g.is_none() {
            g = self.state.cv.wait(g).unwrap();
        }
        if g.unwrap() {
            Ok(())
        } else {
            Err(JobPanicked)
        }
    }
}

#[derive(Default)]
struct ScopeState {
    pending: Mutex<usize>,
    cv: Condvar,
    panics: AtomicUsize,
}

/// Spawning surface handed to the closure of [`ThreadPool::scope`].
/// Invariant in `'env` so borrowed data cannot be shortened under it.
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    state: Arc<ScopeState>,
    _env: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Spawn a job that may borrow data living at least as long as the
    /// scope ('env).
    pub fn spawn<F: FnOnce() + Send + 'env>(&self, f: F) {
        *self.state.pending.lock().unwrap() += 1;
        let state = self.state.clone();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: `scope` joins every spawned job (wait_all) before it
        // returns, on both the normal and panicking path, so the 'env
        // borrows captured by `job` strictly outlive its execution.  The
        // Scope type is invariant in 'env, preventing lifetime shrinking.
        let job: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        self.pool.send(Box::new(move || {
            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                state.panics.fetch_add(1, Ordering::SeqCst);
                note_job_panic();
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.cv.notify_all();
            }
        }));
    }

    fn wait_all(&self) {
        let mut pending = self.state.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.state.cv.wait(pending).unwrap();
        }
    }
}

/// A dependency-ordered batch of jobs for [`ThreadPool::run_dag`].
///
/// Tasks are identified by the index [`TaskDag::add`] returns, and every
/// dependency must refer to an already-added task — the graph is
/// topologically ordered by construction and therefore acyclic.  Like
/// [`Scope::spawn`], tasks may borrow from the caller's stack (`'env`);
/// `run_dag` joins every task before returning.
pub struct TaskDag<'env> {
    jobs: Vec<Box<dyn FnOnce() + Send + 'env>>,
    deps: Vec<Vec<usize>>,
}

impl<'env> TaskDag<'env> {
    pub fn new() -> Self {
        TaskDag { jobs: Vec::new(), deps: Vec::new() }
    }

    /// Add a task that may run only after every task in `deps` has
    /// finished; returns the new task's id for use in later `deps` lists.
    pub fn add<F: FnOnce() + Send + 'env>(
        &mut self,
        deps: &[usize],
        f: F,
    ) -> usize {
        let id = self.jobs.len();
        for &d in deps {
            assert!(d < id, "DAG dependency {d} does not precede task {id}");
        }
        self.jobs.push(Box::new(f));
        self.deps.push(deps.to_vec());
        id
    }

    /// Number of tasks added so far.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

impl Default for TaskDag<'_> {
    fn default() -> Self {
        Self::new()
    }
}

/// Shared state of one in-flight `run_dag` call.
struct DagRun {
    /// Task payloads, taken exactly once when the task is dispatched.
    jobs: Vec<Mutex<Option<Job>>>,
    /// Unmet-dependency counts; a task is enqueued when its count drops
    /// to zero.
    waiting: Vec<AtomicUsize>,
    /// Forward edges: tasks to release when task `i` finishes.
    dependents: Vec<Vec<usize>>,
    /// Tasks not yet finished; `run_dag` blocks until this reaches zero.
    remaining: Mutex<usize>,
    done: Condvar,
    panics: AtomicUsize,
    /// Cloned pool sender so a finishing task (running on a worker) can
    /// enqueue the tasks it just released.  Behind a Mutex because
    /// `mpsc::Sender` is not `Sync` on older toolchains.
    tx: Mutex<mpsc::Sender<Job>>,
}

/// Enqueue ready task `i` of `run` onto the pool.
fn dag_enqueue(run: &Arc<DagRun>, i: usize) {
    let r = run.clone();
    let wrapper: Job = Box::new(move || {
        let job = r.jobs[i].lock().unwrap().take();
        // once any task has panicked the rest of the graph is poisoned:
        // downstream payloads would read garbage, and run_dag re-raises
        // at the join anyway — skip them but still cascade completion so
        // the barrier cannot deadlock
        if r.panics.load(Ordering::SeqCst) == 0 {
            if let Some(job) = job {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    r.panics.fetch_add(1, Ordering::SeqCst);
                    note_job_panic();
                }
            }
        }
        // AcqRel on the final decrement gives the releasing task's writes
        // a happens-before edge to the dependent it enqueues (the channel
        // send/recv pair then carries it to whichever worker runs it)
        for &d in &r.dependents[i] {
            if r.waiting[d].fetch_sub(1, Ordering::AcqRel) == 1 {
                dag_enqueue(&r, d);
            }
        }
        let mut rem = r.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            r.done.notify_all();
        }
    });
    pool_metrics().queue_depth.add(1);
    run.tx
        .lock()
        .unwrap()
        .send(wrapper)
        .expect("pool workers exited");
}

impl ThreadPool {
    /// Execute a dependency graph of tasks on the pool and block until
    /// every task has finished.  Tasks whose dependencies are all met run
    /// concurrently (up to the pool size); each completing task releases
    /// its dependents immediately, so independent subgraphs never wait on
    /// each other.  Panics after the join if any task panicked.
    ///
    /// Like [`ThreadPool::scope`], do not call from inside a pool job:
    /// with all workers blocked on inner graphs the pool can deadlock.
    pub fn run_dag<'env>(&self, dag: TaskDag<'env>) {
        let n = dag.jobs.len();
        if n == 0 {
            return;
        }
        let TaskDag { jobs, deps } = dag;
        // SAFETY: run_dag joins every task (remaining == 0 under the
        // condvar) before returning, so the 'env borrows captured by the
        // jobs strictly outlive their execution — the same argument as
        // Scope::spawn.  The panic path also reaches the join: a
        // panicking task is caught by its wrapper, which still cascades
        // completion.
        let jobs: Vec<Mutex<Option<Job>>> = jobs
            .into_iter()
            .map(|job| {
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'env>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                Mutex::new(Some(job))
            })
            .collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut waiting = Vec::with_capacity(n);
        for (i, ds) in deps.iter().enumerate() {
            waiting.push(AtomicUsize::new(ds.len()));
            for &d in ds {
                dependents[d].push(i);
            }
        }
        let run = Arc::new(DagRun {
            jobs,
            waiting,
            dependents,
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panics: AtomicUsize::new(0),
            tx: Mutex::new(
                self.tx.as_ref().expect("pool shut down").clone(),
            ),
        });
        for (i, ds) in deps.iter().enumerate() {
            if ds.is_empty() {
                dag_enqueue(&run, i);
            }
        }
        let mut rem = run.remaining.lock().unwrap();
        while *rem > 0 {
            rem = run.done.wait(rem).unwrap();
        }
        drop(rem);
        let panics = run.panics.load(Ordering::SeqCst);
        assert!(panics == 0, "{panics} DAG task(s) panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_is_joinable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<JobHandle> = (0..32)
            .map(|_| {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // the join IS the barrier — no drop needed
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    /// Regression: a panicking job used to kill its worker thread; enough
    /// of them wedged the pool and made `execute` panic on a dead channel.
    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        // more panicking jobs than workers
        let handles: Vec<JobHandle> =
            (0..8).map(|_| pool.submit(|| panic!("job boom"))).collect();
        for h in handles {
            assert_eq!(h.join(), Err(JobPanicked));
        }
        // pool still fully functional afterwards
        let counter = Arc::new(AtomicUsize::new(0));
        let hs: Vec<JobHandle> = (0..16)
            .map(|_| {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_joins_and_allows_stack_borrows() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 128];
        pool.scope(|s| {
            for (i, x) in data.iter_mut().enumerate() {
                s.spawn(move || {
                    *x = i * 2;
                });
            }
        });
        // all writes are complete and visible after scope returns
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn pool_metrics_count_completed_jobs() {
        // global monotone counter: assert on the delta (other tests may
        // run pools concurrently, so >= not ==)
        let before = pool_metrics().jobs_completed.get();
        let pool = ThreadPool::new(2);
        let hs: Vec<JobHandle> =
            (0..10).map(|_| pool.submit(|| {})).collect();
        for h in hs {
            h.join().unwrap();
        }
        drop(pool);
        assert!(pool_metrics().jobs_completed.get() >= before + 10);
    }

    #[test]
    fn scope_with_no_jobs_is_fine() {
        let pool = ThreadPool::new(1);
        let r = pool.scope(|_| 42);
        assert_eq!(r, 42);
    }

    #[test]
    #[should_panic(expected = "scoped job")]
    fn scope_propagates_job_panics() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|| {});
            s.spawn(|| panic!("inner boom"));
        });
    }

    #[test]
    fn dag_orders_phases_and_joins() {
        // A-wave writes, one B task reduces, C-wave reads the reduction —
        // the exact shape the sequence-parallel kernels schedule
        let pool = ThreadPool::new(4);
        let xs: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        let total = AtomicUsize::new(0);
        let out: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        let mut dag = TaskDag::new();
        let a_ids: Vec<usize> = (0..16)
            .map(|i| {
                let xs = &xs;
                dag.add(&[], move || {
                    xs[i].store(i + 1, Ordering::SeqCst);
                })
            })
            .collect();
        let b = {
            let (xs, total) = (&xs, &total);
            dag.add(&a_ids, move || {
                let sum =
                    xs.iter().map(|x| x.load(Ordering::SeqCst)).sum();
                total.store(sum, Ordering::SeqCst);
            })
        };
        for i in 0..16 {
            let (total, out) = (&total, &out);
            dag.add(&[b], move || {
                out[i].store(
                    total.load(Ordering::SeqCst) + i,
                    Ordering::SeqCst,
                );
            });
        }
        pool.run_dag(dag);
        assert_eq!(total.load(Ordering::SeqCst), 16 * 17 / 2);
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.load(Ordering::SeqCst), 136 + i);
        }
    }

    #[test]
    fn dag_chain_runs_on_single_worker() {
        // a pure chain on a 1-worker pool: dependents are enqueued from
        // the only worker thread — must not deadlock
        let pool = ThreadPool::new(1);
        let hits = AtomicUsize::new(0);
        let mut dag = TaskDag::new();
        let mut prev: Option<usize> = None;
        for i in 0..100 {
            let hits = &hits;
            let deps: Vec<usize> = prev.into_iter().collect();
            prev = Some(dag.add(&deps, move || {
                // each link asserts every earlier link already ran
                assert_eq!(hits.fetch_add(1, Ordering::SeqCst), i);
            }));
        }
        pool.run_dag(dag);
        assert_eq!(hits.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn dag_pool_is_reusable_and_empty_dag_is_fine() {
        let pool = ThreadPool::new(2);
        pool.run_dag(TaskDag::new());
        for _ in 0..3 {
            let n = AtomicUsize::new(0);
            let mut dag = TaskDag::new();
            let nref = &n;
            let a = dag.add(&[], move || {
                nref.fetch_add(1, Ordering::SeqCst);
            });
            dag.add(&[a], move || {
                nref.fetch_add(1, Ordering::SeqCst);
            });
            pool.run_dag(dag);
            assert_eq!(n.load(Ordering::SeqCst), 2);
        }
    }

    #[test]
    #[should_panic(expected = "DAG task")]
    fn dag_propagates_task_panics() {
        let pool = ThreadPool::new(2);
        let mut dag = TaskDag::new();
        let bad = dag.add(&[], || panic!("task boom"));
        // downstream of the panic: skipped, but the join must still
        // complete (no deadlock) before run_dag re-raises
        dag.add(&[bad], || {});
        dag.add(&[], || {});
        pool.run_dag(dag);
    }

    #[test]
    #[should_panic(expected = "does not precede")]
    fn dag_rejects_forward_dependencies() {
        let mut dag = TaskDag::new();
        dag.add(&[1], || {});
    }
}
