//! Batched multi-head fan-out: one chunkwise forward per (batch, head)
//! problem, scheduled on the scoped thread pool.
//!
//! Every (b, h) slice of a multi-head DeltaNet forward is an independent
//! sequence problem (heads never mix inside the sequence-mixing layer), so
//! the batch dimension is embarrassingly parallel — exactly how the Pallas
//! kernel grids over (batch, head) on the accelerator.
//!
//! Each pool worker owns a thread-local [`super::ChunkWorkspace`]
//! (`workspace::with_thread_workspace`), so concurrent head problems reuse
//! per-thread scratch buffers with no sharing or locking — the chunk loops
//! stay allocation-free no matter how many heads land on one worker.

use std::sync::OnceLock;

use crate::obs::{self, metrics::{counter, Counter}};
use crate::tensor::Mat;
use crate::util::threadpool::ThreadPool;

use super::chunkwise::chunkwise_forward;
use super::{Forward, KernelConfig};

fn head_problems_counter() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    *C.get_or_init(|| counter("kernels.batch.problems"))
}

/// One (batch, head) sequence problem.
#[derive(Debug, Clone)]
pub struct HeadProblem {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub beta: Vec<f32>,
    pub initial_state: Option<Mat>,
}

impl HeadProblem {
    pub fn new(q: Mat, k: Mat, v: Mat, beta: Vec<f32>) -> Self {
        HeadProblem { q, k, v, beta, initial_state: None }
    }

    /// Chunkwise forward for this problem alone.
    pub fn forward(&self, chunk: usize) -> Forward {
        chunkwise_forward(&self.q, &self.k, &self.v, &self.beta, chunk,
                          self.initial_state.as_ref())
    }
}

/// Forward every problem, spinning up a pool sized to `cfg.threads`
/// (capped at the number of problems).  Use [`forward_batched_on`] to
/// amortize the pool across calls.
pub fn forward_batched(problems: &[HeadProblem], cfg: &KernelConfig)
                       -> Vec<Forward> {
    let threads = cfg.threads.max(1).min(problems.len().max(1));
    if threads <= 1 {
        return problems.iter().map(|p| p.forward(cfg.chunk)).collect();
    }
    let pool = ThreadPool::new(threads);
    forward_batched_on(&pool, problems, cfg.chunk)
}

/// Forward every problem on an existing pool; returns results in problem
/// order.  The scope inside joins all per-head jobs before returning.
pub fn forward_batched_on(pool: &ThreadPool, problems: &[HeadProblem],
                          chunk: usize) -> Vec<Forward> {
    map_batched_on(pool, problems, |p| p.forward(chunk))
}

/// One job per problem on the pool, any per-problem computation (the
/// recurrent form of the host backend reuses this fan-out).  Results come
/// back in problem order; the scope joins every job before returning.
pub fn map_batched_on<R, F>(pool: &ThreadPool, problems: &[HeadProblem],
                            f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&HeadProblem) -> R + Sync,
{
    let _sp = obs::trace::span_with("kernel.batch", || {
        vec![("problems", problems.len() as f64),
             ("threads", pool.size() as f64)]
    });
    head_problems_counter().add(problems.len() as u64);
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(problems.len(), || None);
    let f = &f;
    pool.scope(|s| {
        for (slot, p) in slots.iter_mut().zip(problems) {
            s.spawn(move || {
                let _head_sp = obs::trace::span("kernel.head");
                *slot = Some(f(p));
            });
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("scope joined every job"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::{delta_recurrent, random_problem};

    fn problems(n: usize, l: usize, d: usize) -> Vec<HeadProblem> {
        (0..n)
            .map(|i| {
                let (q, k, v, beta) =
                    random_problem(l, d, d, 100 + i as u64);
                HeadProblem::new(q, k, v, beta)
            })
            .collect()
    }

    #[test]
    fn batched_matches_oracle_per_head() {
        let ps = problems(6, 64, 8);
        for threads in [1usize, 4] {
            let cfg =
                KernelConfig::new().chunk(16).threads(threads).build()
                    .unwrap();
            let outs = forward_batched(&ps, &cfg);
            assert_eq!(outs.len(), ps.len());
            for (p, f) in ps.iter().zip(&outs) {
                let want =
                    delta_recurrent(&p.q, &p.k, &p.v, &p.beta, None);
                assert!(f.o.allclose(&want.o, 1e-4, 1e-4));
                assert!(f.state.allclose(&want.state, 1e-4, 1e-4));
            }
        }
    }

    #[test]
    fn results_keep_problem_order() {
        // distinct dv per problem makes any reordering detectable by shape
        let mut ps = problems(5, 32, 4);
        for (i, p) in ps.iter_mut().enumerate() {
            let (_, _, v, _) = random_problem(32, 4, 3 + i, 7 + i as u64);
            p.v = v;
        }
        let pool = ThreadPool::new(4);
        let outs = forward_batched_on(&pool, &ps, 8);
        for (i, f) in outs.iter().enumerate() {
            assert_eq!(f.o.cols, 3 + i);
        }
    }

    #[test]
    fn shared_pool_is_reusable_across_calls() {
        let ps = problems(3, 32, 4);
        let pool = ThreadPool::new(2);
        let a = forward_batched_on(&pool, &ps, 8);
        let b = forward_batched_on(&pool, &ps, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.o.data, y.o.data);
            assert_eq!(x.state.data, y.state.data);
        }
    }
}
