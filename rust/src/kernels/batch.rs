//! Batched fan-out of the sequence-parallel chunkwise kernels: one DAG
//! task per (batch, head, chunk) within each phase, scheduled on
//! [`ThreadPool::run_dag`].
//!
//! Every (b, h) slice of a multi-head DeltaNet forward is an independent
//! sequence problem (heads never mix inside the sequence-mixing layer),
//! and within each problem the three-phase decomposition (see
//! [`super::chunkwise`]) makes every *chunk* of phase A and phase C an
//! independent task too.  The schedulable width is therefore
//! B×H×⌈L/C⌉, not B×H — a single long sequence (B=1) saturates the pool
//! just as well as a wide batch.  Per problem the DAG is
//!
//! ```text
//!   A_0 … A_{n-1}  ──►  B (state scan)  ──►  C_0 … C_{n-1}
//! ```
//!
//! with no edges between problems, so chunk tasks of different
//! (batch, head) problems interleave freely; a finished scan releases its
//! own C wave while other problems are still in phase A.
//!
//! Each pool worker owns a thread-local [`super::ChunkWorkspace`]
//! (`workspace::with_thread_workspace`), so concurrent tasks reuse
//! per-thread scratch buffers with no sharing or locking — the phase
//! kernels stay allocation-free no matter how many tasks land on one
//! worker.  Cross-task data flows through one [`super::chunkwise::SeqBuffers`]
//! per problem (the shared chunk-state checkpoint buffer), handed between
//! tasks as raw disjoint ranges ([`RawRange`]) whose accesses the DAG
//! edges serialize.

use std::sync::OnceLock;

use crate::obs::{self, metrics::{counter, Counter}};
use crate::tensor::Mat;
use crate::util::threadpool::{TaskDag, ThreadPool};

use super::chunkwise::{
    chunkwise_forward, note_forward, phase_a_chunk, phase_c_chunk,
    scan_states, validate_forward_inputs, SeqBuffers,
};
use super::{Forward, KernelConfig};

fn head_problems_counter() -> &'static Counter {
    static C: OnceLock<&'static Counter> = OnceLock::new();
    *C.get_or_init(|| counter("kernels.batch.problems"))
}

/// One (batch, head) sequence problem.
#[derive(Debug, Clone)]
pub struct HeadProblem {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub beta: Vec<f32>,
    pub initial_state: Option<Mat>,
}

impl HeadProblem {
    pub fn new(q: Mat, k: Mat, v: Mat, beta: Vec<f32>) -> Self {
        HeadProblem { q, k, v, beta, initial_state: None }
    }

    /// Chunkwise forward for this problem alone.
    pub fn forward(&self, chunk: usize) -> Forward {
        chunkwise_forward(&self.q, &self.k, &self.v, &self.beta, chunk,
                          self.initial_state.as_ref())
    }
}

/// Total schedulable tasks of one phase of the decomposition: one task
/// per (batch, head, chunk) triple.  This is the width that bounds useful
/// parallelism — NOT `problems.len()`.
pub(crate) fn task_count(problems: &[HeadProblem], chunk: usize) -> usize {
    problems.iter().map(|p| p.q.rows.div_ceil(chunk.max(1))).sum()
}

/// An unchecked `*mut f32` range into a buffer that outlives the DAG run.
/// Built from one base pointer per buffer (so every subrange shares its
/// provenance) and materialized back into slices inside tasks; the DAG
/// edges must serialize every writer-before-reader pair, and concurrent
/// tasks must hold disjoint ranges.
#[derive(Clone, Copy)]
pub(crate) struct RawRange {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: a RawRange is just an address+length; the scheduling discipline
// above (disjoint ranges within a phase, DAG edges across phases, and the
// run_dag join before the owning buffer is touched again) makes the
// cross-thread accesses race-free.
unsafe impl Send for RawRange {}
unsafe impl Sync for RawRange {}

impl RawRange {
    pub(crate) fn of(s: &mut [f32]) -> Self {
        RawRange { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// The subrange `[at, at + len)` of this range.
    pub(crate) fn sub(self, at: usize, len: usize) -> Self {
        assert!(at + len <= self.len, "RawRange::sub out of bounds");
        // in-bounds of the same contiguous buffer, so the add is valid
        RawRange { ptr: unsafe { self.ptr.add(at) }, len }
    }

    /// # Safety
    /// No concurrent task may write this range, and its writer (if any)
    /// must be an upstream DAG dependency.
    pub(crate) unsafe fn slice<'a>(self) -> &'a [f32] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// # Safety
    /// This task must be the sole accessor of the range until a
    /// downstream dependent reads it.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice_mut<'a>(self) -> &'a mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

/// Add one sequence's forward tasks to the DAG: A per chunk → state scan
/// → C per chunk.
fn build_forward_tasks<'env>(
    dag: &mut TaskDag<'env>,
    p: &'env HeadProblem,
    chunk: usize,
    buf: &mut SeqBuffers,
    o: &mut Mat,
) {
    validate_forward_inputs(&p.q, &p.k, &p.v, &p.beta, chunk,
                            p.initial_state.as_ref());
    let (l, dk, dv) = (p.q.rows, p.q.cols, p.v.cols);
    let n = buf.n_chunks;
    debug_assert_eq!(n, l.div_ceil(chunk));
    let w_all = RawRange::of(&mut buf.w);
    let u_all = RawRange::of(&mut buf.u);
    let p_all = RawRange::of(&mut buf.p);
    let g_all = RawRange::of(&mut buf.g);
    let states_all = RawRange::of(&mut buf.states);
    let o_all = RawRange::of(&mut o.data);

    // Phase A: one independent task per chunk
    let a_ids: Vec<usize> = (0..n)
        .map(|ci| {
            let t0 = ci * chunk;
            let c = chunk.min(l - t0);
            let w = w_all.sub(t0 * dk, c * dk);
            let u = u_all.sub(t0 * dv, c * dv);
            let pp = p_all.sub(ci * dk * dk, dk * dk);
            let g = g_all.sub(ci * dk * dv, dk * dv);
            dag.add(&[], move || {
                let _sp = obs::trace::span("kernel.chunkwise.chunk");
                // SAFETY: sole writer of these chunk-local ranges; the
                // phase-B/C readers depend on this task
                unsafe {
                    phase_a_chunk(&p.k, &p.v, &p.beta, t0, c,
                                  w.slice_mut(), u.slice_mut(),
                                  pp.slice_mut(), g.slice_mut());
                }
            })
        })
        .collect();

    // Phase B: the per-sequence inter-chunk state scan
    let init = p.initial_state.as_ref();
    let b_id = dag.add(&a_ids, move || {
        let _sp = obs::trace::span("kernel.chunkwise.scan");
        // SAFETY: every phase-A writer of p/g is a dependency; sole
        // writer of states
        unsafe {
            scan_states(p_all.slice(), g_all.slice(), n, dk, dv, init,
                        states_all.slice_mut());
        }
    });

    // Phase C: per-chunk outputs from the propagated entry states
    for ci in 0..n {
        let t0 = ci * chunk;
        let c = chunk.min(l - t0);
        let w = w_all.sub(t0 * dk, c * dk);
        let u = u_all.sub(t0 * dv, c * dv);
        let s_in = states_all.sub(ci * dk * dv, dk * dv);
        let o_r = o_all.sub(t0 * dv, c * dv);
        dag.add(&[b_id], move || {
            let _sp = obs::trace::span("kernel.chunkwise.output");
            // SAFETY: w/u/states are read-only now (their writers are
            // upstream dependencies); sole writer of this output range
            unsafe {
                phase_c_chunk(&p.q, &p.k, t0, c, w.slice(), u.slice(),
                              s_in.slice(), o_r.slice_mut());
            }
        });
    }
}

/// Forward every problem, spinning up a pool sized to `cfg.threads`
/// capped at the total (batch, head, chunk) task count — a single
/// sequence still fans out across all its chunks.  Use
/// [`forward_batched_on`] to amortize the pool across calls.
pub fn forward_batched(problems: &[HeadProblem], cfg: &KernelConfig)
                       -> Vec<Forward> {
    let threads =
        cfg.threads.max(1).min(task_count(problems, cfg.chunk).max(1));
    if threads <= 1 {
        return problems.iter().map(|p| p.forward(cfg.chunk)).collect();
    }
    let pool = ThreadPool::new(threads);
    forward_batched_on(&pool, problems, cfg.chunk)
}

/// Forward every problem on an existing pool, DAG-scheduled over every
/// (batch, head, chunk) task; returns results in problem order.  The DAG
/// run joins all tasks before returning.
pub fn forward_batched_on(pool: &ThreadPool, problems: &[HeadProblem],
                          chunk: usize) -> Vec<Forward> {
    assert!(chunk > 0, "chunk must be positive");
    let _sp = obs::trace::span_with("kernel.batch", || {
        vec![("problems", problems.len() as f64),
             ("threads", pool.size() as f64),
             ("tasks", task_count(problems, chunk) as f64)]
    });
    head_problems_counter().add(problems.len() as u64);
    if problems.is_empty() {
        return Vec::new();
    }
    let mut outs: Vec<Mat> = problems
        .iter()
        .map(|p| Mat::zeros(p.q.rows, p.v.cols))
        .collect();
    let mut bufs: Vec<SeqBuffers> = problems
        .iter()
        .map(|p| {
            SeqBuffers::forward(p.q.rows, p.q.cols, p.v.cols,
                                p.q.rows.div_ceil(chunk))
        })
        .collect();
    let mut dag = TaskDag::new();
    for (p, (buf, o)) in
        problems.iter().zip(bufs.iter_mut().zip(outs.iter_mut()))
    {
        build_forward_tasks(&mut dag, p, chunk, buf, o);
        note_forward(p.q.rows, chunk, p.q.cols, p.v.cols);
    }
    pool.run_dag(dag);
    bufs.into_iter()
        .zip(outs)
        .map(|(buf, o)| Forward { o, state: buf.final_state() })
        .collect()
}

/// One job per problem on the pool, any per-problem computation (the
/// recurrent form of the host backend reuses this fan-out).  Results come
/// back in problem order; the scope joins every job before returning.
pub fn map_batched_on<R, F>(pool: &ThreadPool, problems: &[HeadProblem],
                            f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&HeadProblem) -> R + Sync,
{
    let _sp = obs::trace::span_with("kernel.batch", || {
        vec![("problems", problems.len() as f64),
             ("threads", pool.size() as f64)]
    });
    head_problems_counter().add(problems.len() as u64);
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(problems.len(), || None);
    let f = &f;
    pool.scope(|s| {
        for (slot, p) in slots.iter_mut().zip(problems) {
            s.spawn(move || {
                let _head_sp = obs::trace::span("kernel.head");
                *slot = Some(f(p));
            });
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("scope joined every job"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::{delta_recurrent, random_problem};

    fn problems(n: usize, l: usize, d: usize) -> Vec<HeadProblem> {
        (0..n)
            .map(|i| {
                let (q, k, v, beta) =
                    random_problem(l, d, d, 100 + i as u64);
                HeadProblem::new(q, k, v, beta)
            })
            .collect()
    }

    #[test]
    fn batched_matches_oracle_per_head() {
        let ps = problems(6, 64, 8);
        for threads in [1usize, 4] {
            let cfg =
                KernelConfig::new().chunk(16).threads(threads).build()
                    .unwrap();
            let outs = forward_batched(&ps, &cfg);
            assert_eq!(outs.len(), ps.len());
            for (p, f) in ps.iter().zip(&outs) {
                let want =
                    delta_recurrent(&p.q, &p.k, &p.v, &p.beta, None);
                assert!(f.o.allclose(&want.o, 1e-4, 1e-4));
                assert!(f.state.allclose(&want.state, 1e-4, 1e-4));
            }
        }
    }

    #[test]
    fn results_keep_problem_order() {
        // distinct dv per problem makes any reordering detectable by shape
        let mut ps = problems(5, 32, 4);
        for (i, p) in ps.iter_mut().enumerate() {
            let (_, _, v, _) = random_problem(32, 4, 3 + i, 7 + i as u64);
            p.v = v;
        }
        let pool = ThreadPool::new(4);
        let outs = forward_batched_on(&pool, &ps, 8);
        for (i, f) in outs.iter().enumerate() {
            assert_eq!(f.o.cols, 3 + i);
        }
    }

    #[test]
    fn shared_pool_is_reusable_across_calls() {
        let ps = problems(3, 32, 4);
        let pool = ThreadPool::new(2);
        let a = forward_batched_on(&pool, &ps, 8);
        let b = forward_batched_on(&pool, &ps, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.o.data, y.o.data);
            assert_eq!(x.state.data, y.state.data);
        }
    }

    #[test]
    fn single_problem_fans_out_over_chunks() {
        // B=1, H=1: the old per-head fan-out would cap threads at 1; the
        // task count is now the chunk count, and an oversubscribed pool
        // must still produce the sequential result bit-for-bit
        let ps = problems(1, 96, 8);
        let single = ps[0].forward(8);
        let cfg = KernelConfig { chunk: 8, threads: 8 };
        assert_eq!(task_count(&ps, cfg.chunk), 12);
        let outs = forward_batched(&ps, &cfg);
        assert_eq!(outs[0].o.data, single.o.data);
        assert_eq!(outs[0].state.data, single.state.data);
    }
}
