//! Chunkwise-parallel DeltaNet forward over one sequence, built on the
//! cache-blocked primitives in `tensor::blocked`.
//!
//! Per chunk of C tokens (paper Eq. 8–11, Listing-1 sign convention):
//!
//! ```text
//!   A  = tril(diag(β) K Kᵀ, −1)            strictly-lower, computed only
//!   T  = (I + A)⁻¹                          on the kept triangle
//!   W  = T diag(β) K,   U = T diag(β) V     UT transform
//!   U̅  = U − W S                            fold in the carried state
//!   O  = Q S + tril(Q Kᵀ) U̅                 intra-chunk outputs
//!   S += Kᵀ U̅                               inter-chunk recurrence
//! ```
//!
//! Differences from the scalar oracle (`reference::delta_chunkwise_scalar`):
//! the causal products materialize only their triangle, every matmul is
//! blocked/accumulating, the chunk loop reuses one set of intermediates,
//! and a trailing partial chunk (L % C ≠ 0) is supported.

use std::sync::OnceLock;

use crate::obs::{self, metrics::{counter, Counter}};
use crate::tensor::blocked::{
    matmul_into, matmul_tn_acc, scale_rows_into, sub_in_place,
    tril_matmul_nt_into, tri_inv_unit_lower_into,
};
use crate::tensor::{simd, Mat};

use super::workspace::with_thread_workspace;
use super::Forward;

/// Work counters for the forward kernel, interned once.
struct FwdCounters {
    calls: &'static Counter,
    chunks: &'static Counter,
    flops: &'static Counter,
    bytes: &'static Counter,
}

fn fwd_counters() -> &'static FwdCounters {
    static M: OnceLock<FwdCounters> = OnceLock::new();
    M.get_or_init(|| FwdCounters {
        calls: counter("kernels.forward.calls"),
        chunks: counter("kernels.forward.chunks"),
        flops: counter("kernels.forward.flops"),
        bytes: counter("kernels.forward.bytes"),
    })
}

struct RecCounters {
    steps: &'static Counter,
    flops: &'static Counter,
}

fn rec_counters() -> &'static RecCounters {
    static M: OnceLock<RecCounters> = OnceLock::new();
    M.get_or_init(|| RecCounters {
        steps: counter("kernels.recurrent.steps"),
        flops: counter("kernels.recurrent.flops"),
    })
}

/// Estimated FLOPs of one forward chunk (2mnk per dense matmul, triangle
/// products at half, c³/3 for the unit-lower inverse) — an estimate for
/// roofline-style ratios, not an exact op count.
pub(crate) fn chunk_flops(c: usize, dk: usize, dv: usize) -> u64 {
    let (c, dk, dv) = (c as u64, dk as u64, dv as u64);
    4 * c * c * (dk + dv) + c * c * c / 3 + 6 * c * dk * dv
}

/// Estimated f32 bytes moved by one forward call (inputs + outputs +
/// state read/write).
pub(crate) fn forward_bytes(l: usize, dk: usize, dv: usize) -> u64 {
    (4 * (2 * l * dk + 2 * l * dv + l + 2 * dk * dv)) as u64
}

/// Chunkwise forward for one sequence.  `q,k: [L,dk]`, `v: [L,dv]`,
/// `beta: [L]`; `chunk` may not divide L (the tail chunk is shorter).
pub fn chunkwise_forward(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    chunk: usize,
    initial_state: Option<&Mat>,
) -> Forward {
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;
    assert!(chunk > 0, "chunk must be positive");
    assert_eq!(k.rows, l, "k rows");
    assert_eq!(k.cols, dk, "k cols");
    assert_eq!(v.rows, l, "v rows");
    assert_eq!(beta.len(), l, "beta len");
    if let Some(s0) = initial_state {
        assert_eq!((s0.rows, s0.cols), (dk, dv), "initial state shape");
    }

    let _sp = obs::trace::span_with("kernel.chunkwise.forward", || {
        vec![("L", l as f64), ("chunk", chunk as f64),
             ("dk", dk as f64), ("dv", dv as f64)]
    });

    let mut s = initial_state
        .cloned()
        .unwrap_or_else(|| Mat::zeros(dk, dv));
    let mut o = Mat::zeros(l, dv);

    let mut flops = 0u64;
    let mut nchunks = 0u64;
    // the chunk loop runs entirely inside this thread's workspace: every
    // intermediate is a reused buffer, every chunk input a borrowed row
    // window — zero heap allocations at steady state
    with_thread_workspace(|scr| {
        let mut t0 = 0;
        while t0 < l {
            let c = chunk.min(l - t0);
            let _chunk_sp = obs::trace::span("kernel.chunkwise.chunk");
            let qc = q.rows_window(t0, c);
            let kc = k.rows_window(t0, c);
            let vc = v.rows_window(t0, c);
            let bc = &beta[t0..t0 + c];

            // UT transform: T = (I + tril(diag(β)KKᵀ, −1))⁻¹, W/U = T·diag(β)·{K,V}
            scale_rows_into(&mut scr.kb, kc, bc);
            scale_rows_into(&mut scr.vb, vc, bc);
            tril_matmul_nt_into(&mut scr.a, &scr.kb, kc, -1);
            tri_inv_unit_lower_into(&mut scr.t, &scr.a);
            matmul_into(&mut scr.w, &scr.t, &scr.kb, false);
            matmul_into(&mut scr.u_bar, &scr.t, &scr.vb, false);

            // U̅ = U − W S
            matmul_into(&mut scr.ws, &scr.w, &s, false);
            sub_in_place(&mut scr.u_bar, &scr.ws);

            // O_c = Q_c S + tril(Q_c K_cᵀ) U̅
            tril_matmul_nt_into(&mut scr.attn, qc, kc, 0);
            matmul_into(&mut scr.oc, qc, &s, false);
            matmul_into(&mut scr.oc, &scr.attn, &scr.u_bar, true);
            o.data[t0 * dv..(t0 + c) * dv].copy_from_slice(&scr.oc.data);

            // S += K_cᵀ U̅
            matmul_tn_acc(&mut s, kc, &scr.u_bar);

            flops += chunk_flops(c, dk, dv);
            nchunks += 1;
            t0 += c;
        }
    });
    let m = fwd_counters();
    m.calls.inc();
    m.chunks.add(nchunks);
    m.flops.add(flops);
    m.bytes.add(forward_bytes(l, dk, dv));
    Forward { o, state: s }
}

/// One recurrent delta-rule step (the decode path): reads `q,k,v` rows for
/// a single token, updates `s` in place and writes the output row.
/// `s: [dk,dv]`, `out: [dv]`.
pub fn recurrent_step(
    s: &mut Mat,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    beta: f32,
    out: &mut [f32],
) {
    let (dk, dv) = (s.rows, s.cols);
    assert_eq!(q.len(), dk, "q len");
    assert_eq!(k.len(), dk, "k len");
    assert_eq!(v.len(), dv, "v len");
    assert_eq!(out.len(), dv, "out len");
    // v_old = kᵀ S
    let mut v_old = vec![0.0f32; dv];
    for (i, &ki) in k.iter().enumerate() {
        if ki != 0.0 {
            simd::axpy(&mut v_old, ki, s.row(i));
        }
    }
    // S += β k (v − v_old)ᵀ
    for (i, &ki) in k.iter().enumerate() {
        let c = beta * ki;
        if c != 0.0 {
            let srow = s.row_mut(i);
            for (x, (&vj, &vo)) in srow.iter_mut().zip(v.iter().zip(&v_old)) {
                *x += c * (vj - vo);
            }
        }
    }
    // o = q S
    out.fill(0.0);
    for (i, &qi) in q.iter().enumerate() {
        if qi != 0.0 {
            simd::axpy(out, qi, s.row(i));
        }
    }
    let m = rec_counters();
    m.steps.inc();
    m.flops.add((6 * dk * dv) as u64);
}

pub(crate) fn slice_rows(m: &Mat, start: usize, n: usize) -> Mat {
    Mat {
        rows: n,
        cols: m.cols,
        data: m.data[start * m.cols..(start + n) * m.cols].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::{delta_recurrent, random_problem};

    #[test]
    fn blocked_chunkwise_matches_recurrent_oracle() {
        let (q, k, v, beta) = random_problem(64, 16, 16, 21);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        for chunk in [1, 3, 16, 64, 128] {
            let got = chunkwise_forward(&q, &k, &v, &beta, chunk, None);
            assert!(got.o.allclose(&want.o, 1e-4, 1e-4), "chunk={chunk}");
            assert!(got.state.allclose(&want.state, 1e-4, 1e-4),
                    "chunk={chunk}");
        }
    }

    #[test]
    fn partial_tail_chunk_supported() {
        // L=80 with C=64 leaves a 16-token tail chunk
        let (q, k, v, beta) = random_problem(80, 8, 8, 22);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let got = chunkwise_forward(&q, &k, &v, &beta, 64, None);
        assert!(got.o.allclose(&want.o, 1e-4, 1e-4));
        assert!(got.state.allclose(&want.state, 1e-4, 1e-4));
    }

    #[test]
    fn rectangular_dk_dv() {
        let (q, k, _, beta) = random_problem(32, 8, 8, 23);
        let (_, _, v, _) = random_problem(32, 8, 12, 24);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let got = chunkwise_forward(&q, &k, &v, &beta, 8, None);
        assert!(got.o.allclose(&want.o, 1e-4, 1e-4));
        assert!(got.state.allclose(&want.state, 1e-4, 1e-4));
    }

    #[test]
    fn recurrent_step_chains_to_full_forward() {
        let (q, k, v, beta) = random_problem(24, 8, 8, 25);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let mut s = Mat::zeros(8, 8);
        let mut out = vec![0.0f32; 8];
        for t in 0..24 {
            recurrent_step(&mut s, q.row(t), k.row(t), v.row(t), beta[t],
                           &mut out);
            for (a, b) in out.iter().zip(want.o.row(t)) {
                assert!((a - b).abs() < 1e-4, "token {t}");
            }
        }
        assert!(s.allclose(&want.state, 1e-4, 1e-4));
    }

    #[test]
    fn initial_state_is_respected() {
        let (q, k, v, beta) = random_problem(32, 8, 8, 26);
        let full = chunkwise_forward(&q, &k, &v, &beta, 8, None);
        let h1 = chunkwise_forward(&slice_rows(&q, 0, 16),
                                   &slice_rows(&k, 0, 16),
                                   &slice_rows(&v, 0, 16), &beta[..16], 8,
                                   None);
        let h2 = chunkwise_forward(&slice_rows(&q, 16, 16),
                                   &slice_rows(&k, 16, 16),
                                   &slice_rows(&v, 16, 16), &beta[16..], 8,
                                   Some(&h1.state));
        assert!(h2.state.allclose(&full.state, 1e-4, 1e-4));
        for i in 0..16 {
            for (a, b) in full.o.row(16 + i).iter().zip(h2.o.row(i)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }
}
