//! Chunkwise-parallel DeltaNet forward over one sequence, built on the
//! cache-blocked primitives in `tensor::blocked`.
//!
//! Per chunk of C tokens (paper Eq. 8–11, Listing-1 sign convention):
//!
//! ```text
//!   A  = tril(diag(β) K Kᵀ, −1)            strictly-lower, computed only
//!   T  = (I + A)⁻¹                          on the kept triangle
//!   W  = T diag(β) K,   U = T diag(β) V     UT transform
//!   U̅  = U − W S                            fold in the carried state
//!   O  = Q S + tril(Q Kᵀ) U̅                 intra-chunk outputs
//!   S += Kᵀ U̅                               inter-chunk recurrence
//! ```
//!
//! The kernel is factored into the paper's *sequence-parallel* three-phase
//! form rather than one fused chunk loop.  Substituting U̅ = U − W S_in
//! into the state update gives an affine inter-chunk recurrence
//!
//! ```text
//!   S_out = (I − Kᵀ W) S_in + Kᵀ U  =  P S_in + G
//! ```
//!
//! whose coefficients P ([dk,dk]) and G ([dk,dv]) depend only on the
//! chunk's own tokens.  That splits the work into
//!
//!   * **Phase A** ([`phase_a_chunk`]): per-chunk UT transform producing
//!     W, U, P, G — independent across every chunk of every sequence,
//!   * **Phase B** ([`scan_states`]): the per-sequence state scan
//!     `S_{i+1} = P_i S_i + G_i` — only state-size matmuls, sequential in
//!     the chunk index but concurrent across sequences,
//!   * **Phase C** ([`phase_c_chunk`]): per-chunk outputs from the
//!     propagated entry state — independent across all chunks again.
//!
//! [`chunkwise_forward`] runs the same three phases in order on the
//! calling thread (so single-sequence results are bit-identical to the
//! DAG-scheduled path in `kernels::batch`, which fans A and C out over
//! every (batch, head, chunk) task).  All per-chunk intermediates live in
//! the per-thread [`ChunkWorkspace`]; the per-sequence W/U/P/G/state
//! buffers are one exact-sized [`SeqBuffers`] allocation per call, so
//! steady-state chunk work stays allocation-free
//! (`tests/alloc_steady.rs`).
//!
//! Differences from the scalar oracle (`reference::delta_chunkwise_scalar`):
//! the causal products materialize only their triangle, every matmul is
//! blocked/accumulating, and a trailing partial chunk (L % C ≠ 0) is
//! supported.

use std::sync::OnceLock;

use crate::obs::{self, metrics::{counter, Counter}};
use crate::tensor::blocked::{
    copy_into, matmul_into, matmul_tn_acc, scale_rows_into, sub_in_place,
    tril_matmul_nt_into, tri_inv_unit_lower_into,
};
use crate::tensor::{simd, Mat, MatRef};

use super::workspace::{with_thread_workspace, ChunkWorkspace};
use super::Forward;

/// Work counters for the forward kernel, interned once.
struct FwdCounters {
    calls: &'static Counter,
    chunks: &'static Counter,
    flops: &'static Counter,
    bytes: &'static Counter,
}

fn fwd_counters() -> &'static FwdCounters {
    static M: OnceLock<FwdCounters> = OnceLock::new();
    M.get_or_init(|| FwdCounters {
        calls: counter("kernels.forward.calls"),
        chunks: counter("kernels.forward.chunks"),
        flops: counter("kernels.forward.flops"),
        bytes: counter("kernels.forward.bytes"),
    })
}

struct RecCounters {
    steps: &'static Counter,
    flops: &'static Counter,
}

fn rec_counters() -> &'static RecCounters {
    static M: OnceLock<RecCounters> = OnceLock::new();
    M.get_or_init(|| RecCounters {
        steps: counter("kernels.recurrent.steps"),
        flops: counter("kernels.recurrent.flops"),
    })
}

/// Estimated FLOPs of one forward chunk in the three-phase form (2mnk per
/// dense matmul, triangle products at half, c³/3 for the unit-lower
/// inverse, plus the P/G scan coefficients and the chunk's share of the
/// phase-B scan) — an estimate for roofline-style ratios, not an exact op
/// count.
pub(crate) fn chunk_flops(c: usize, dk: usize, dv: usize) -> u64 {
    let (c, dk, dv) = (c as u64, dk as u64, dv as u64);
    4 * c * c * (dk + dv) + c * c * c / 3 + 6 * c * dk * dv
        + 2 * c * dk * dk + 2 * dk * dk * dv
}

/// Estimated f32 bytes moved by one forward call (inputs + outputs +
/// state read/write).
pub(crate) fn forward_bytes(l: usize, dk: usize, dv: usize) -> u64 {
    (4 * (2 * l * dk + 2 * l * dv + l + 2 * dk * dv)) as u64
}

/// Bump the forward work counters for one sequence — shared by the
/// sequential entry point and the DAG-scheduled batch path.
pub(crate) fn note_forward(l: usize, chunk: usize, dk: usize, dv: usize) {
    let m = fwd_counters();
    m.calls.inc();
    let mut flops = 0u64;
    let mut nchunks = 0u64;
    let mut t0 = 0;
    while t0 < l {
        let c = chunk.min(l - t0);
        flops += chunk_flops(c, dk, dv);
        nchunks += 1;
        t0 += c;
    }
    m.chunks.add(nchunks);
    m.flops.add(flops);
    m.bytes.add(forward_bytes(l, dk, dv));
}

/// Per-sequence buffers of the three-phase decomposition: the phase-A
/// outputs (W, U, the scan coefficients P, G) and the propagated chunk
/// boundary states — the shared checkpoint buffer the DAG tasks hand each
/// other.  One exact-sized allocation set per kernel call; the count is
/// independent of the number of chunks (pinned by `tests/alloc_steady.rs`).
pub(crate) struct SeqBuffers {
    /// W rows for every token: `[L, dk]`.
    pub(crate) w: Vec<f32>,
    /// U rows for every token (pre state-fold, i.e. T·diag(β)V): `[L, dv]`.
    pub(crate) u: Vec<f32>,
    /// Scan transition P = I − KᵀW per chunk: `[n, dk, dk]`.
    pub(crate) p: Vec<f32>,
    /// Scan offset G = KᵀU per chunk: `[n, dk, dv]`.
    pub(crate) g: Vec<f32>,
    /// Chunk boundary states: `states[i]` enters chunk i; `[n+1, dk, dv]`.
    pub(crate) states: Vec<f32>,
    /// Reverse-scan source H = QᵀdO − Wᵀ(AttnᵀdO) per chunk (backward
    /// only): `[n, dk, dv]`.
    pub(crate) h: Vec<f32>,
    /// State gradients: `dsb[i]` = dL/dS entering chunk i, `dsb[n]` =
    /// d_state (backward only): `[n+1, dk, dv]`.
    pub(crate) dsb: Vec<f32>,
    pub(crate) n_chunks: usize,
    dk: usize,
    dv: usize,
}

impl SeqBuffers {
    pub(crate) fn forward(l: usize, dk: usize, dv: usize, n: usize) -> Self {
        SeqBuffers {
            w: vec![0.0; l * dk],
            u: vec![0.0; l * dv],
            p: vec![0.0; n * dk * dk],
            g: vec![0.0; n * dk * dv],
            states: vec![0.0; (n + 1) * dk * dv],
            h: Vec::new(),
            dsb: Vec::new(),
            n_chunks: n,
            dk,
            dv,
        }
    }

    pub(crate) fn backward(l: usize, dk: usize, dv: usize, n: usize) -> Self {
        let mut b = Self::forward(l, dk, dv, n);
        b.h = vec![0.0; n * dk * dv];
        b.dsb = vec![0.0; (n + 1) * dk * dv];
        b
    }

    /// The state after the last chunk.
    pub(crate) fn final_state(&self) -> Mat {
        let sdv = self.dk * self.dv;
        Mat {
            rows: self.dk,
            cols: self.dv,
            data: self.states[self.n_chunks * sdv..].to_vec(),
        }
    }

    /// The gradient w.r.t. the initial state (backward only).
    pub(crate) fn dstate(&self) -> Mat {
        Mat {
            rows: self.dk,
            cols: self.dv,
            data: self.dsb[..self.dk * self.dv].to_vec(),
        }
    }
}

/// Phase A, workspace-explicit core: the UT transform of chunk
/// `[t0, t0+c)` plus the scan coefficients.  On return the workspace
/// additionally holds `kb/vb/a/t` for callers (the backward recompute)
/// that extend the chunk computation without re-acquiring the thread
/// workspace.
pub(crate) fn phase_a_core(
    scr: &mut ChunkWorkspace,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    t0: usize,
    c: usize,
    w_out: &mut [f32],
    u_out: &mut [f32],
    p_out: &mut [f32],
    g_out: &mut [f32],
) {
    let (dk, dv) = (k.cols, v.cols);
    debug_assert_eq!(w_out.len(), c * dk);
    debug_assert_eq!(u_out.len(), c * dv);
    debug_assert_eq!(p_out.len(), dk * dk);
    debug_assert_eq!(g_out.len(), dk * dv);
    let kc = k.rows_window(t0, c);
    let vc = v.rows_window(t0, c);
    let bc = &beta[t0..t0 + c];

    // UT transform: T = (I + tril(diag(β)KKᵀ, −1))⁻¹, W/U = T·diag(β)·{K,V}
    scale_rows_into(&mut scr.kb, kc, bc);
    scale_rows_into(&mut scr.vb, vc, bc);
    tril_matmul_nt_into(&mut scr.a, &scr.kb, kc, -1);
    tri_inv_unit_lower_into(&mut scr.t, &scr.a);
    matmul_into(&mut scr.w, &scr.t, &scr.kb, false);
    matmul_into(&mut scr.u_bar, &scr.t, &scr.vb, false);

    // scan coefficients: P = I − KᵀW, G = KᵀU
    scr.pc.reset(dk, dk);
    matmul_tn_acc(&mut scr.pc, kc, &scr.w);
    for x in scr.pc.data.iter_mut() {
        *x = -*x;
    }
    for i in 0..dk {
        scr.pc[(i, i)] += 1.0;
    }
    scr.gc.reset(dk, dv);
    matmul_tn_acc(&mut scr.gc, kc, &scr.u_bar);

    w_out.copy_from_slice(&scr.w.data);
    u_out.copy_from_slice(&scr.u_bar.data);
    p_out.copy_from_slice(&scr.pc.data);
    g_out.copy_from_slice(&scr.gc.data);
}

/// Phase A for one chunk, on this thread's workspace.  Independent of
/// every other chunk — the DAG schedules one such task per
/// (batch, head, chunk).
pub(crate) fn phase_a_chunk(
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    t0: usize,
    c: usize,
    w_out: &mut [f32],
    u_out: &mut [f32],
    p_out: &mut [f32],
    g_out: &mut [f32],
) {
    with_thread_workspace(|scr| {
        phase_a_core(scr, k, v, beta, t0, c, w_out, u_out, p_out, g_out);
    });
}

/// Phase B: propagate the inter-chunk states `S_{i+1} = P_i S_i + G_i`.
/// `states` gets all n+1 chunk boundary states (`states[0]` = initial).
/// Per sequence this is n state-size matmuls — the only sequential part
/// of the decomposition.
pub(crate) fn scan_states(
    p: &[f32],
    g: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    initial_state: Option<&Mat>,
    states: &mut [f32],
) {
    let sdv = dk * dv;
    debug_assert_eq!(p.len(), n * dk * dk);
    debug_assert_eq!(g.len(), n * sdv);
    debug_assert_eq!(states.len(), (n + 1) * sdv);
    match initial_state {
        Some(s0) => {
            debug_assert_eq!((s0.rows, s0.cols), (dk, dv));
            states[..sdv].copy_from_slice(&s0.data);
        }
        None => states[..sdv].fill(0.0),
    }
    with_thread_workspace(|scr| {
        for ci in 0..n {
            let (done, rest) = states.split_at_mut((ci + 1) * sdv);
            let s_in =
                MatRef { rows: dk, cols: dv, data: &done[ci * sdv..] };
            let p_i = MatRef {
                rows: dk,
                cols: dk,
                data: &p[ci * dk * dk..(ci + 1) * dk * dk],
            };
            matmul_into(&mut scr.sc, p_i, s_in, false);
            let out = &mut rest[..sdv];
            out.copy_from_slice(&g[ci * sdv..(ci + 1) * sdv]);
            for (x, &y) in out.iter_mut().zip(&scr.sc.data) {
                *x += y;
            }
        }
    });
}

/// Phase C: outputs of chunk `[t0, t0+c)` from its propagated entry state
/// — `U̅ = U − W S_in`, `O = Q S_in + tril(QKᵀ) U̅`.  Independent across
/// chunks once phase B has filled `states`.
pub(crate) fn phase_c_chunk(
    q: &Mat,
    k: &Mat,
    t0: usize,
    c: usize,
    w_c: &[f32],
    u_c: &[f32],
    s_in: &[f32],
    o_out: &mut [f32],
) {
    let dk = q.cols;
    debug_assert_eq!(w_c.len(), c * dk);
    let dv = u_c.len() / c.max(1);
    debug_assert_eq!(s_in.len(), dk * dv);
    debug_assert_eq!(o_out.len(), c * dv);
    let qc = q.rows_window(t0, c);
    let kc = k.rows_window(t0, c);
    let w = MatRef { rows: c, cols: dk, data: w_c };
    let u = MatRef { rows: c, cols: dv, data: u_c };
    let s = MatRef { rows: dk, cols: dv, data: s_in };
    with_thread_workspace(|scr| {
        // U̅ = U − W S_in
        copy_into(&mut scr.u_bar, u);
        matmul_into(&mut scr.ws, w, s, false);
        sub_in_place(&mut scr.u_bar, &scr.ws);
        // O_c = Q_c S_in + tril(Q_c K_cᵀ) U̅
        tril_matmul_nt_into(&mut scr.attn, qc, kc, 0);
        matmul_into(&mut scr.oc, qc, s, false);
        matmul_into(&mut scr.oc, &scr.attn, &scr.u_bar, true);
        o_out.copy_from_slice(&scr.oc.data);
    });
}

pub(crate) fn validate_forward_inputs(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    chunk: usize,
    initial_state: Option<&Mat>,
) {
    let (l, dk) = (q.rows, q.cols);
    assert!(chunk > 0, "chunk must be positive");
    assert_eq!(k.rows, l, "k rows");
    assert_eq!(k.cols, dk, "k cols");
    assert_eq!(v.rows, l, "v rows");
    assert_eq!(beta.len(), l, "beta len");
    if let Some(s0) = initial_state {
        assert_eq!((s0.rows, s0.cols), (dk, v.cols), "initial state shape");
    }
}

/// Chunkwise forward for one sequence.  `q,k: [L,dk]`, `v: [L,dv]`,
/// `beta: [L]`; `chunk` may not divide L (the tail chunk is shorter).
///
/// Runs the three phases sequentially on the calling thread; the batched
/// DAG path (`kernels::batch::forward_batched_on`) runs the exact same
/// phase functions, so the two are bit-identical per sequence.
pub fn chunkwise_forward(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    chunk: usize,
    initial_state: Option<&Mat>,
) -> Forward {
    validate_forward_inputs(q, k, v, beta, chunk, initial_state);
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;

    let _sp = obs::trace::span_with("kernel.chunkwise.forward", || {
        vec![("L", l as f64), ("chunk", chunk as f64),
             ("dk", dk as f64), ("dv", dv as f64)]
    });

    let n = l.div_ceil(chunk);
    let mut seq = SeqBuffers::forward(l, dk, dv, n);
    let mut o = Mat::zeros(l, dv);

    // Phase A: per-chunk UT transform + scan coefficients
    for ci in 0..n {
        let t0 = ci * chunk;
        let c = chunk.min(l - t0);
        let _chunk_sp = obs::trace::span("kernel.chunkwise.chunk");
        phase_a_chunk(k, v, beta, t0, c,
                      &mut seq.w[t0 * dk..(t0 + c) * dk],
                      &mut seq.u[t0 * dv..(t0 + c) * dv],
                      &mut seq.p[ci * dk * dk..(ci + 1) * dk * dk],
                      &mut seq.g[ci * dk * dv..(ci + 1) * dk * dv]);
    }

    // Phase B: inter-chunk state scan
    {
        let _scan_sp = obs::trace::span("kernel.chunkwise.scan");
        scan_states(&seq.p, &seq.g, n, dk, dv, initial_state,
                    &mut seq.states);
    }

    // Phase C: per-chunk outputs from the propagated entry states
    for ci in 0..n {
        let t0 = ci * chunk;
        let c = chunk.min(l - t0);
        let _chunk_sp = obs::trace::span("kernel.chunkwise.output");
        phase_c_chunk(q, k, t0, c,
                      &seq.w[t0 * dk..(t0 + c) * dk],
                      &seq.u[t0 * dv..(t0 + c) * dv],
                      &seq.states[ci * dk * dv..(ci + 1) * dk * dv],
                      &mut o.data[t0 * dv..(t0 + c) * dv]);
    }

    note_forward(l, chunk, dk, dv);
    Forward { o, state: seq.final_state() }
}

/// One recurrent delta-rule step (the decode path): reads `q,k,v` rows for
/// a single token, updates `s` in place and writes the output row.
/// `s: [dk,dv]`, `out: [dv]`.
pub fn recurrent_step(
    s: &mut Mat,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    beta: f32,
    out: &mut [f32],
) {
    let (dk, dv) = (s.rows, s.cols);
    assert_eq!(q.len(), dk, "q len");
    assert_eq!(k.len(), dk, "k len");
    assert_eq!(v.len(), dv, "v len");
    assert_eq!(out.len(), dv, "out len");
    // v_old = kᵀ S
    let mut v_old = vec![0.0f32; dv];
    for (i, &ki) in k.iter().enumerate() {
        if ki != 0.0 {
            simd::axpy(&mut v_old, ki, s.row(i));
        }
    }
    // S += β k (v − v_old)ᵀ
    for (i, &ki) in k.iter().enumerate() {
        let c = beta * ki;
        if c != 0.0 {
            let srow = s.row_mut(i);
            for (x, (&vj, &vo)) in srow.iter_mut().zip(v.iter().zip(&v_old)) {
                *x += c * (vj - vo);
            }
        }
    }
    // o = q S
    out.fill(0.0);
    for (i, &qi) in q.iter().enumerate() {
        if qi != 0.0 {
            simd::axpy(out, qi, s.row(i));
        }
    }
    let m = rec_counters();
    m.steps.inc();
    m.flops.add((6 * dk * dv) as u64);
}

pub(crate) fn slice_rows(m: &Mat, start: usize, n: usize) -> Mat {
    Mat {
        rows: n,
        cols: m.cols,
        data: m.data[start * m.cols..(start + n) * m.cols].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::{delta_recurrent, random_problem};

    #[test]
    fn blocked_chunkwise_matches_recurrent_oracle() {
        let (q, k, v, beta) = random_problem(64, 16, 16, 21);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        for chunk in [1, 3, 16, 64, 128] {
            let got = chunkwise_forward(&q, &k, &v, &beta, chunk, None);
            assert!(got.o.allclose(&want.o, 1e-4, 1e-4), "chunk={chunk}");
            assert!(got.state.allclose(&want.state, 1e-4, 1e-4),
                    "chunk={chunk}");
        }
    }

    #[test]
    fn partial_tail_chunk_supported() {
        // L=80 with C=64 leaves a 16-token tail chunk
        let (q, k, v, beta) = random_problem(80, 8, 8, 22);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let got = chunkwise_forward(&q, &k, &v, &beta, 64, None);
        assert!(got.o.allclose(&want.o, 1e-4, 1e-4));
        assert!(got.state.allclose(&want.state, 1e-4, 1e-4));
    }

    #[test]
    fn rectangular_dk_dv() {
        let (q, k, _, beta) = random_problem(32, 8, 8, 23);
        let (_, _, v, _) = random_problem(32, 8, 12, 24);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let got = chunkwise_forward(&q, &k, &v, &beta, 8, None);
        assert!(got.o.allclose(&want.o, 1e-4, 1e-4));
        assert!(got.state.allclose(&want.state, 1e-4, 1e-4));
    }

    #[test]
    fn recurrent_step_chains_to_full_forward() {
        let (q, k, v, beta) = random_problem(24, 8, 8, 25);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let mut s = Mat::zeros(8, 8);
        let mut out = vec![0.0f32; 8];
        for t in 0..24 {
            recurrent_step(&mut s, q.row(t), k.row(t), v.row(t), beta[t],
                           &mut out);
            for (a, b) in out.iter().zip(want.o.row(t)) {
                assert!((a - b).abs() < 1e-4, "token {t}");
            }
        }
        assert!(s.allclose(&want.state, 1e-4, 1e-4));
    }

    #[test]
    fn initial_state_is_respected() {
        let (q, k, v, beta) = random_problem(32, 8, 8, 26);
        let full = chunkwise_forward(&q, &k, &v, &beta, 8, None);
        let h1 = chunkwise_forward(&slice_rows(&q, 0, 16),
                                   &slice_rows(&k, 0, 16),
                                   &slice_rows(&v, 0, 16), &beta[..16], 8,
                                   None);
        let h2 = chunkwise_forward(&slice_rows(&q, 16, 16),
                                   &slice_rows(&k, 16, 16),
                                   &slice_rows(&v, 16, 16), &beta[16..], 8,
                                   Some(&h1.state));
        assert!(h2.state.allclose(&full.state, 1e-4, 1e-4));
        for i in 0..16 {
            for (a, b) in full.o.row(16 + i).iter().zip(h2.o.row(i)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn scan_coefficients_reproduce_the_state_recurrence() {
        // P/G from phase A must give the same boundary states the fused
        // recurrence S += KᵀU̅ produces (here: oracle final state)
        let (q, k, v, beta) = random_problem(48, 8, 8, 27);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let got = chunkwise_forward(&q, &k, &v, &beta, 16, None);
        assert!(got.state.allclose(&want.state, 1e-4, 1e-4));
        // and a mid-sequence boundary state equals the oracle prefix state
        let prefix = delta_recurrent(&slice_rows(&q, 0, 32),
                                     &slice_rows(&k, 0, 32),
                                     &slice_rows(&v, 0, 32), &beta[..32],
                                     None);
        let n = 3;
        let mut seq = SeqBuffers::forward(48, 8, 8, n);
        for ci in 0..n {
            let t0 = ci * 16;
            phase_a_chunk(&k, &v, &beta, t0, 16,
                          &mut seq.w[t0 * 8..(t0 + 16) * 8],
                          &mut seq.u[t0 * 8..(t0 + 16) * 8],
                          &mut seq.p[ci * 64..(ci + 1) * 64],
                          &mut seq.g[ci * 64..(ci + 1) * 64]);
        }
        scan_states(&seq.p, &seq.g, n, 8, 8, None, &mut seq.states);
        let s2 = Mat { rows: 8, cols: 8,
                       data: seq.states[2 * 64..3 * 64].to_vec() };
        assert!(s2.allclose(&prefix.state, 1e-4, 1e-4));
    }
}
