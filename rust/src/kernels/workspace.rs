//! Reusable per-thread scratch buffers for the chunkwise kernels.
//!
//! The forward and backward chunk loops need a dozen chunk-shaped
//! intermediates (C×C triangles, C×d panels, the d_k×d_v state products).
//! Allocating them fresh every chunk put the allocator on the hot path —
//! O(chunks) round trips per sequence.  A [`ChunkWorkspace`] owns one set
//! of buffers that every `_into` primitive reshapes in place
//! ([`crate::tensor::Mat::reset`] keeps the backing allocation), so after
//! the first chunk of the largest shape the steady-state loop performs
//! ZERO heap allocations — `tests/alloc_steady.rs` counts them.
//!
//! Ownership model: one workspace per thread, fetched by
//! [`with_thread_workspace`].  The batch layer (`super::batch`) fans
//! per-(head, chunk) phase tasks out over pool workers; each worker
//! thread lazily materializes its own workspace on first use and keeps it
//! for the life of the thread, so parallel tasks never contend and no
//! locking is involved.

use std::cell::RefCell;

use crate::tensor::Mat;

/// Scratch buffers for one chunk of the forward/backward scan.  Field
/// names mirror the math in `chunkwise.rs` / `backward.rs` (`kb` = βK,
/// `t` = (I+A)⁻¹, `u_bar` = U̅, `d*` = gradients of `*`…).  All buffers
/// start empty and grow to their steady-state size on first use.
#[derive(Debug)]
pub struct ChunkWorkspace {
    // ---- forward (and the backward's recompute pass)
    pub(crate) kb: Mat,
    pub(crate) vb: Mat,
    pub(crate) a: Mat,
    pub(crate) t: Mat,
    pub(crate) w: Mat,
    pub(crate) u_bar: Mat,
    pub(crate) ws: Mat,
    pub(crate) attn: Mat,
    pub(crate) oc: Mat,
    /// Scan transition P = I − KᵀW of the current chunk (phase A).
    pub(crate) pc: Mat,
    /// Scan offset G = KᵀU of the current chunk (phase A).
    pub(crate) gc: Mat,
    /// State-size product temp of the phase-B scans (P·S / Pᵀ·dS).
    pub(crate) sc: Mat,
    /// Reverse-scan source H = QᵀdO − Wᵀ(AttnᵀdO) (backward phase A).
    pub(crate) hc: Mat,
    // ---- backward
    pub(crate) du_bar: Mat,
    pub(crate) d_attn: Mat,
    pub(crate) dqc: Mat,
    pub(crate) dkc: Mat,
    pub(crate) dvc: Mat,
    pub(crate) dw: Mat,
    pub(crate) dt: Mat,
    pub(crate) sol: Mat,
    pub(crate) solt: Mat,
    pub(crate) da: Mat,
    pub(crate) dkb: Mat,
    pub(crate) dvb: Mat,
    pub(crate) wtd: Mat,
}

impl ChunkWorkspace {
    pub fn new() -> Self {
        let empty = || Mat::zeros(0, 0);
        ChunkWorkspace {
            kb: empty(),
            vb: empty(),
            a: empty(),
            t: empty(),
            w: empty(),
            u_bar: empty(),
            ws: empty(),
            attn: empty(),
            oc: empty(),
            pc: empty(),
            gc: empty(),
            sc: empty(),
            hc: empty(),
            du_bar: empty(),
            d_attn: empty(),
            dqc: empty(),
            dkc: empty(),
            dvc: empty(),
            dw: empty(),
            dt: empty(),
            sol: empty(),
            solt: empty(),
            da: empty(),
            dkb: empty(),
            dvb: empty(),
            wtd: empty(),
        }
    }
}

impl Default for ChunkWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Run `f` with this thread's [`ChunkWorkspace`] (created on first use).
///
/// The borrow is scoped to the call, so kernels must not call back into
/// another workspace-using kernel from inside `f` — the forward and
/// backward entry points each take the workspace exactly once around
/// their whole chunk loop.
pub(crate) fn with_thread_workspace<R>(
    f: impl FnOnce(&mut ChunkWorkspace) -> R,
) -> R {
    thread_local! {
        static WS: RefCell<ChunkWorkspace> =
            RefCell::new(ChunkWorkspace::new());
    }
    WS.with(|w| f(&mut w.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_workspace_persists_across_calls() {
        // buffers grown by one call must still be there for the next —
        // that persistence is the whole point
        with_thread_workspace(|ws| {
            ws.kb.reset(8, 8);
            ws.pc.reset(8, 8);
        });
        with_thread_workspace(|ws| {
            assert!(ws.kb.data.capacity() >= 64);
            assert!(ws.pc.data.capacity() >= 64);
        });
    }

    #[test]
    fn workspaces_are_per_thread() {
        with_thread_workspace(|ws| ws.a.reset(4, 4));
        std::thread::spawn(|| {
            with_thread_workspace(|ws| {
                assert_eq!((ws.a.rows, ws.a.cols), (0, 0));
            });
        })
        .join()
        .unwrap();
    }
}
