//! Host kernel layer: batched, multi-head, multi-threaded chunkwise
//! DeltaNet forward.
//!
//! The paper's contribution is a chunkwise WY-representation algorithm
//! that parallelizes the delta rule over sequence length (Eq. 8–11).  The
//! `reference` module keeps the obviously-correct scalar implementation as
//! a cross-check oracle; this module is the throughput engine:
//!
//! ```text
//!   kernels::batch      [B,H] head problems fanned out over a scoped
//!        │               worker pool (util::threadpool::ThreadPool::scope)
//!        ▼
//!   kernels::chunkwise  per-sequence chunkwise forward: intra-chunk UT
//!        │               transform + inter-chunk state recurrence
//!        ▼
//!   tensor::blocked     cache-blocked matmul / tril-matmul primitives
//! ```
//!
//! The same layer backs `reference::delta_chunkwise`, the bench targets
//! (`bench_reference`, `bench_fig1_forms`, `bench_fig4_throughput`) and
//! the coordinator's host backend (`coordinator::host`), which exposes it
//! under the kernel-artifact signature as a drop-in for PJRT.

pub mod batch;
pub mod chunkwise;

pub use batch::{
    forward_batched, forward_batched_on, map_batched_on, HeadProblem,
};
pub use chunkwise::{chunkwise_forward, recurrent_step};

use crate::tensor::Mat;

/// Output of a sequence-level forward: per-token outputs + final state.
#[derive(Debug, Clone)]
pub struct Forward {
    /// [L, d_v] per-token outputs.
    pub o: Mat,
    /// [d_k, d_v] final state (feeds the next segment or decode).
    pub state: Mat,
}

/// Tuning knobs for the batched kernels.
#[derive(Debug, Clone, Copy)]
pub struct KernelConfig {
    /// Chunk length C of the chunkwise form (the paper sweeps 16–128;
    /// C=64 is the default operating point).
    pub chunk: usize,
    /// Worker threads for the [B,H] fan-out.
    pub threads: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig { chunk: 64, threads: default_threads() }
    }
}

/// Host parallelism to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
