//! Host kernel layer: batched, multi-head, multi-threaded chunkwise
//! DeltaNet forward.
//!
//! The paper's contribution is a chunkwise WY-representation algorithm
//! that parallelizes the delta rule over sequence length (Eq. 8–11).  The
//! `reference` module keeps the obviously-correct scalar implementation as
//! a cross-check oracle; this module is the throughput engine:
//!
//! ```text
//!   kernels::batch      [B,H,⌈L/C⌉] phase tasks scheduled as a DAG on
//!        │               the worker pool (util::threadpool::run_dag):
//!        │               per-chunk UT transforms ─► per-sequence state
//!        │               scan ─► per-chunk outputs
//!        ▼
//!   kernels::chunkwise  the three phase kernels + the sequential
//!        │               per-sequence entry point (same code path)
//!        ▼
//!   tensor::blocked     cache-blocked matmul / tril-matmul primitives
//! ```
//!
//! The same layer backs `reference::delta_chunkwise`, the bench targets
//! (`bench_reference`, `bench_fig1_forms`, `bench_fig4_throughput`) and
//! the coordinator's host backend (`coordinator::host`), which exposes it
//! under the kernel-artifact signature as a drop-in for PJRT.

pub mod backward;
pub mod batch;
pub mod chunkwise;
pub mod workspace;

pub use backward::{
    backward_batched, backward_batched_on, chunkwise_backward, Gradients,
};
pub use batch::{
    forward_batched, forward_batched_on, map_batched_on, HeadProblem,
};
pub use chunkwise::{chunkwise_forward, recurrent_step};
pub use workspace::ChunkWorkspace;

use crate::tensor::Mat;

/// Output of a sequence-level forward: per-token outputs + final state.
#[derive(Debug, Clone)]
pub struct Forward {
    /// [L, d_v] per-token outputs.
    pub o: Mat,
    /// [d_k, d_v] final state (feeds the next segment or decode).
    pub state: Mat,
}

/// Tuning knobs for the batched kernels.
#[derive(Debug, Clone, Copy)]
pub struct KernelConfig {
    /// Chunk length C of the chunkwise form (the paper sweeps 16–128;
    /// C=64 is the default operating point).
    pub chunk: usize,
    /// Worker threads for the (batch, head, chunk) task fan-out.
    pub threads: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig { chunk: 64, threads: default_threads() }
    }
}

impl KernelConfig {
    /// Start a validated builder seeded with the default operating point.
    /// `KernelConfig::new().chunk(64).threads(8).build()?` — the `build`
    /// step rejects `chunk == 0` / `threads == 0`, which the bare struct
    /// literal cannot.
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> KernelConfigBuilder {
        KernelConfigBuilder { cfg: KernelConfig::default() }
    }
}

/// Builder for [`KernelConfig`] — see [`KernelConfig::new`].
#[derive(Debug, Clone)]
pub struct KernelConfigBuilder {
    cfg: KernelConfig,
}

impl KernelConfigBuilder {
    /// Chunk length C of the chunkwise form.
    pub fn chunk(mut self, chunk: usize) -> Self {
        self.cfg.chunk = chunk;
        self
    }

    /// Worker threads for the (batch, head, chunk) task fan-out.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> crate::Result<KernelConfig> {
        crate::ensure!(self.cfg.chunk > 0, "chunk must be > 0");
        crate::ensure!(self.cfg.threads > 0, "threads must be > 0");
        Ok(self.cfg)
    }
}

/// Host parallelism to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_validated_config() {
        let cfg = KernelConfig::new().chunk(16).threads(2).build().unwrap();
        assert_eq!(cfg.chunk, 16);
        assert_eq!(cfg.threads, 2);
        // untouched knobs keep the defaults
        let cfg = KernelConfig::new().chunk(32).build().unwrap();
        assert_eq!(cfg.chunk, 32);
        assert_eq!(cfg.threads, KernelConfig::default().threads);
    }

    #[test]
    fn builder_rejects_zero_knobs() {
        assert!(KernelConfig::new().chunk(0).build().is_err());
        assert!(KernelConfig::new().threads(0).build().is_err());
    }
}
