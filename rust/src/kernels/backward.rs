//! Chunkwise-parallel DeltaNet backward over one sequence (paper App. B):
//! gradients for q/k/v/β through the intra-chunk UT transform and the
//! inter-chunk state recurrence, as a reverse scan over chunks.
//!
//! The forward (see [`super::chunkwise`]) keeps only the carried state
//! between chunks, so the backward recomputes the per-chunk intermediates
//! (W, U, T, attention triangle) from a cheap forward pre-pass that
//! checkpoints the chunk-entry states S_in — O(L/C) extra state memory
//! instead of O(L) activation memory.
//!
//! Per chunk, with dS the gradient carried from the chunks to the right
//! (initialized from d(final state)):
//!
//! ```text
//!   dU̅  = Attnᵀ dO + K dS
//!   dAttn = tril(dO U̅ᵀ, 0)
//!   dQ   = dO S_inᵀ + dAttn K
//!   dK   = dAttnᵀ Q + U̅ dSᵀ          (incoming dS, before the carry update)
//!   dW   = −dU̅ S_inᵀ,  dU = dU̅
//!   dT   = dW Kᵦᵀ + dU Vᵦᵀ
//!   dA   = −tril((I+A)⁻ᵀ dT (I+A)⁻ᵀ, −1)    via two triangular solves
//!   dKᵦ  = Tᵀ dW + dA K,   dVᵦ = Tᵀ dU
//!   dK  += dAᵀ Kᵦ + diag(β) dKᵦ,   dV = diag(β) dVᵦ
//!   dβᵢ  = dKᵦᵢ·Kᵢ + dVᵦᵢ·Vᵢ
//!   dS  ← dS + Qᵀ dO − Wᵀ dU̅                (the reverse state recurrence)
//! ```
//!
//! The reverse scan is sequential per sequence (mirroring the forward), and
//! the [B,H] fan-out in [`backward_batched_on`] parallelizes across head
//! problems exactly like the forward batch layer.

use std::sync::OnceLock;

use crate::obs::{self, metrics::{counter, Counter}};
use crate::tensor::blocked::{
    matmul_into, matmul_nt_into, matmul_tn_acc, scale_rows_into,
    solve_unit_lower_in_place, solve_unit_lower_t_into, sub_in_place,
    transpose_into, tril_matmul_nt_into, tri_inv_unit_lower_into,
};
use crate::tensor::{simd, Mat, MatRef};
use crate::util::threadpool::ThreadPool;

use super::batch::HeadProblem;
use super::chunkwise::{chunk_flops, forward_bytes};
use super::workspace::with_thread_workspace;
use super::KernelConfig;

struct BwdCounters {
    calls: &'static Counter,
    chunks: &'static Counter,
    flops: &'static Counter,
    bytes: &'static Counter,
}

fn bwd_counters() -> &'static BwdCounters {
    static M: OnceLock<BwdCounters> = OnceLock::new();
    M.get_or_init(|| BwdCounters {
        calls: counter("kernels.backward.calls"),
        chunks: counter("kernels.backward.chunks"),
        flops: counter("kernels.backward.flops"),
        bytes: counter("kernels.backward.bytes"),
    })
}

/// Gradients of one sequence problem: same shapes as the inputs, plus the
/// gradient flowing into the initial state (zero-state problems can ignore
/// it; stacked segments chain it backwards).
#[derive(Debug, Clone)]
pub struct Gradients {
    /// [L, d_k]
    pub dq: Mat,
    /// [L, d_k]
    pub dk: Mat,
    /// [L, d_v]
    pub dv: Mat,
    /// [L]
    pub dbeta: Vec<f32>,
    /// [d_k, d_v] — gradient w.r.t. the initial state.
    pub dstate: Mat,
}

/// Chunkwise backward for one sequence.  `q,k: [L,dk]`, `v: [L,dv]`,
/// `beta: [L]`, `d_o: [L,dv]` the output gradient, `d_state: [dk,dv]` the
/// gradient w.r.t. the final state (None = zeros).  `chunk` may not divide
/// L (the tail chunk is shorter), matching the forward.
pub fn chunkwise_backward(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    chunk: usize,
    initial_state: Option<&Mat>,
    d_o: &Mat,
    d_state: Option<&Mat>,
) -> Gradients {
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;
    assert!(chunk > 0, "chunk must be positive");
    assert_eq!(k.rows, l, "k rows");
    assert_eq!(k.cols, dk, "k cols");
    assert_eq!(v.rows, l, "v rows");
    assert_eq!(beta.len(), l, "beta len");
    assert_eq!((d_o.rows, d_o.cols), (l, dv), "d_o shape");
    if let Some(s0) = initial_state {
        assert_eq!((s0.rows, s0.cols), (dk, dv), "initial state shape");
    }
    if let Some(dsn) = d_state {
        assert_eq!((dsn.rows, dsn.cols), (dk, dv), "d_state shape");
    }

    let _sp = obs::trace::span_with("kernel.chunkwise.backward", || {
        vec![("L", l as f64), ("chunk", chunk as f64),
             ("dk", dk as f64), ("dv", dv as f64)]
    });

    // ---- gradient outputs (the only per-call allocations)
    let mut dq = Mat::zeros(l, dk);
    let mut dk_out = Mat::zeros(l, dk);
    let mut dv_out = Mat::zeros(l, dv);
    let mut dbeta = vec![0.0f32; l];
    let mut s = initial_state
        .cloned()
        .unwrap_or_else(|| Mat::zeros(dk, dv));
    let mut ds = d_state.cloned().unwrap_or_else(|| Mat::zeros(dk, dv));

    let n_chunks = l.div_ceil(chunk);
    let mut flops = 0u64;
    // both scans run inside this thread's workspace: intermediates are
    // reused buffers, chunk inputs are borrowed row windows, and the
    // chunk-entry checkpoints land in one flat reused Vec
    with_thread_workspace(|scr| {
        // ---- forward pre-pass: checkpoint the state entering each chunk
        {
            let _ckpt_sp = obs::trace::span("kernel.backward.checkpoint");
            scr.checkpoints.clear();
            scr.checkpoints.reserve(n_chunks * dk * dv);
            let mut t0 = 0;
            while t0 < l {
                let c = chunk.min(l - t0);
                scr.checkpoints.extend_from_slice(&s.data);
                let kc = k.rows_window(t0, c);
                let vc = v.rows_window(t0, c);
                let bc = &beta[t0..t0 + c];
                scale_rows_into(&mut scr.kb, kc, bc);
                scale_rows_into(&mut scr.vb, vc, bc);
                tril_matmul_nt_into(&mut scr.a, &scr.kb, kc, -1);
                tri_inv_unit_lower_into(&mut scr.t, &scr.a);
                matmul_into(&mut scr.w, &scr.t, &scr.kb, false);
                matmul_into(&mut scr.u_bar, &scr.t, &scr.vb, false);
                matmul_into(&mut scr.ws, &scr.w, &s, false);
                sub_in_place(&mut scr.u_bar, &scr.ws);
                matmul_tn_acc(&mut s, kc, &scr.u_bar);
                t0 += c;
            }
        }

        // ---- reverse scan over chunks
        for ci in (0..n_chunks).rev() {
            let t0 = ci * chunk;
            let c = chunk.min(l - t0);
            let _chunk_sp = obs::trace::span("kernel.backward.chunk");
            // recompute (≈ forward) + gradient products: ~3× the forward chunk
            flops += 3 * chunk_flops(c, dk, dv);
            let s_in = MatRef {
                rows: dk,
                cols: dv,
                data: &scr.checkpoints[ci * dk * dv..(ci + 1) * dk * dv],
            };
            let qc = q.rows_window(t0, c);
            let kc = k.rows_window(t0, c);
            let vc = v.rows_window(t0, c);
            let bc = &beta[t0..t0 + c];
            let d_oc = d_o.rows_window(t0, c);

            // recompute the chunk intermediates
            scale_rows_into(&mut scr.kb, kc, bc);
            scale_rows_into(&mut scr.vb, vc, bc);
            tril_matmul_nt_into(&mut scr.a, &scr.kb, kc, -1);
            tri_inv_unit_lower_into(&mut scr.t, &scr.a);
            matmul_into(&mut scr.w, &scr.t, &scr.kb, false);
            matmul_into(&mut scr.u_bar, &scr.t, &scr.vb, false);
            matmul_into(&mut scr.ws, &scr.w, s_in, false);
            sub_in_place(&mut scr.u_bar, &scr.ws);
            tril_matmul_nt_into(&mut scr.attn, qc, kc, 0);

            // dU̅ = Attnᵀ dO + K dS
            scr.du_bar.reset(c, dv);
            matmul_tn_acc(&mut scr.du_bar, &scr.attn, d_oc);
            matmul_into(&mut scr.du_bar, kc, &ds, true);

            // dAttn = tril(dO U̅ᵀ, 0)
            tril_matmul_nt_into(&mut scr.d_attn, d_oc, &scr.u_bar, 0);

            // dQ = dO S_inᵀ + dAttn K
            matmul_nt_into(&mut scr.dqc, d_oc, s_in, false);
            matmul_into(&mut scr.dqc, &scr.d_attn, kc, true);

            // dK = dAttnᵀ Q + U̅ dSᵀ — must see dS *before* the carry update
            scr.dkc.reset(c, dk);
            matmul_tn_acc(&mut scr.dkc, &scr.d_attn, qc);
            matmul_nt_into(&mut scr.dkc, &scr.u_bar, &ds, true);

            // dW = −dU̅ S_inᵀ; dU aliases dU̅
            matmul_nt_into(&mut scr.dw, &scr.du_bar, s_in, false);
            for x in scr.dw.data.iter_mut() {
                *x = -*x;
            }

            // dT = dW Kᵦᵀ + dU Vᵦᵀ
            matmul_nt_into(&mut scr.dt, &scr.dw, &scr.kb, false);
            matmul_nt_into(&mut scr.dt, &scr.du_bar, &scr.vb, true);

            // dA = −tril((I+A)⁻ᵀ dT (I+A)⁻ᵀ, −1): two triangular solves
            // instead of three dense products with the explicit inverse
            solve_unit_lower_t_into(&mut scr.sol, &scr.a, &scr.dt);
            transpose_into(&mut scr.solt, &scr.sol);
            solve_unit_lower_in_place(&scr.a, &mut scr.solt);
            scr.da.reset(c, c);
            for i in 0..c {
                for j in 0..i {
                    scr.da[(i, j)] = -scr.solt[(j, i)];
                }
            }

            // dKᵦ = Tᵀ dW + dA K,  dVᵦ = Tᵀ dU
            scr.dkb.reset(c, dk);
            matmul_tn_acc(&mut scr.dkb, &scr.t, &scr.dw);
            matmul_into(&mut scr.dkb, &scr.da, kc, true);
            scr.dvb.reset(c, dv);
            matmul_tn_acc(&mut scr.dvb, &scr.t, &scr.du_bar);

            // dK += dAᵀ Kᵦ + diag(β) dKᵦ,  dV = diag(β) dVᵦ,  dβ from Kᵦ/Vᵦ
            matmul_tn_acc(&mut scr.dkc, &scr.da, &scr.kb);
            scr.dvc.reset(c, dv);
            for i in 0..c {
                let b = bc[i];
                for (x, &g) in
                    scr.dkc.row_mut(i).iter_mut().zip(scr.dkb.row(i))
                {
                    *x += b * g;
                }
                for (x, &g) in
                    scr.dvc.row_mut(i).iter_mut().zip(scr.dvb.row(i))
                {
                    *x = b * g;
                }
                dbeta[t0 + i] = simd::dot(scr.dkb.row(i), kc.row(i))
                    + simd::dot(scr.dvb.row(i), vc.row(i));
            }

            dq.data[t0 * dk..(t0 + c) * dk].copy_from_slice(&scr.dqc.data);
            dk_out.data[t0 * dk..(t0 + c) * dk]
                .copy_from_slice(&scr.dkc.data);
            dv_out.data[t0 * dv..(t0 + c) * dv]
                .copy_from_slice(&scr.dvc.data);

            // carry: dS ← dS + Qᵀ dO − Wᵀ dU̅ (last — earlier terms need old dS)
            matmul_tn_acc(&mut ds, qc, d_oc);
            scr.wtd.reset(dk, dv);
            matmul_tn_acc(&mut scr.wtd, &scr.w, &scr.du_bar);
            sub_in_place(&mut ds, &scr.wtd);
        }
    });

    let bm = bwd_counters();
    bm.calls.inc();
    bm.chunks.add(n_chunks as u64);
    bm.flops.add(flops);
    // checkpoint pre-pass re-reads the inputs, gradients are written: ~3×
    bm.bytes.add(3 * forward_bytes(l, dk, dv));

    Gradients { dq, dk: dk_out, dv: dv_out, dbeta, dstate: ds }
}

impl HeadProblem {
    /// Chunkwise backward for this problem alone.
    pub fn backward(&self, chunk: usize, d_o: &Mat, d_state: Option<&Mat>)
                    -> Gradients {
        chunkwise_backward(&self.q, &self.k, &self.v, &self.beta, chunk,
                           self.initial_state.as_ref(), d_o, d_state)
    }
}

/// Backward for every problem on an existing pool, one scoped job per
/// (batch, head) problem; results come back in problem order.  `d_o` must
/// parallel `problems`; `d_state` is optional per-problem final-state
/// gradients (None = zeros for all).
pub fn backward_batched_on(pool: &ThreadPool, problems: &[HeadProblem],
                           d_o: &[Mat], d_state: Option<&[Mat]>,
                           chunk: usize) -> Vec<Gradients> {
    assert_eq!(problems.len(), d_o.len(), "one d_o per problem");
    if let Some(dsn) = d_state {
        assert_eq!(problems.len(), dsn.len(), "one d_state per problem");
    }
    let _sp = obs::trace::span_with("kernel.batch", || {
        vec![("problems", problems.len() as f64),
             ("threads", pool.size() as f64)]
    });
    let mut slots: Vec<Option<Gradients>> = Vec::new();
    slots.resize_with(problems.len(), || None);
    pool.scope(|s| {
        for (i, (slot, p)) in slots.iter_mut().zip(problems).enumerate() {
            let go = &d_o[i];
            let gs = d_state.map(|dsn| &dsn[i]);
            s.spawn(move || {
                let _head_sp = obs::trace::span("kernel.head");
                *slot = Some(p.backward(chunk, go, gs));
            });
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("scope joined every job"))
        .collect()
}

/// Backward for every problem, spinning up a pool sized to `cfg.threads`
/// (capped at the number of problems) — the companion of
/// [`super::batch::forward_batched`].
pub fn backward_batched(problems: &[HeadProblem], d_o: &[Mat],
                        d_state: Option<&[Mat]>, cfg: &KernelConfig)
                        -> Vec<Gradients> {
    let threads = cfg.threads.max(1).min(problems.len().max(1));
    if threads <= 1 {
        assert_eq!(problems.len(), d_o.len(), "one d_o per problem");
        return problems
            .iter()
            .enumerate()
            .map(|(i, p)| {
                p.backward(cfg.chunk, &d_o[i], d_state.map(|dsn| &dsn[i]))
            })
            .collect();
    }
    let pool = ThreadPool::new(threads);
    backward_batched_on(&pool, problems, d_o, d_state, cfg.chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::chunkwise::slice_rows;
    use crate::reference::random_problem;
    use crate::tensor::rng::Rng;

    fn problem(l: usize, d: usize, seed: u64) -> HeadProblem {
        let (q, k, v, beta) = random_problem(l, d, d, seed);
        HeadProblem::new(q, k, v, beta)
    }

    #[test]
    fn backward_is_chunk_invariant() {
        // the gradients are a function of the math, not the chunking
        let p = problem(48, 8, 31);
        let mut rng = Rng::new(32);
        let d_o = Mat::random(48, 8, &mut rng, 1.0);
        let base = p.backward(1, &d_o, None);
        for chunk in [4usize, 16, 48, 64] {
            let g = p.backward(chunk, &d_o, None);
            assert!(g.dq.allclose(&base.dq, 1e-3, 1e-3), "dq C={chunk}");
            assert!(g.dk.allclose(&base.dk, 1e-3, 1e-3), "dk C={chunk}");
            assert!(g.dv.allclose(&base.dv, 1e-3, 1e-3), "dv C={chunk}");
            for (a, b) in g.dbeta.iter().zip(&base.dbeta) {
                assert!((a - b).abs() < 1e-3, "dbeta C={chunk}");
            }
            assert!(g.dstate.allclose(&base.dstate, 1e-3, 1e-3),
                    "dstate C={chunk}");
        }
    }

    #[test]
    fn batched_backward_matches_single_and_is_deterministic() {
        let ps: Vec<HeadProblem> =
            (0..6).map(|i| problem(32, 8, 40 + i)).collect();
        let mut rng = Rng::new(41);
        let d_os: Vec<Mat> =
            (0..6).map(|_| Mat::random(32, 8, &mut rng, 1.0)).collect();
        let single: Vec<Gradients> = ps
            .iter()
            .zip(&d_os)
            .map(|(p, go)| p.backward(8, go, None))
            .collect();
        for threads in [1usize, 4] {
            let cfg = KernelConfig { chunk: 8, threads };
            let batched = backward_batched(&ps, &d_os, None, &cfg);
            for (a, b) in batched.iter().zip(&single) {
                // the per-problem computation is identical code on every
                // thread count, so results must be bit-equal
                assert_eq!(a.dq.data, b.dq.data, "T={threads}");
                assert_eq!(a.dk.data, b.dk.data, "T={threads}");
                assert_eq!(a.dv.data, b.dv.data, "T={threads}");
                assert_eq!(a.dbeta, b.dbeta, "T={threads}");
                assert_eq!(a.dstate.data, b.dstate.data, "T={threads}");
            }
        }
    }

    #[test]
    fn initial_and_final_state_gradients_chain() {
        // splitting a sequence and chaining dstate across the cut must
        // equal the unsplit backward
        let l = 32;
        let p = problem(l, 6, 50);
        let mut rng = Rng::new(51);
        let d_o = Mat::random(l, 6, &mut rng, 1.0);
        let full = p.backward(8, &d_o, None);

        let half = l / 2;
        let first = HeadProblem::new(
            slice_rows(&p.q, 0, half), slice_rows(&p.k, 0, half),
            slice_rows(&p.v, 0, half), p.beta[..half].to_vec());
        let mid = first.forward(8).state;
        let second = HeadProblem {
            q: slice_rows(&p.q, half, half),
            k: slice_rows(&p.k, half, half),
            v: slice_rows(&p.v, half, half),
            beta: p.beta[half..].to_vec(),
            initial_state: Some(mid),
        };
        let g2 = second.backward(8, &slice_rows(&d_o, half, half), None);
        let g1 = first.backward(8, &slice_rows(&d_o, 0, half),
                                Some(&g2.dstate));
        for t in 0..half {
            for (a, b) in g1.dq.row(t).iter().zip(full.dq.row(t)) {
                assert!((a - b).abs() < 1e-3, "dq token {t}");
            }
            for (a, b) in g2.dk.row(t).iter().zip(full.dk.row(half + t)) {
                assert!((a - b).abs() < 1e-3, "dk token {t}");
            }
        }
        assert!((g1.dbeta[3] - full.dbeta[3]).abs() < 1e-3);
    }
}
