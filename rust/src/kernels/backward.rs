//! Chunkwise-parallel DeltaNet backward over one sequence (paper App. B):
//! gradients for q/k/v/β through the intra-chunk UT transform and the
//! inter-chunk state recurrence, in the same three-phase sequence-parallel
//! form as the forward (see [`super::chunkwise`]).
//!
//! The forward keeps only chunk boundary states, so the backward
//! recomputes per-chunk intermediates from the inputs.  Writing the state
//! gradient recurrence with the forward's scan transition P = I − KᵀW:
//!
//! ```text
//!   dS_i = Pᵢᵀ dS_{i+1} + H_i,    H_i = Qᵢᵀ dOᵢ − Wᵢᵀ (Attnᵢᵀ dOᵢ)
//! ```
//!
//! (substituting dU̅ = Attnᵀ dO + K dS into dS ← dS + QᵀdO − WᵀdU̅ and
//! noting Pᵀ = I − WᵀK), which is again an affine scan whose coefficients
//! depend only on the chunk's own tokens.  The decomposition mirrors the
//! forward's:
//!
//!   * **Phase A** ([`bwd_phase_a_chunk`]): per-chunk recompute of
//!     W/U/P/G plus the reverse-scan source H — independent across all
//!     (batch, head, chunk) tasks,
//!   * **Phase B**: the forward state scan ([`scan_states`], for the
//!     chunk-entry states S_in) and the *reverse* gradient scan
//!     ([`scan_dstates`], for the incoming dS of every chunk) — two
//!     independent per-sequence scans of state-size matmuls,
//!   * **Phase C** ([`bwd_phase_c_chunk`]): per-chunk dq/dk/dv/dβ from
//!     the propagated (S_in, dS) pair — independent across chunks, with
//!     dS the *incoming* carry (= dsb[i+1]):
//!
//! ```text
//!   dU̅  = Attnᵀ dO + K dS
//!   dAttn = tril(dO U̅ᵀ, 0)
//!   dQ   = dO S_inᵀ + dAttn K
//!   dK   = dAttnᵀ Q + U̅ dSᵀ
//!   dW   = −dU̅ S_inᵀ,  dU = dU̅
//!   dT   = dW Kᵦᵀ + dU Vᵦᵀ
//!   dA   = −tril((I+A)⁻ᵀ dT (I+A)⁻ᵀ, −1)    via two triangular solves
//!   dKᵦ  = Tᵀ dW + dA K,   dVᵦ = Tᵀ dU
//!   dK  += dAᵀ Kᵦ + diag(β) dKᵦ,   dV = diag(β) dVᵦ
//!   dβᵢ  = dKᵦᵢ·Kᵢ + dVᵦᵢ·Vᵢ
//! ```
//!
//! [`chunkwise_backward`] runs the phases in order on the calling thread;
//! [`backward_batched_on`] schedules the identical phase functions as a
//! DAG over every (batch, head, chunk) task, so the two are bit-identical
//! per sequence and parallelism is B×H×⌈L/C⌉, not B×H.

use std::sync::OnceLock;

use crate::obs::{self, metrics::{counter, Counter}};
use crate::tensor::blocked::{
    copy_into, matmul_into, matmul_nt_into, matmul_tn_acc, scale_rows_into,
    solve_unit_lower_in_place, solve_unit_lower_t_into, sub_in_place,
    transpose_into, tril_matmul_nt_into, tri_inv_unit_lower_into,
};
use crate::tensor::{simd, Mat, MatRef};
use crate::util::threadpool::{TaskDag, ThreadPool};

use super::batch::{task_count, HeadProblem, RawRange};
use super::chunkwise::{
    chunk_flops, forward_bytes, phase_a_core, scan_states, SeqBuffers,
    validate_forward_inputs,
};
use super::workspace::with_thread_workspace;
use super::KernelConfig;

struct BwdCounters {
    calls: &'static Counter,
    chunks: &'static Counter,
    flops: &'static Counter,
    bytes: &'static Counter,
}

fn bwd_counters() -> &'static BwdCounters {
    static M: OnceLock<BwdCounters> = OnceLock::new();
    M.get_or_init(|| BwdCounters {
        calls: counter("kernels.backward.calls"),
        chunks: counter("kernels.backward.chunks"),
        flops: counter("kernels.backward.flops"),
        bytes: counter("kernels.backward.bytes"),
    })
}

/// Bump the backward work counters for one sequence — shared by the
/// sequential entry point and the DAG-scheduled batch path.
pub(crate) fn note_backward(l: usize, chunk: usize, dk: usize, dv: usize) {
    let m = bwd_counters();
    m.calls.inc();
    let mut flops = 0u64;
    let mut nchunks = 0u64;
    let mut t0 = 0;
    while t0 < l {
        let c = chunk.min(l - t0);
        // recompute (≈ forward) + gradient products: ~3× the forward chunk
        flops += 3 * chunk_flops(c, dk, dv);
        nchunks += 1;
        t0 += c;
    }
    m.chunks.add(nchunks);
    m.flops.add(flops);
    // recompute re-reads the inputs, gradients are written: ~3×
    m.bytes.add(3 * forward_bytes(l, dk, dv));
}

/// Gradients of one sequence problem: same shapes as the inputs, plus the
/// gradient flowing into the initial state (zero-state problems can ignore
/// it; stacked segments chain it backwards).
#[derive(Debug, Clone)]
pub struct Gradients {
    /// [L, d_k]
    pub dq: Mat,
    /// [L, d_k]
    pub dk: Mat,
    /// [L, d_v]
    pub dv: Mat,
    /// [L]
    pub dbeta: Vec<f32>,
    /// [d_k, d_v] — gradient w.r.t. the initial state.
    pub dstate: Mat,
}

/// Backward phase A for one chunk: the forward recompute
/// ([`phase_a_core`]: W, U, P, G) plus the reverse-scan source
/// H = QᵀdO − Wᵀ(AttnᵀdO).  Independent of every other chunk.
pub(crate) fn bwd_phase_a_chunk(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    d_o: &Mat,
    t0: usize,
    c: usize,
    w_out: &mut [f32],
    u_out: &mut [f32],
    p_out: &mut [f32],
    g_out: &mut [f32],
    h_out: &mut [f32],
) {
    let (dk, dv) = (k.cols, v.cols);
    debug_assert_eq!(h_out.len(), dk * dv);
    with_thread_workspace(|scr| {
        phase_a_core(scr, k, v, beta, t0, c, w_out, u_out, p_out, g_out);
        let qc = q.rows_window(t0, c);
        let kc = k.rows_window(t0, c);
        let d_oc = d_o.rows_window(t0, c);
        tril_matmul_nt_into(&mut scr.attn, qc, kc, 0);
        // H = QᵀdO − Wᵀ(AttnᵀdO)
        scr.hc.reset(dk, dv);
        matmul_tn_acc(&mut scr.hc, qc, d_oc);
        scr.du_bar.reset(c, dv);
        matmul_tn_acc(&mut scr.du_bar, &scr.attn, d_oc);
        scr.wtd.reset(dk, dv);
        matmul_tn_acc(&mut scr.wtd, &scr.w, &scr.du_bar);
        sub_in_place(&mut scr.hc, &scr.wtd);
        h_out.copy_from_slice(&scr.hc.data);
    });
}

/// Backward phase B (reverse leg): propagate the state gradients
/// `dsb[i] = Pᵢᵀ dsb[i+1] + H_i` right to left; `dsb[n]` is seeded from
/// `d_state` and `dsb[0]` is the gradient w.r.t. the initial state.
pub(crate) fn scan_dstates(
    p: &[f32],
    h: &[f32],
    n: usize,
    dk: usize,
    dv: usize,
    d_state: Option<&Mat>,
    dsb: &mut [f32],
) {
    let sdv = dk * dv;
    debug_assert_eq!(p.len(), n * dk * dk);
    debug_assert_eq!(h.len(), n * sdv);
    debug_assert_eq!(dsb.len(), (n + 1) * sdv);
    match d_state {
        Some(dsn) => {
            debug_assert_eq!((dsn.rows, dsn.cols), (dk, dv));
            dsb[n * sdv..].copy_from_slice(&dsn.data);
        }
        None => dsb[n * sdv..].fill(0.0),
    }
    with_thread_workspace(|scr| {
        for ci in (0..n).rev() {
            let (left, right) = dsb.split_at_mut((ci + 1) * sdv);
            let ds_next =
                MatRef { rows: dk, cols: dv, data: &right[..sdv] };
            let p_i = MatRef {
                rows: dk,
                cols: dk,
                data: &p[ci * dk * dk..(ci + 1) * dk * dk],
            };
            // dsb[ci] = Pᵀ dsb[ci+1] + H
            scr.sc.reset(dk, dv);
            matmul_tn_acc(&mut scr.sc, p_i, ds_next);
            let out = &mut left[ci * sdv..];
            out.copy_from_slice(&h[ci * sdv..(ci + 1) * sdv]);
            for (x, &y) in out.iter_mut().zip(&scr.sc.data) {
                *x += y;
            }
        }
    });
}

/// Backward phase C for one chunk: dq/dk/dv/dβ from the propagated
/// `(S_in, dS)` pair, where `s_in = states[ci]` and `ds_next = dsb[ci+1]`
/// (the incoming carry).  Uses the stored W/U from phase A and recomputes
/// the chunk-local triangle factors.  Independent across chunks.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bwd_phase_c_chunk(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    d_o: &Mat,
    t0: usize,
    c: usize,
    w_c: &[f32],
    u_c: &[f32],
    s_in: &[f32],
    ds_next: &[f32],
    dq_out: &mut [f32],
    dk_out: &mut [f32],
    dv_out: &mut [f32],
    dbeta_out: &mut [f32],
) {
    let (dk, dv) = (q.cols, v.cols);
    let s_in = MatRef { rows: dk, cols: dv, data: s_in };
    let ds = MatRef { rows: dk, cols: dv, data: ds_next };
    let w = MatRef { rows: c, cols: dk, data: w_c };
    let u = MatRef { rows: c, cols: dv, data: u_c };
    with_thread_workspace(|scr| {
        let qc = q.rows_window(t0, c);
        let kc = k.rows_window(t0, c);
        let vc = v.rows_window(t0, c);
        let bc = &beta[t0..t0 + c];
        let d_oc = d_o.rows_window(t0, c);

        // recompute the chunk-local triangle factors (W/U come in stored)
        scale_rows_into(&mut scr.kb, kc, bc);
        scale_rows_into(&mut scr.vb, vc, bc);
        tril_matmul_nt_into(&mut scr.a, &scr.kb, kc, -1);
        tri_inv_unit_lower_into(&mut scr.t, &scr.a);
        // U̅ = U − W S_in
        copy_into(&mut scr.u_bar, u);
        matmul_into(&mut scr.ws, w, s_in, false);
        sub_in_place(&mut scr.u_bar, &scr.ws);
        tril_matmul_nt_into(&mut scr.attn, qc, kc, 0);

        // dU̅ = Attnᵀ dO + K dS
        scr.du_bar.reset(c, dv);
        matmul_tn_acc(&mut scr.du_bar, &scr.attn, d_oc);
        matmul_into(&mut scr.du_bar, kc, ds, true);

        // dAttn = tril(dO U̅ᵀ, 0)
        tril_matmul_nt_into(&mut scr.d_attn, d_oc, &scr.u_bar, 0);

        // dQ = dO S_inᵀ + dAttn K
        matmul_nt_into(&mut scr.dqc, d_oc, s_in, false);
        matmul_into(&mut scr.dqc, &scr.d_attn, kc, true);

        // dK = dAttnᵀ Q + U̅ dSᵀ — dS is the incoming carry (dsb[ci+1])
        scr.dkc.reset(c, dk);
        matmul_tn_acc(&mut scr.dkc, &scr.d_attn, qc);
        matmul_nt_into(&mut scr.dkc, &scr.u_bar, ds, true);

        // dW = −dU̅ S_inᵀ; dU aliases dU̅
        matmul_nt_into(&mut scr.dw, &scr.du_bar, s_in, false);
        for x in scr.dw.data.iter_mut() {
            *x = -*x;
        }

        // dT = dW Kᵦᵀ + dU Vᵦᵀ
        matmul_nt_into(&mut scr.dt, &scr.dw, &scr.kb, false);
        matmul_nt_into(&mut scr.dt, &scr.du_bar, &scr.vb, true);

        // dA = −tril((I+A)⁻ᵀ dT (I+A)⁻ᵀ, −1): two triangular solves
        // instead of three dense products with the explicit inverse
        solve_unit_lower_t_into(&mut scr.sol, &scr.a, &scr.dt);
        transpose_into(&mut scr.solt, &scr.sol);
        solve_unit_lower_in_place(&scr.a, &mut scr.solt);
        scr.da.reset(c, c);
        for i in 0..c {
            for j in 0..i {
                scr.da[(i, j)] = -scr.solt[(j, i)];
            }
        }

        // dKᵦ = Tᵀ dW + dA K,  dVᵦ = Tᵀ dU
        scr.dkb.reset(c, dk);
        matmul_tn_acc(&mut scr.dkb, &scr.t, &scr.dw);
        matmul_into(&mut scr.dkb, &scr.da, kc, true);
        scr.dvb.reset(c, dv);
        matmul_tn_acc(&mut scr.dvb, &scr.t, &scr.du_bar);

        // dK += dAᵀ Kᵦ + diag(β) dKᵦ,  dV = diag(β) dVᵦ,  dβ from Kᵦ/Vᵦ
        matmul_tn_acc(&mut scr.dkc, &scr.da, &scr.kb);
        scr.dvc.reset(c, dv);
        for i in 0..c {
            let b = bc[i];
            for (x, &g) in
                scr.dkc.row_mut(i).iter_mut().zip(scr.dkb.row(i))
            {
                *x += b * g;
            }
            for (x, &g) in
                scr.dvc.row_mut(i).iter_mut().zip(scr.dvb.row(i))
            {
                *x = b * g;
            }
            dbeta_out[i] = simd::dot(scr.dkb.row(i), kc.row(i))
                + simd::dot(scr.dvb.row(i), vc.row(i));
        }

        dq_out.copy_from_slice(&scr.dqc.data);
        dk_out.copy_from_slice(&scr.dkc.data);
        dv_out.copy_from_slice(&scr.dvc.data);
    });
}

fn validate_backward_inputs(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    chunk: usize,
    initial_state: Option<&Mat>,
    d_o: &Mat,
    d_state: Option<&Mat>,
) {
    validate_forward_inputs(q, k, v, beta, chunk, initial_state);
    assert_eq!((d_o.rows, d_o.cols), (q.rows, v.cols), "d_o shape");
    if let Some(dsn) = d_state {
        assert_eq!((dsn.rows, dsn.cols), (q.cols, v.cols),
                   "d_state shape");
    }
}

/// Chunkwise backward for one sequence.  `q,k: [L,dk]`, `v: [L,dv]`,
/// `beta: [L]`, `d_o: [L,dv]` the output gradient, `d_state: [dk,dv]` the
/// gradient w.r.t. the final state (None = zeros).  `chunk` may not divide
/// L (the tail chunk is shorter), matching the forward.
///
/// Runs the three phases sequentially on the calling thread; the batched
/// DAG path ([`backward_batched_on`]) runs the exact same phase functions,
/// so the two are bit-identical per sequence.
#[allow(clippy::too_many_arguments)]
pub fn chunkwise_backward(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    beta: &[f32],
    chunk: usize,
    initial_state: Option<&Mat>,
    d_o: &Mat,
    d_state: Option<&Mat>,
) -> Gradients {
    validate_backward_inputs(q, k, v, beta, chunk, initial_state, d_o,
                             d_state);
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;

    let _sp = obs::trace::span_with("kernel.chunkwise.backward", || {
        vec![("L", l as f64), ("chunk", chunk as f64),
             ("dk", dk as f64), ("dv", dv as f64)]
    });

    let n = l.div_ceil(chunk);
    let mut seq = SeqBuffers::backward(l, dk, dv, n);
    // ---- gradient outputs (the only other per-call allocations)
    let mut dq = Mat::zeros(l, dk);
    let mut dk_out = Mat::zeros(l, dk);
    let mut dv_out = Mat::zeros(l, dv);
    let mut dbeta = vec![0.0f32; l];

    // Phase A: per-chunk recompute of W/U/P/G + the reverse-scan source H
    for ci in 0..n {
        let t0 = ci * chunk;
        let c = chunk.min(l - t0);
        let _chunk_sp = obs::trace::span("kernel.backward.chunk");
        bwd_phase_a_chunk(q, k, v, beta, d_o, t0, c,
                          &mut seq.w[t0 * dk..(t0 + c) * dk],
                          &mut seq.u[t0 * dv..(t0 + c) * dv],
                          &mut seq.p[ci * dk * dk..(ci + 1) * dk * dk],
                          &mut seq.g[ci * dk * dv..(ci + 1) * dk * dv],
                          &mut seq.h[ci * dk * dv..(ci + 1) * dk * dv]);
    }

    // Phase B: the forward state scan and the reverse gradient scan
    {
        let _scan_sp = obs::trace::span("kernel.backward.scan");
        scan_states(&seq.p, &seq.g, n, dk, dv, initial_state,
                    &mut seq.states);
        scan_dstates(&seq.p, &seq.h, n, dk, dv, d_state, &mut seq.dsb);
    }

    // Phase C: per-chunk input gradients from the propagated (S_in, dS)
    for ci in 0..n {
        let t0 = ci * chunk;
        let c = chunk.min(l - t0);
        let _chunk_sp = obs::trace::span("kernel.backward.grad");
        bwd_phase_c_chunk(q, k, v, beta, d_o, t0, c,
                          &seq.w[t0 * dk..(t0 + c) * dk],
                          &seq.u[t0 * dv..(t0 + c) * dv],
                          &seq.states[ci * dk * dv..(ci + 1) * dk * dv],
                          &seq.dsb[(ci + 1) * dk * dv..(ci + 2) * dk * dv],
                          &mut dq.data[t0 * dk..(t0 + c) * dk],
                          &mut dk_out.data[t0 * dk..(t0 + c) * dk],
                          &mut dv_out.data[t0 * dv..(t0 + c) * dv],
                          &mut dbeta[t0..t0 + c]);
    }

    note_backward(l, chunk, dk, dv);
    Gradients { dq, dk: dk_out, dv: dv_out, dbeta, dstate: seq.dstate() }
}

impl HeadProblem {
    /// Chunkwise backward for this problem alone.
    pub fn backward(&self, chunk: usize, d_o: &Mat, d_state: Option<&Mat>)
                    -> Gradients {
        chunkwise_backward(&self.q, &self.k, &self.v, &self.beta, chunk,
                           self.initial_state.as_ref(), d_o, d_state)
    }
}

/// Add one sequence's backward tasks to the DAG: FA per chunk → {forward
/// state scan, reverse gradient scan} → C per chunk.  The two phase-B
/// scans are independent of each other and run concurrently.
fn build_backward_tasks<'env>(
    dag: &mut TaskDag<'env>,
    p: &'env HeadProblem,
    d_o: &'env Mat,
    d_state: Option<&'env Mat>,
    chunk: usize,
    buf: &mut SeqBuffers,
    out: &mut Gradients,
) {
    validate_backward_inputs(&p.q, &p.k, &p.v, &p.beta, chunk,
                             p.initial_state.as_ref(), d_o, d_state);
    let (l, dk, dv) = (p.q.rows, p.q.cols, p.v.cols);
    let n = buf.n_chunks;
    debug_assert_eq!(n, l.div_ceil(chunk));
    // Disjoint raw views of the shared per-sequence buffers, all derived
    // from one base pointer per array; the DAG edges serialize every
    // cross-task access (see build_forward_tasks in batch.rs).
    let w_all = RawRange::of(&mut buf.w);
    let u_all = RawRange::of(&mut buf.u);
    let p_all = RawRange::of(&mut buf.p);
    let g_all = RawRange::of(&mut buf.g);
    let h_all = RawRange::of(&mut buf.h);
    let states_all = RawRange::of(&mut buf.states);
    let dsb_all = RawRange::of(&mut buf.dsb);
    let dq_all = RawRange::of(&mut out.dq.data);
    let dk_all = RawRange::of(&mut out.dk.data);
    let dv_all = RawRange::of(&mut out.dv.data);
    let dbeta_all = RawRange::of(&mut out.dbeta);

    // Phase A: one independent recompute task per chunk
    let a_ids: Vec<usize> = (0..n)
        .map(|ci| {
            let t0 = ci * chunk;
            let c = chunk.min(l - t0);
            let w = w_all.sub(t0 * dk, c * dk);
            let u = u_all.sub(t0 * dv, c * dv);
            let pp = p_all.sub(ci * dk * dk, dk * dk);
            let g = g_all.sub(ci * dk * dv, dk * dv);
            let h = h_all.sub(ci * dk * dv, dk * dv);
            dag.add(&[], move || {
                let _sp = obs::trace::span("kernel.backward.chunk");
                // SAFETY: sole writer of these chunk-local ranges; the
                // phase-B/C readers depend on this task
                unsafe {
                    bwd_phase_a_chunk(&p.q, &p.k, &p.v, &p.beta, d_o, t0,
                                      c, w.slice_mut(), u.slice_mut(),
                                      pp.slice_mut(), g.slice_mut(),
                                      h.slice_mut());
                }
            })
        })
        .collect();

    // Phase B: the two per-sequence scans, concurrent with each other
    let init = p.initial_state.as_ref();
    let fb = dag.add(&a_ids, move || {
        let _sp = obs::trace::span("kernel.backward.scan");
        // SAFETY: every phase-A writer of p/g is a dependency; sole
        // writer of states (the reverse scan writes dsb, not states)
        unsafe {
            scan_states(p_all.slice(), g_all.slice(), n, dk, dv, init,
                        states_all.slice_mut());
        }
    });
    let rb = dag.add(&a_ids, move || {
        let _sp = obs::trace::span("kernel.backward.scan");
        // SAFETY: every phase-A writer of p/h is a dependency; sole
        // writer of dsb (shared read of p with the forward scan is fine)
        unsafe {
            scan_dstates(p_all.slice(), h_all.slice(), n, dk, dv, d_state,
                         dsb_all.slice_mut());
        }
    });

    // Phase C: per-chunk input gradients once both scans are in
    for ci in 0..n {
        let t0 = ci * chunk;
        let c = chunk.min(l - t0);
        let w = w_all.sub(t0 * dk, c * dk);
        let u = u_all.sub(t0 * dv, c * dv);
        let s_in = states_all.sub(ci * dk * dv, dk * dv);
        let ds = dsb_all.sub((ci + 1) * dk * dv, dk * dv);
        let dq = dq_all.sub(t0 * dk, c * dk);
        let dkr = dk_all.sub(t0 * dk, c * dk);
        let dvr = dv_all.sub(t0 * dv, c * dv);
        let db = dbeta_all.sub(t0, c);
        dag.add(&[fb, rb], move || {
            let _sp = obs::trace::span("kernel.backward.grad");
            // SAFETY: w/u/states/dsb are read-only now (their writers are
            // upstream dependencies); sole writer of these gradient ranges
            unsafe {
                bwd_phase_c_chunk(&p.q, &p.k, &p.v, &p.beta, d_o, t0, c,
                                  w.slice(), u.slice(), s_in.slice(),
                                  ds.slice(), dq.slice_mut(),
                                  dkr.slice_mut(), dvr.slice_mut(),
                                  db.slice_mut());
            }
        });
    }
}

/// Backward for every problem on an existing pool, DAG-scheduled over
/// every (batch, head, chunk) task; results come back in problem order.
/// `d_o` must parallel `problems`; `d_state` is optional per-problem
/// final-state gradients (None = zeros for all).
pub fn backward_batched_on(pool: &ThreadPool, problems: &[HeadProblem],
                           d_o: &[Mat], d_state: Option<&[Mat]>,
                           chunk: usize) -> Vec<Gradients> {
    assert!(chunk > 0, "chunk must be positive");
    assert_eq!(problems.len(), d_o.len(), "one d_o per problem");
    if let Some(dsn) = d_state {
        assert_eq!(problems.len(), dsn.len(), "one d_state per problem");
    }
    let _sp = obs::trace::span_with("kernel.batch", || {
        vec![("problems", problems.len() as f64),
             ("threads", pool.size() as f64),
             ("tasks", task_count(problems, chunk) as f64)]
    });
    if problems.is_empty() {
        return Vec::new();
    }
    let mut outs: Vec<Gradients> = problems
        .iter()
        .map(|p| Gradients {
            dq: Mat::zeros(p.q.rows, p.q.cols),
            dk: Mat::zeros(p.q.rows, p.q.cols),
            dv: Mat::zeros(p.q.rows, p.v.cols),
            dbeta: vec![0.0; p.q.rows],
            dstate: Mat::zeros(0, 0),
        })
        .collect();
    let mut bufs: Vec<SeqBuffers> = problems
        .iter()
        .map(|p| {
            SeqBuffers::backward(p.q.rows, p.q.cols, p.v.cols,
                                 p.q.rows.div_ceil(chunk))
        })
        .collect();
    let mut dag = TaskDag::new();
    for (i, (p, (buf, out))) in problems
        .iter()
        .zip(bufs.iter_mut().zip(outs.iter_mut()))
        .enumerate()
    {
        build_backward_tasks(&mut dag, p, &d_o[i],
                             d_state.map(|dsn| &dsn[i]), chunk, buf, out);
        note_backward(p.q.rows, chunk, p.q.cols, p.v.cols);
    }
    pool.run_dag(dag);
    for (g, buf) in outs.iter_mut().zip(&bufs) {
        g.dstate = buf.dstate();
    }
    outs
}

/// Backward for every problem, spinning up a pool sized to `cfg.threads`
/// capped at the total (batch, head, chunk) task count — the companion of
/// [`super::batch::forward_batched`].
pub fn backward_batched(problems: &[HeadProblem], d_o: &[Mat],
                        d_state: Option<&[Mat]>, cfg: &KernelConfig)
                        -> Vec<Gradients> {
    let threads =
        cfg.threads.max(1).min(task_count(problems, cfg.chunk).max(1));
    if threads <= 1 {
        assert_eq!(problems.len(), d_o.len(), "one d_o per problem");
        return problems
            .iter()
            .enumerate()
            .map(|(i, p)| {
                p.backward(cfg.chunk, &d_o[i], d_state.map(|dsn| &dsn[i]))
            })
            .collect();
    }
    let pool = ThreadPool::new(threads);
    backward_batched_on(&pool, problems, d_o, d_state, cfg.chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::chunkwise::slice_rows;
    use crate::reference::random_problem;
    use crate::tensor::rng::Rng;

    fn problem(l: usize, d: usize, seed: u64) -> HeadProblem {
        let (q, k, v, beta) = random_problem(l, d, d, seed);
        HeadProblem::new(q, k, v, beta)
    }

    #[test]
    fn backward_is_chunk_invariant() {
        // the gradients are a function of the math, not the chunking
        let p = problem(48, 8, 31);
        let mut rng = Rng::new(32);
        let d_o = Mat::random(48, 8, &mut rng, 1.0);
        let base = p.backward(1, &d_o, None);
        for chunk in [4usize, 16, 48, 64] {
            let g = p.backward(chunk, &d_o, None);
            assert!(g.dq.allclose(&base.dq, 1e-3, 1e-3), "dq C={chunk}");
            assert!(g.dk.allclose(&base.dk, 1e-3, 1e-3), "dk C={chunk}");
            assert!(g.dv.allclose(&base.dv, 1e-3, 1e-3), "dv C={chunk}");
            for (a, b) in g.dbeta.iter().zip(&base.dbeta) {
                assert!((a - b).abs() < 1e-3, "dbeta C={chunk}");
            }
            assert!(g.dstate.allclose(&base.dstate, 1e-3, 1e-3),
                    "dstate C={chunk}");
        }
    }

    #[test]
    fn batched_backward_matches_single_and_is_deterministic() {
        let ps: Vec<HeadProblem> =
            (0..6).map(|i| problem(32, 8, 40 + i)).collect();
        let mut rng = Rng::new(41);
        let d_os: Vec<Mat> =
            (0..6).map(|_| Mat::random(32, 8, &mut rng, 1.0)).collect();
        let single: Vec<Gradients> = ps
            .iter()
            .zip(&d_os)
            .map(|(p, go)| p.backward(8, go, None))
            .collect();
        for threads in [1usize, 4] {
            let cfg = KernelConfig { chunk: 8, threads };
            let batched = backward_batched(&ps, &d_os, None, &cfg);
            for (a, b) in batched.iter().zip(&single) {
                // the per-problem computation is identical code on every
                // thread count, so results must be bit-equal
                assert_eq!(a.dq.data, b.dq.data, "T={threads}");
                assert_eq!(a.dk.data, b.dk.data, "T={threads}");
                assert_eq!(a.dv.data, b.dv.data, "T={threads}");
                assert_eq!(a.dbeta, b.dbeta, "T={threads}");
                assert_eq!(a.dstate.data, b.dstate.data, "T={threads}");
            }
        }
    }

    #[test]
    fn initial_and_final_state_gradients_chain() {
        // splitting a sequence and chaining dstate across the cut must
        // equal the unsplit backward
        let l = 32;
        let p = problem(l, 6, 50);
        let mut rng = Rng::new(51);
        let d_o = Mat::random(l, 6, &mut rng, 1.0);
        let full = p.backward(8, &d_o, None);

        let half = l / 2;
        let first = HeadProblem::new(
            slice_rows(&p.q, 0, half), slice_rows(&p.k, 0, half),
            slice_rows(&p.v, 0, half), p.beta[..half].to_vec());
        let mid = first.forward(8).state;
        let second = HeadProblem {
            q: slice_rows(&p.q, half, half),
            k: slice_rows(&p.k, half, half),
            v: slice_rows(&p.v, half, half),
            beta: p.beta[half..].to_vec(),
            initial_state: Some(mid),
        };
        let g2 = second.backward(8, &slice_rows(&d_o, half, half), None);
        let g1 = first.backward(8, &slice_rows(&d_o, 0, half),
                                Some(&g2.dstate));
        for t in 0..half {
            for (a, b) in g1.dq.row(t).iter().zip(full.dq.row(t)) {
                assert!((a - b).abs() < 1e-3, "dq token {t}");
            }
            for (a, b) in g2.dk.row(t).iter().zip(full.dk.row(half + t)) {
                assert!((a - b).abs() < 1e-3, "dk token {t}");
            }
        }
        assert!((g1.dbeta[3] - full.dbeta[3]).abs() < 1e-3);
    }
}
