//! Host-side tensor values and their conversion to/from XLA literals,
//! plus manifest-driven parameter initialization (the Rust side owns init —
//! Python never materializes a parameter).

use xla::{ElementType, Literal};

use crate::bail;
use crate::util::error::Context;

use super::manifest::{Dtype, TensorSpec};
use crate::tensor::rng::Rng;

/// A host tensor: shape + data, f32 or i32.
#[derive(Debug, Clone, PartialEq)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn zeros_f32(shape: &[usize]) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        HostValue::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostValue::I32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> crate::Result<Self> {
        let n = shape.iter().product::<usize>().max(1);
        if data.len() != n {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(HostValue::F32 { shape: shape.to_vec(), data })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> crate::Result<Self> {
        let n = shape.iter().product::<usize>().max(1);
        if data.len() != n {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(HostValue::I32 { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } | HostValue::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostValue::F32 { data, .. } => data.len(),
            HostValue::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> crate::Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> crate::Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn scalar(&self) -> crate::Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elems", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (single copy — `create_from_shape_and_
    /// untyped_data` writes straight into the literal; the earlier
    /// `vec1().reshape()` path copied twice, see EXPERIMENTS.md §Perf).
    pub fn to_literal(&self) -> crate::Result<Literal> {
        let lit = match self {
            HostValue::F32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8, data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32, shape, bytes)?
            }
            HostValue::I32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8, data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::S32, shape, bytes)?
            }
        };
        Ok(lit)
    }

    /// Convert from an XLA literal (copies).
    pub fn from_literal(lit: &Literal) -> crate::Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(HostValue::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            ElementType::S32 => Ok(HostValue::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            t => bail!("unsupported literal element type {t:?}"),
        }
    }

    /// Approximate equality for f32 tensors (tests / cross-checks).
    pub fn allclose(&self, other: &HostValue, atol: f32, rtol: f32) -> bool {
        match (self, other) {
            (HostValue::F32 { data: a, shape: sa },
             HostValue::F32 { data: b, shape: sb }) => {
                sa == sb && a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| {
                        (x - y).abs() <= atol + rtol * y.abs().max(x.abs())
                    })
            }
            (HostValue::I32 { data: a, shape: sa },
             HostValue::I32 { data: b, shape: sb }) => sa == sb && a == b,
            _ => false,
        }
    }
}

/// Initialize one tensor from its manifest spec.  Deterministic under seed.
pub fn init_tensor(spec: &TensorSpec, rng: &mut Rng) -> crate::Result<HostValue> {
    let n = spec.element_count();
    match spec.dtype {
        Dtype::I32 => Ok(HostValue::I32 {
            shape: spec.shape.clone(),
            data: vec![0; n],
        }),
        Dtype::F32 => {
            let init = spec.init.as_deref().unwrap_or("zeros");
            let data = if init == "zeros" {
                vec![0.0; n]
            } else if init == "ones" {
                vec![1.0; n]
            } else if let Some(v) = init.strip_prefix("const:") {
                let v: f32 = v.parse().context("const init")?;
                vec![v; n]
            } else if let Some(std) = init.strip_prefix("normal:") {
                let std: f32 = std.parse().context("normal init")?;
                (0..n).map(|_| rng.normal() * std).collect()
            } else {
                bail!("unknown init spec {init:?} for {}", spec.name);
            };
            Ok(HostValue::F32 { shape: spec.shape.clone(), data })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Role;

    fn spec(init: &str) -> TensorSpec {
        TensorSpec {
            name: "w".into(),
            shape: vec![4, 8],
            dtype: Dtype::F32,
            role: Role::Param,
            init: Some(init.into()),
        }
    }

    #[test]
    fn init_kinds() {
        let mut rng = Rng::new(1);
        assert!(init_tensor(&spec("zeros"), &mut rng).unwrap()
            .as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(init_tensor(&spec("ones"), &mut rng).unwrap()
            .as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(init_tensor(&spec("const:2.5"), &mut rng).unwrap()
            .as_f32().unwrap().iter().all(|&x| x == 2.5));
        let v = init_tensor(&spec("normal:0.02"), &mut rng).unwrap();
        let d = v.as_f32().unwrap();
        assert!(d.iter().any(|&x| x != 0.0));
        assert!(d.iter().all(|&x| x.abs() < 0.2)); // 10 sigma
    }

    #[test]
    fn init_deterministic_under_seed() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a = init_tensor(&spec("normal:1.0"), &mut r1).unwrap();
        let b = init_tensor(&spec("normal:1.0"), &mut r2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostValue::from_f32(&[2, 2], vec![0.0; 3]).is_err());
        assert!(HostValue::from_i32(&[2], vec![1, 2]).is_ok());
    }

    #[test]
    fn allclose_works() {
        let a = HostValue::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        let b = HostValue::from_f32(&[2], vec![1.0 + 1e-6, 2.0]).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = HostValue::from_f32(&[2], vec![1.5, 2.0]).unwrap();
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }
}
