//! Artifact manifests: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Each `<name>.manifest.json` describes every input/output
//! tensor of the lowered HLO in the exact flattened order jax.jit used.

use std::collections::HashMap;
use std::path::Path;

use crate::bail;
use crate::util::error::Context;
use crate::util::json::Json;

/// Tensor dtype as emitted by the exporter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// Role of a tensor in the artifact signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Model parameter (has an `init` spec).
    Param,
    /// AdamW first moment.
    OptM,
    /// AdamW second moment.
    OptV,
    /// Recurrent decode state (S matrices, conv tails, KV caches).
    State,
    /// Per-step data fed by the coordinator (tokens, masks, lr, ...).
    Data,
    /// Output-only metric (loss, nll sums, predictions, logits).
    Metric,
}

impl Role {
    fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "param" => Role::Param,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "state" => Role::State,
            "data" => Role::Data,
            "metric" => Role::Metric,
            other => bail!("unknown role {other:?}"),
        })
    }
}

/// One tensor in the artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub role: Role,
    /// Init spec for params: "normal:<std>" | "zeros" | "ones" | "const:<v>"
    pub init: Option<String>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> crate::Result<Self> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_arr()?
                .iter().map(|d| d.as_usize()).collect::<crate::Result<_>>()?,
            dtype: Dtype::parse(v.req("dtype")?.as_str()?)?,
            role: Role::parse(v.req("role")?.as_str()?)?,
            init: match v.get("init") {
                Some(Json::Str(s)) => Some(s.clone()),
                _ => None,
            },
        })
    }
}

/// Model configuration echoed by the exporter (None for raw kernels).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub arch: String,
    pub use_conv: bool,
    pub conv_size: usize,
    pub feature_map: String,
    pub key_norm: String,
    pub chunk_size: usize,
    pub swa_window: usize,
    pub max_seq_len: usize,
    pub ffn_mult: f64,
}

impl ModelCfg {
    fn from_json(v: &Json) -> crate::Result<Self> {
        Ok(ModelCfg {
            vocab_size: v.req("vocab_size")?.as_usize()?,
            d_model: v.req("d_model")?.as_usize()?,
            n_layers: v.req("n_layers")?.as_usize()?,
            n_heads: v.req("n_heads")?.as_usize()?,
            arch: v.req("arch")?.as_str()?.to_string(),
            use_conv: v.req("use_conv")?.as_bool()?,
            conv_size: v.req("conv_size")?.as_usize()?,
            feature_map: v.req("feature_map")?.as_str()?.to_string(),
            key_norm: v.req("key_norm")?.as_str()?.to_string(),
            chunk_size: v.req("chunk_size")?.as_usize()?,
            swa_window: v.req("swa_window")?.as_usize()?,
            max_seq_len: v.req("max_seq_len")?.as_usize()?,
            ffn_mult: v.req("ffn_mult")?.as_f64()?,
        })
    }
}

/// A full artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub kind: String, // train | eval | decode | kernel
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub config: Option<ModelCfg>,
    pub batch: usize,
    pub seq_len: usize,
    // kernel artifacts carry their sweep parameters
    pub form: Option<String>,
    pub l: Option<usize>,
    pub d: Option<usize>,
    pub c: Option<usize>,
}

impl Manifest {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let m = Self::parse(&text)
            .with_context(|| format!("parsing manifest {}", path.display()))?;
        m.validate()?;
        Ok(m)
    }

    pub fn parse(text: &str) -> crate::Result<Self> {
        let v = Json::parse(text)?;
        let tensors = |key: &str| -> crate::Result<Vec<TensorSpec>> {
            v.req(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        let opt_usize = |key: &str| -> Option<usize> {
            v.get(key).and_then(|x| x.as_usize().ok())
        };
        Ok(Manifest {
            name: v.req("name")?.as_str()?.to_string(),
            kind: v.req("kind")?.as_str()?.to_string(),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
            config: match v.get("config") {
                Some(c) if !c.is_null() => Some(ModelCfg::from_json(c)?),
                _ => None,
            },
            batch: v.req("batch")?.as_usize()?,
            seq_len: v.req("seq_len")?.as_usize()?,
            form: v.get("form")
                .and_then(|x| x.as_str().ok().map(|s| s.to_string())),
            l: opt_usize("L"),
            d: opt_usize("d"),
            c: opt_usize("C"),
        })
    }

    /// Basic consistency checks (roles/inits/shapes).
    pub fn validate(&self) -> crate::Result<()> {
        for t in &self.inputs {
            if t.role == Role::Param && t.init.is_none() {
                bail!("param input {} missing init spec", t.name);
            }
            if t.shape.iter().any(|&d| d == 0) {
                bail!("zero-sized dim in {}", t.name);
            }
        }
        if self.inputs.is_empty() || self.outputs.is_empty() {
            bail!("manifest {} has empty signature", self.name);
        }
        Ok(())
    }

    pub fn inputs_with_role(&self, role: Role) -> Vec<(usize, &TensorSpec)> {
        self.inputs.iter().enumerate()
            .filter(|(_, t)| t.role == role).collect()
    }

    pub fn outputs_with_role(&self, role: Role) -> Vec<(usize, &TensorSpec)> {
        self.outputs.iter().enumerate()
            .filter(|(_, t)| t.role == role).collect()
    }

    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> crate::Result<usize> {
        self.inputs.iter().position(|t| t.name == name)
            .with_context(|| format!("no input named {name} in {}", self.name))
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> crate::Result<usize> {
        self.outputs.iter().position(|t| t.name == name)
            .with_context(|| format!("no output named {name} in {}", self.name))
    }

    /// Map from output index → input index for tensors that cycle through
    /// the step function (params/opt/state carried across invocations).
    pub fn carry_map(&self) -> HashMap<usize, usize> {
        let mut by_name: HashMap<&str, usize> = HashMap::new();
        for (i, t) in self.inputs.iter().enumerate() {
            by_name.insert(t.name.as_str(), i);
        }
        let mut map = HashMap::new();
        for (o, t) in self.outputs.iter().enumerate() {
            if matches!(t.role, Role::Param | Role::OptM | Role::OptV | Role::State) {
                if let Some(&i) = by_name.get(t.name.as_str()) {
                    map.insert(o, i);
                }
            }
        }
        map
    }

    /// Total parameter count (Role::Param inputs).
    pub fn param_count(&self) -> usize {
        self.inputs.iter().filter(|t| t.role == Role::Param)
            .map(|t| t.element_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "name": "t", "kind": "train", "batch": 1, "seq_len": 8,
        "config": null,
        "inputs": [
            {"name": "params.w", "shape": [2,3], "dtype": "f32",
             "role": "param", "init": "zeros"},
            {"name": "m.w", "shape": [2,3], "dtype": "f32", "role": "opt_m"},
            {"name": "tokens", "shape": [1,9], "dtype": "i32", "role": "data"}
        ],
        "outputs": [
            {"name": "params.w", "shape": [2,3], "dtype": "f32",
             "role": "param"},
            {"name": "m.w", "shape": [2,3], "dtype": "f32", "role": "opt_m"},
            {"name": "loss", "shape": [], "dtype": "f32", "role": "metric"}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[0].role, Role::Param);
        assert_eq!(m.inputs[2].dtype, Dtype::I32);
        assert_eq!(m.inputs[0].element_count(), 6);
        m.validate().unwrap();
    }

    #[test]
    fn carry_map_links_outputs_to_inputs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let map = m.carry_map();
        assert_eq!(map.get(&0), Some(&0));
        assert_eq!(map.get(&1), Some(&1));
        assert!(!map.contains_key(&2)); // loss is not carried
    }

    #[test]
    fn validate_rejects_param_without_init() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.inputs[0].init = None;
        assert!(m.validate().is_err());
    }

    #[test]
    fn name_lookups() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.input_index("tokens").unwrap(), 2);
        assert_eq!(m.output_index("loss").unwrap(), 2);
        assert!(m.input_index("nope").is_err());
        assert_eq!(m.param_count(), 6);
    }
}
