//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `client.compile` → `execute` → untuple.
//!
//! The exporter lowers with `return_tuple=True`, so every execution returns
//! a single tuple literal which we decompose back into per-output values in
//! manifest order.

pub mod manifest;
pub mod values;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use crate::ensure;
use crate::util::error::Context;

pub use manifest::{Dtype, Manifest, Role, TensorSpec};
pub use values::{init_tensor, HostValue};

use crate::tensor::rng::Rng;

/// A compiled artifact: PJRT executable + its manifest.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    /// wall time spent compiling the HLO
    pub compile_time: std::time::Duration,
}

impl Executable {
    /// Execute on host literals; returns per-output literals in manifest
    /// order.  Validates argument count against the manifest.
    pub fn execute(&self, args: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        ensure!(
            args.len() == self.manifest.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.manifest.name, self.manifest.inputs.len(), args.len()
        );
        let bufs = self.exe.execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.manifest.name))?;
        let mut tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        ensure!(
            outs.len() == self.manifest.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.manifest.name, self.manifest.outputs.len(), outs.len()
        );
        Ok(outs)
    }

    /// Execute on host values (converts in and out).
    pub fn run(&self, args: &[HostValue]) -> crate::Result<Vec<HostValue>> {
        let lits: Vec<xla::Literal> = args.iter()
            .map(|v| v.to_literal())
            .collect::<crate::Result<_>>()?;
        let outs = self.execute(&lits)?;
        outs.iter().map(HostValue::from_literal).collect()
    }

    /// Initialize all Param inputs from the manifest (seeded), with OptM /
    /// OptV / State inputs zeroed.  Returns the full input vector with Data
    /// inputs zero-initialized placeholders the caller overwrites.
    pub fn init_inputs(&self, seed: u64) -> crate::Result<Vec<HostValue>> {
        let mut rng = Rng::new(seed);
        self.manifest.inputs.iter()
            .map(|spec| match spec.role {
                Role::Param => init_tensor(spec, &mut rng),
                _ => {
                    // zeros of the right dtype/shape
                    let mut z = spec.clone();
                    z.init = Some("zeros".into());
                    init_tensor(&z, &mut rng)
                }
            })
            .collect()
    }
}

/// Runtime: one PJRT CPU client + a compile cache over artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether a real PJRT backend is linked in (false under the offline
    /// `xla` shim — artifact execution will fail and callers should use
    /// the host kernel backend or skip).
    pub fn backend_available() -> bool {
        xla::pjrt_available()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Does an artifact exist on disk?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
            && self.artifacts_dir.join(format!("{name}.manifest.json")).exists()
    }

    /// List artifact names available on disk.
    pub fn list_artifacts(&self) -> crate::Result<Vec<String>> {
        let mut names = vec![];
        for entry in std::fs::read_dir(&self.artifacts_dir)? {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = fname.strip_suffix(".manifest.json") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> crate::Result<std::sync::Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let hlo_path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let man_path = self.artifacts_dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man_path)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let exec = std::sync::Arc::new(Executable {
            exe,
            manifest,
            compile_time: t0.elapsed(),
        });
        self.cache.lock().unwrap().insert(name.to_string(), exec.clone());
        Ok(exec)
    }
}
