//! Training health monitor: rolling loss/grad-norm statistics feeding
//! NaN/Inf, spike, and plateau detectors with a configurable policy.
//!
//! A [`HealthMonitor`] lives inside the training engines
//! (`HostKernelBackend::train_step_detailed`, `Trainer::train_step`) and
//! sees every `(loss, grad_norm)` pair *before* the optimizer applies the
//! update, so the policy can actually intervene:
//!
//! * [`HealthPolicy::Warn`]     — log + count the issue, keep training,
//! * [`HealthPolicy::SkipStep`] — drop this step's optimizer update,
//! * [`HealthPolicy::Abort`]    — error out of the run (the default: this
//!   preserves the old behaviour of bailing on a non-finite loss, but now
//!   with rolling context and a flight-recorder trail).
//!
//! Detectors:
//!
//! * **non-finite** — loss or grad norm is NaN/Inf;
//! * **spike** — loss exceeds the rolling window's `mean + k·std` (only
//!   once the window holds enough samples to trust the statistics);
//! * **plateau** — no new best loss for `plateau_window` steps.  A plateau
//!   is always a warning regardless of policy: skipping or aborting a step
//!   cannot un-plateau a run, so escalation is left to the operator.
//!
//! Every verdict feeds the `train.health.*` metrics and (non-OK) flight
//! events, and the worst level seen so far is exported through the
//! `train.health.status` gauge consumed by the `/healthz` endpoint.
//!
//! Env knobs (see [`HealthConfig::from_env`]):
//! `DELTANET_HEALTH=warn|skip|abort`, `DELTANET_HEALTH_WINDOW=N`,
//! `DELTANET_HEALTH_SPIKE=K`, `DELTANET_HEALTH_PLATEAU=N` (0 disables).

use std::collections::VecDeque;

use super::{flight, metrics};

/// What to do when a detector fires on a training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HealthPolicy {
    /// Count + log, keep the step.
    Warn,
    /// Drop the optimizer update for the offending step, keep training.
    SkipStep,
    /// Fail the run (matches the pre-monitor `bail!` on non-finite loss).
    #[default]
    Abort,
}

impl HealthPolicy {
    pub fn parse(s: &str) -> Option<HealthPolicy> {
        match s {
            "warn" => Some(HealthPolicy::Warn),
            "skip" | "skip_step" => Some(HealthPolicy::SkipStep),
            "abort" => Some(HealthPolicy::Abort),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            HealthPolicy::Warn => "warn",
            HealthPolicy::SkipStep => "skip_step",
            HealthPolicy::Abort => "abort",
        }
    }
}

/// Detector thresholds + policy.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    pub policy: HealthPolicy,
    /// Rolling window length for the spike statistics.
    pub window: usize,
    /// Minimum window samples before the spike detector arms.
    pub spike_min_samples: usize,
    /// Spike when `loss > mean + spike_factor * std` over the window.
    pub spike_factor: f64,
    /// Warn when no new best loss for this many steps (0 disables).
    pub plateau_window: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            policy: HealthPolicy::Abort,
            window: 32,
            spike_min_samples: 8,
            spike_factor: 6.0,
            plateau_window: 0,
        }
    }
}

impl HealthConfig {
    /// Defaults overridden by `DELTANET_HEALTH*` environment variables.
    pub fn from_env() -> Self {
        let mut cfg = HealthConfig::default();
        if let Ok(p) = std::env::var("DELTANET_HEALTH") {
            if let Some(policy) = HealthPolicy::parse(&p) {
                cfg.policy = policy;
            }
        }
        let parse = |key: &str| {
            std::env::var(key).ok().and_then(|v| v.parse::<f64>().ok())
        };
        if let Some(w) = parse("DELTANET_HEALTH_WINDOW") {
            cfg.window = (w as usize).max(2);
        }
        if let Some(k) = parse("DELTANET_HEALTH_SPIKE") {
            cfg.spike_factor = k.max(0.0);
        }
        if let Some(p) = parse("DELTANET_HEALTH_PLATEAU") {
            cfg.plateau_window = p as usize;
        }
        cfg
    }
}

/// Why a verdict was issued.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HealthIssue {
    NonFiniteLoss,
    NonFiniteGrad,
    LossSpike { loss: f64, mean: f64, std: f64 },
    Plateau { best: f64, stale_steps: usize },
}

impl std::fmt::Display for HealthIssue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HealthIssue::NonFiniteLoss => write!(f, "non-finite loss"),
            HealthIssue::NonFiniteGrad => write!(f, "non-finite grad norm"),
            HealthIssue::LossSpike { loss, mean, std } => write!(
                f, "loss spike: {loss:.4} vs window mean {mean:.4} \
                    (std {std:.4})"),
            HealthIssue::Plateau { best, stale_steps } => write!(
                f, "plateau: no improvement on best loss {best:.4} \
                    for {stale_steps} steps"),
        }
    }
}

/// The monitor's decision for one step.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    Ok,
    Warn(HealthIssue),
    /// Drop the optimizer update for this step.
    Skip(HealthIssue),
    /// Fail the run.
    Abort(HealthIssue),
}

impl Verdict {
    pub fn issue(&self) -> Option<&HealthIssue> {
        match self {
            Verdict::Ok => None,
            Verdict::Warn(i) | Verdict::Skip(i) | Verdict::Abort(i) => {
                Some(i)
            }
        }
    }
}

/// `train.health.status` gauge levels (also the `/healthz` contract):
/// 0 = healthy, 1 = warned/skipped at least once, 2 = aborted.
pub const STATUS_OK: i64 = 0;
pub const STATUS_WARN: i64 = 1;
pub const STATUS_FAILING: i64 = 2;

fn raise_status(level: i64) {
    let g = metrics::gauge("train.health.status");
    if g.get() < level {
        g.set(level);
    }
}

/// Current process-wide health level (worst seen by any monitor).
pub fn global_status() -> i64 {
    metrics::gauge("train.health.status").get()
}

/// Rolling-statistics monitor; one per training engine.
pub struct HealthMonitor {
    cfg: HealthConfig,
    window: VecDeque<f64>,
    steps_seen: usize,
    best_loss: f64,
    best_step: usize,
}

impl HealthMonitor {
    pub fn new(cfg: HealthConfig) -> Self {
        HealthMonitor {
            cfg,
            window: VecDeque::new(),
            steps_seen: 0,
            best_loss: f64::INFINITY,
            best_step: 0,
        }
    }

    /// Monitor configured from `DELTANET_HEALTH*` env vars.
    pub fn from_env() -> Self {
        Self::new(HealthConfig::from_env())
    }

    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    pub fn steps_seen(&self) -> usize {
        self.steps_seen
    }

    fn window_stats(&self) -> Option<(f64, f64)> {
        if self.window.len() < self.cfg.spike_min_samples.max(2) {
            return None;
        }
        let n = self.window.len() as f64;
        let mean = self.window.iter().sum::<f64>() / n;
        let var = self.window.iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>() / n;
        Some((mean, var.sqrt()))
    }

    /// Classify one step, update the rolling state, emit metrics + flight
    /// events, and return the policy's verdict.
    pub fn observe(&mut self, loss: f32, grad_norm: Option<f32>)
                   -> Verdict {
        self.steps_seen += 1;
        let step = self.steps_seen;

        let issue = self.detect(loss as f64, grad_norm.map(|g| g as f64));
        let verdict = match issue {
            None => Verdict::Ok,
            Some(HealthIssue::Plateau { .. }) => {
                // never skip/abort on a plateau (see module docs)
                Verdict::Warn(issue.unwrap())
            }
            Some(i) => match self.cfg.policy {
                HealthPolicy::Warn => Verdict::Warn(i),
                HealthPolicy::SkipStep => Verdict::Skip(i),
                HealthPolicy::Abort => Verdict::Abort(i),
            },
        };
        self.account(step, loss as f64, &verdict);
        verdict
    }

    fn detect(&mut self, loss: f64, grad_norm: Option<f64>)
              -> Option<HealthIssue> {
        if !loss.is_finite() {
            return Some(HealthIssue::NonFiniteLoss);
        }
        if let Some(g) = grad_norm {
            if !g.is_finite() {
                return Some(HealthIssue::NonFiniteGrad);
            }
        }
        if self.cfg.spike_factor > 0.0 {
            if let Some((mean, std)) = self.window_stats() {
                // floor the deviation so a flat window (std≈0) does not
                // flag ordinary batch-to-batch noise as a spike
                let dev = std.max(mean.abs() * 0.01).max(1e-6);
                if loss > mean + self.cfg.spike_factor * dev {
                    return Some(HealthIssue::LossSpike { loss, mean, std });
                }
            }
        }
        if self.cfg.plateau_window > 0
            && self.steps_seen - self.best_step >= self.cfg.plateau_window
            && self.best_loss.is_finite()
        {
            return Some(HealthIssue::Plateau {
                best: self.best_loss,
                stale_steps: self.steps_seen - self.best_step,
            });
        }
        None
    }

    fn account(&mut self, step: usize, loss: f64, verdict: &Verdict) {
        // rolling state: finite losses only, spikes included (a genuine
        // level shift must eventually stop counting as a spike)
        if loss.is_finite() {
            self.window.push_back(loss);
            while self.window.len() > self.cfg.window {
                self.window.pop_front();
            }
            if loss < self.best_loss {
                self.best_loss = loss;
                self.best_step = step;
            }
        }
        let issue = match verdict.issue() {
            None => return,
            Some(i) => i,
        };
        let issue_name = match issue {
            HealthIssue::NonFiniteLoss | HealthIssue::NonFiniteGrad => {
                metrics::counter("train.health.nonfinite").inc();
                "nonfinite"
            }
            HealthIssue::LossSpike { .. } => {
                metrics::counter("train.health.spikes").inc();
                "spike"
            }
            HealthIssue::Plateau { .. } => {
                // re-arm: one warning per stale stretch, not per step
                self.best_step = step;
                metrics::counter("train.health.plateaus").inc();
                "plateau"
            }
        };
        let (level, action) = match verdict {
            Verdict::Ok => unreachable!("issue implies non-Ok verdict"),
            Verdict::Warn(_) => (STATUS_WARN, 0.0),
            Verdict::Skip(_) => {
                metrics::counter("train.health.skipped_steps").inc();
                (STATUS_WARN, 1.0)
            }
            Verdict::Abort(_) => {
                metrics::counter("train.health.aborts").inc();
                (STATUS_FAILING, 2.0)
            }
        };
        raise_status(level);
        flight::record(
            flight::EventKind::Health,
            &format!("health.{issue_name}"),
            &[("step", step as f64), ("loss", loss), ("action", action)],
        );
        eprintln!("[health] step {step}: {issue} -> {}",
                  match verdict {
                      Verdict::Warn(_) => "warn",
                      Verdict::Skip(_) => "skip step",
                      Verdict::Abort(_) => "abort",
                      Verdict::Ok => unreachable!(),
                  });
    }
}

impl Default for HealthMonitor {
    fn default() -> Self {
        Self::new(HealthConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn warn_cfg() -> HealthConfig {
        HealthConfig { policy: HealthPolicy::Warn, ..Default::default() }
    }

    #[test]
    fn finite_steady_losses_are_ok() {
        let mut m = HealthMonitor::new(warn_cfg());
        for i in 0..50 {
            let loss = 2.0 - 0.01 * i as f32;
            assert_eq!(m.observe(loss, Some(1.0)), Verdict::Ok, "step {i}");
        }
        assert_eq!(m.steps_seen(), 50);
    }

    #[test]
    fn nonfinite_maps_through_policy() {
        for (policy, want_skip, want_abort) in [
            (HealthPolicy::Warn, false, false),
            (HealthPolicy::SkipStep, true, false),
            (HealthPolicy::Abort, false, true),
        ] {
            let mut m = HealthMonitor::new(HealthConfig {
                policy, ..Default::default()
            });
            let v = m.observe(f32::NAN, Some(1.0));
            assert_eq!(v.issue(), Some(&HealthIssue::NonFiniteLoss));
            assert_eq!(matches!(v, Verdict::Skip(_)), want_skip);
            assert_eq!(matches!(v, Verdict::Abort(_)), want_abort);
        }
        // non-finite grad with finite loss is its own issue
        let mut m = HealthMonitor::new(warn_cfg());
        let v = m.observe(1.0, Some(f32::INFINITY));
        assert_eq!(v.issue(), Some(&HealthIssue::NonFiniteGrad));
    }

    #[test]
    fn spike_detector_fires_after_window_fills() {
        let mut m = HealthMonitor::new(warn_cfg());
        // too few samples: a wild value passes while the detector is unarmed
        assert_eq!(m.observe(100.0, None), Verdict::Ok);
        let mut m = HealthMonitor::new(warn_cfg());
        for i in 0..20 {
            let loss = 1.0 + 0.01 * (i % 3) as f32; // tight band
            assert_eq!(m.observe(loss, None), Verdict::Ok);
        }
        let v = m.observe(50.0, None);
        assert!(matches!(v.issue(), Some(HealthIssue::LossSpike { .. })),
                "expected spike, got {v:?}");
        // the spike entered the window, so a repeat of the same level
        // eventually stops flagging (genuine level shifts are absorbed)
        let mut flagged = 0;
        for _ in 0..40 {
            if m.observe(50.0, None) != Verdict::Ok {
                flagged += 1;
            }
        }
        assert!(flagged < 40, "level shift never absorbed");
    }

    #[test]
    fn plateau_warns_once_per_stale_stretch_even_under_abort() {
        let mut m = HealthMonitor::new(HealthConfig {
            policy: HealthPolicy::Abort,
            plateau_window: 10,
            spike_factor: 0.0,
            ..Default::default()
        });
        assert_eq!(m.observe(1.0, None), Verdict::Ok);
        let mut warns = 0;
        for _ in 0..25 {
            match m.observe(1.0, None) {
                Verdict::Ok => {}
                Verdict::Warn(HealthIssue::Plateau { .. }) => warns += 1,
                other => panic!("plateau must only warn, got {other:?}"),
            }
        }
        // 25 stale steps with a window of 10 → two warnings, not 15
        assert_eq!(warns, 2);
    }

    #[test]
    fn verdicts_feed_health_metrics() {
        let before = metrics::counter("train.health.nonfinite").get();
        let mut m = HealthMonitor::new(warn_cfg());
        m.observe(f32::INFINITY, None);
        assert!(metrics::counter("train.health.nonfinite").get() > before);
        assert!(global_status() >= STATUS_WARN);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [HealthPolicy::Warn, HealthPolicy::SkipStep,
                  HealthPolicy::Abort] {
            assert_eq!(HealthPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(HealthPolicy::parse("bogus"), None);
    }
}
