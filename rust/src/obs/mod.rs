//! Zero-dependency observability: hierarchical trace spans + a global
//! metrics registry.
//!
//! Two complementary views of the same run:
//!
//! * [`trace`] — scoped RAII spans with per-thread span stacks, exported
//!   as Chrome trace-event JSON (load in Perfetto or `chrome://tracing`).
//!   Off by default; one relaxed atomic load per span when disabled, so
//!   instrumentation can live permanently on hot paths.  Enable with
//!   `DELTANET_TRACE=out.json` (see [`trace::init_from_env`]).
//! * [`metrics`] — always-on atomic counters, gauges, and log-linear
//!   latency histograms (p50/p95/p99) addressable by static name, e.g.
//!   `metrics::counter("kernels.forward.flops").add(n)`.
//! * [`export`] — a `std::net`-only HTTP endpoint serving the metrics
//!   snapshot (`/metrics`, `/metrics.json`), liveness (`/healthz`), and
//!   the flight-recorder ring (`/flight.json`).
//! * [`flight`] — an always-on lock-free ring buffer of structured
//!   events (spans, train steps, counter snapshots, health incidents)
//!   with a panic hook that dumps the tail + a metrics snapshot to
//!   `FLIGHT_<run>.json` for post-mortems.
//! * [`health`] — rolling loss/grad statistics feeding NaN/Inf, spike,
//!   and plateau detectors with a `warn | skip_step | abort` policy
//!   (`DELTANET_HEALTH`), surfaced as `train.health.*` metrics.
//! * [`regress`] — the bench regression gate behind
//!   `deltanet bench-diff`: compares `BENCH_*.json` reports against
//!   committed baselines with per-metric noise thresholds.
//!
//! Naming convention (dot-separated, coarse→fine):
//! `kernel.*` / `kernels.*` for the chunkwise/backward/batch layer,
//! `pool.*` for the thread pool, `model.*` + `train.*` for the training
//! stack, `decode.*` + `serve.*` for inference, `backend.*` for the
//! `Backend`-trait boundary.

pub mod export;
pub mod flight;
pub mod health;
pub mod metrics;
pub mod regress;
pub mod trace;
