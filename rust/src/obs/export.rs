//! Minimal metrics snapshot endpoint over `std::net` — no async runtime.
//!
//! Serves the [`super::metrics`] registry on demand:
//!
//! * `GET /metrics` — one metric per line (text)
//! * `GET /metrics.json` — the JSON snapshot
//! * `GET /healthz` — `200 ok` while training health is not failing,
//!   `503` once the [`super::health`] status gauge reports failure
//! * `GET /flight.json` — the live flight-recorder ring + metrics
//!
//! Unknown paths get `404`; non-GET methods get `405` with an `Allow`
//! header.  The listener polls non-blocking accepts on a named thread so
//! shutdown (drop or [`MetricsServer::shutdown`]) never hangs on a
//! blocked accept.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::{flight, health, metrics};

const POLL: Duration = Duration::from_millis(25);

/// Handle to a running metrics endpoint; stops serving when dropped.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// The bound address (useful with a `:0` ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:9464"` or `"127.0.0.1:0"`) and serve the
/// metrics snapshot until the returned handle is dropped.
pub fn serve_metrics(addr: &str) -> crate::Result<MetricsServer> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| crate::err!("binding metrics endpoint {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::Builder::new()
        .name("metrics-endpoint".to_string())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = handle_conn(stream);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(POLL);
                    }
                    Err(_) => std::thread::sleep(POLL),
                }
            }
        })?;
    Ok(MetricsServer { addr: bound, stop, handle: Some(handle) })
}

fn handle_conn(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let mut first = req.lines().next().unwrap_or("").split_whitespace();
    let method = first.next().unwrap_or("");
    let path = first.next().unwrap_or("/");

    let (status, ctype, body, allow) = route(method, path);
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\n{}Connection: close\r\n\r\n{body}",
        body.len(),
        if allow { "Allow: GET\r\n" } else { "" },
    )?;
    stream.flush()
}

const TEXT: &str = "text/plain; charset=utf-8";
const JSON: &str = "application/json";

fn route(method: &str, path: &str)
         -> (&'static str, &'static str, String, bool) {
    if method != "GET" {
        return ("405 Method Not Allowed", TEXT,
                "method not allowed\n".to_string(), true);
    }
    match path {
        "/metrics" | "/" => {
            ("200 OK", TEXT, metrics::snapshot().render_text(), false)
        }
        "/metrics.json" => {
            ("200 OK", JSON,
             metrics::snapshot().to_json().render() + "\n", false)
        }
        "/healthz" => {
            if health::global_status() >= health::STATUS_FAILING {
                ("503 Service Unavailable", TEXT,
                 "failing\n".to_string(), false)
            } else {
                ("200 OK", TEXT, "ok\n".to_string(), false)
            }
        }
        "/flight.json" => {
            ("200 OK", JSON, flight::snapshot_json().render() + "\n",
             false)
        }
        _ => ("404 Not Found", TEXT, "not found\n".to_string(), false),
    }
}
