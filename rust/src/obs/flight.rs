//! Always-on flight recorder: a lock-free ring buffer of the last N
//! structured events (train steps, span open/close, counter snapshots,
//! health verdicts, panics), dumped to `FLIGHT_<run>.json` together with a
//! full metrics snapshot whenever the process panics — including a panic
//! inside a pool worker that the pool itself catches and survives.
//!
//! Unlike [`super::trace`] (opt-in, unbounded buffers, written at clean
//! exit), the recorder is meant to be **on for every run** and to survive
//! crashes: recording an event is a handful of relaxed atomic stores into
//! a fixed ring (no locks, no allocation after the name is interned), and
//! the dump path is wired into a process-wide panic hook installed by
//! [`install_panic_hook`] / [`init_from_env`].
//!
//! Each slot is a seqlock: the writer claims a sequence number with one
//! `fetch_add`, takes exclusive ownership of the destination slot with a
//! single CAS to a `BUSY` marker (writers only ever contend on the same
//! slot when one lags a full ring behind, so the claim virtually never
//! spins), writes the fields, then publishes the real sequence number
//! with `Release`.  Readers ([`snapshot_events`]) read `seq` before and
//! after the fields and discard the slot when the two reads disagree or
//! the slot is mid-write, so a reader racing a wrapping writer sees
//! either the old event or nothing — never a torn one.  Everything in a
//! slot is an atomic integer (names and field keys are interned to `u32`
//! ids), so there is no `unsafe` and no UB-prone shared mutable state.
//!
//! Env knobs (read once, at first use / [`init_from_env`]):
//!
//! * `DELTANET_FLIGHT=off`        — disable recording and the panic hook
//! * `DELTANET_FLIGHT_EVENTS=N`   — ring capacity (default 1024)
//! * `DELTANET_FLIGHT_DIR=DIR`    — where `FLIGHT_<run>.json` lands (".")
//! * `DELTANET_RUN_ID=NAME`       — run id (defaults to the process id)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, RwLock};
use std::time::Instant;

use crate::util::json::Json;

use super::metrics;

/// Default ring capacity (events kept for the post-mortem).
pub const DEFAULT_CAPACITY: usize = 1024;
/// Numeric fields carried per event (excess fields are dropped).
pub const MAX_FIELDS: usize = 4;

const NO_NAME: u32 = u32::MAX;

/// Slot `seq` marker for "a writer owns this slot right now" (0 = empty).
const BUSY: u64 = u64::MAX;

/// What kind of thing an event records (stable names in the JSON dump).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A trace span opened (recorded only while tracing is enabled).
    SpanOpen,
    /// A trace span closed (dur_ms field).
    SpanClose,
    /// One training step (step / loss / grad_norm / ms fields).
    Step,
    /// Point-in-time values of selected metrics counters.
    Counter,
    /// A training-health verdict (see [`super::health`]).
    Health,
    /// A panic observed by the process-wide hook or a pool worker.
    Panic,
    /// Free-form marker (run phase boundaries etc.).
    Mark,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::SpanOpen => "span_open",
            EventKind::SpanClose => "span_close",
            EventKind::Step => "step",
            EventKind::Counter => "counter",
            EventKind::Health => "health",
            EventKind::Panic => "panic",
            EventKind::Mark => "mark",
        }
    }

    fn from_u32(v: u32) -> EventKind {
        match v {
            0 => EventKind::SpanOpen,
            1 => EventKind::SpanClose,
            2 => EventKind::Step,
            3 => EventKind::Counter,
            4 => EventKind::Health,
            5 => EventKind::Panic,
            _ => EventKind::Mark,
        }
    }

    fn to_u32(self) -> u32 {
        match self {
            EventKind::SpanOpen => 0,
            EventKind::SpanClose => 1,
            EventKind::Step => 2,
            EventKind::Counter => 3,
            EventKind::Health => 4,
            EventKind::Panic => 5,
            EventKind::Mark => 6,
        }
    }
}

/// One decoded event, as returned by [`snapshot_events`].
#[derive(Debug, Clone)]
pub struct FlightEvent {
    /// Global sequence number (1-based, strictly increasing).
    pub seq: u64,
    /// Microseconds since the recorder epoch.
    pub ts_us: f64,
    pub kind: EventKind,
    pub name: String,
    pub fields: Vec<(String, f64)>,
}

/// Seqlock slot: `seq == 0` means empty, [`BUSY`] means mid-write.
struct Slot {
    seq: AtomicU64,
    ts_us: AtomicU64, // f64 bits
    kind: AtomicU32,
    name: AtomicU32,
    n_fields: AtomicU32,
    keys: [AtomicU32; MAX_FIELDS],
    vals: [AtomicU64; MAX_FIELDS], // f64 bits
}

impl Slot {
    fn empty() -> Self {
        Slot {
            seq: AtomicU64::new(0),
            ts_us: AtomicU64::new(0),
            kind: AtomicU32::new(0),
            name: AtomicU32::new(NO_NAME),
            n_fields: AtomicU32::new(0),
            keys: std::array::from_fn(|_| AtomicU32::new(NO_NAME)),
            vals: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

struct Ring {
    slots: Vec<Slot>,
    /// Next sequence number to hand out (seq ids start at 1).
    head: AtomicU64,
    epoch: Instant,
}

fn ring() -> &'static Ring {
    static R: OnceLock<Ring> = OnceLock::new();
    R.get_or_init(|| {
        let cap = std::env::var("DELTANET_FLIGHT_EVENTS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(DEFAULT_CAPACITY);
        Ring {
            slots: (0..cap).map(|_| Slot::empty()).collect(),
            head: AtomicU64::new(1),
            epoch: Instant::now(),
        }
    })
}

/// Interned event/field names: id ↔ string, append-only.
#[derive(Default)]
struct Names {
    by_name: BTreeMap<String, u32>,
    by_id: Vec<String>,
}

fn names() -> &'static RwLock<Names> {
    static N: OnceLock<RwLock<Names>> = OnceLock::new();
    N.get_or_init(|| RwLock::new(Names::default()))
}

fn intern(name: &str) -> u32 {
    if let Some(&id) = names().read().unwrap().by_name.get(name) {
        return id;
    }
    let mut w = names().write().unwrap();
    if let Some(&id) = w.by_name.get(name) {
        return id;
    }
    let id = w.by_id.len() as u32;
    w.by_id.push(name.to_string());
    w.by_name.insert(name.to_string(), id);
    id
}

fn resolve(id: u32) -> String {
    names()
        .read()
        .unwrap()
        .by_id
        .get(id as usize)
        .cloned()
        .unwrap_or_else(|| format!("name#{id}"))
}

static DISABLED: AtomicBool = AtomicBool::new(false);

/// Turn recording off/on at runtime (also settable via
/// `DELTANET_FLIGHT=off` through [`init_from_env`]).
pub fn set_enabled(on: bool) {
    DISABLED.store(!on, Ordering::SeqCst);
}

/// Is the recorder currently accepting events?
pub fn enabled() -> bool {
    !DISABLED.load(Ordering::Relaxed)
}

/// Record one event.  Lock-free: one `fetch_add` to claim a slot plus a
/// fixed number of relaxed stores; at most [`MAX_FIELDS`] fields are kept.
pub fn record(kind: EventKind, name: &str, fields: &[(&str, f64)]) {
    if DISABLED.load(Ordering::Relaxed) {
        return;
    }
    let r = ring();
    let ts = r.epoch.elapsed().as_secs_f64() * 1e6;
    let name_id = intern(name);
    let seq = r.head.fetch_add(1, Ordering::Relaxed);
    let slot = &r.slots[(seq % r.slots.len() as u64) as usize];
    // Claim the slot exclusively (two writers only meet here when one
    // lags a full ring behind the other, so this effectively never
    // spins).  Without the claim, interleaved writers could each see a
    // "stable" seq while the fields mix values from both events.
    loop {
        let cur = slot.seq.load(Ordering::Relaxed);
        if cur == BUSY {
            std::hint::spin_loop();
            continue;
        }
        if slot
            .seq
            .compare_exchange_weak(cur, BUSY, Ordering::Acquire,
                                   Ordering::Relaxed)
            .is_ok()
        {
            break;
        }
    }
    slot.ts_us.store(ts.to_bits(), Ordering::Relaxed);
    slot.kind.store(kind.to_u32(), Ordering::Relaxed);
    slot.name.store(name_id, Ordering::Relaxed);
    let n = fields.len().min(MAX_FIELDS);
    slot.n_fields.store(n as u32, Ordering::Relaxed);
    for (i, (k, v)) in fields.iter().take(MAX_FIELDS).enumerate() {
        slot.keys[i].store(intern(k), Ordering::Relaxed);
        slot.vals[i].store(v.to_bits(), Ordering::Relaxed);
    }
    slot.seq.store(seq, Ordering::Release);
}

/// Record a [`EventKind::Counter`] event holding the current values of up
/// to [`MAX_FIELDS`] interned metrics counters.
pub fn record_counters(counter_names: &[&'static str]) {
    if DISABLED.load(Ordering::Relaxed) {
        return;
    }
    let fields: Vec<(&str, f64)> = counter_names
        .iter()
        .take(MAX_FIELDS)
        .map(|&n| (n, metrics::counter(n).get() as f64))
        .collect();
    record(EventKind::Counter, "metrics.counters", &fields);
}

/// Consistent copy of every live ring event, ordered by sequence number.
/// Slots a concurrent writer is mid-way through are skipped, not torn.
pub fn snapshot_events() -> Vec<FlightEvent> {
    let r = ring();
    let mut out: Vec<FlightEvent> = Vec::with_capacity(r.slots.len());
    for slot in &r.slots {
        let seq = slot.seq.load(Ordering::Acquire);
        if seq == 0 || seq == BUSY {
            continue;
        }
        let ts_us = f64::from_bits(slot.ts_us.load(Ordering::Relaxed));
        let kind = EventKind::from_u32(slot.kind.load(Ordering::Relaxed));
        let name_id = slot.name.load(Ordering::Relaxed);
        let n = slot.n_fields.load(Ordering::Relaxed) as usize;
        let mut fields = Vec::with_capacity(n.min(MAX_FIELDS));
        for i in 0..n.min(MAX_FIELDS) {
            fields.push((
                resolve(slot.keys[i].load(Ordering::Relaxed)),
                f64::from_bits(slot.vals[i].load(Ordering::Relaxed)),
            ));
        }
        // seqlock read validation: discard the slot if a writer raced us
        if slot.seq.load(Ordering::Acquire) != seq {
            continue;
        }
        out.push(FlightEvent {
            seq,
            ts_us,
            kind,
            name: resolve(name_id),
            fields,
        });
    }
    out.sort_by_key(|e| e.seq);
    out
}

// ------------------------------------------------------------ dump plumbing

struct DumpConfig {
    run_id: String,
    dir: PathBuf,
}

fn dump_config() -> &'static Mutex<DumpConfig> {
    static C: OnceLock<Mutex<DumpConfig>> = OnceLock::new();
    C.get_or_init(|| {
        Mutex::new(DumpConfig {
            run_id: std::env::var("DELTANET_RUN_ID")
                .unwrap_or_else(|_| std::process::id().to_string()),
            dir: std::env::var_os("DELTANET_FLIGHT_DIR")
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from(".")),
        })
    })
}

/// Override the run id used in the dump filename (`FLIGHT_<run>.json`).
pub fn set_run_id(run: &str) {
    dump_config().lock().unwrap().run_id = run.to_string();
}

/// Override the directory the panic dump is written into.
pub fn set_dump_dir(dir: &Path) {
    dump_config().lock().unwrap().dir = dir.to_path_buf();
}

/// Where [`dump`] (and the panic hook) will write.
pub fn dump_path() -> PathBuf {
    let c = dump_config().lock().unwrap();
    c.dir.join(format!("FLIGHT_{}.json", c.run_id))
}

/// The full recorder state as JSON: schema tag, run id, the event ring,
/// and a point-in-time metrics snapshot (the `/flight.json` payload).
pub fn snapshot_json() -> Json {
    // non-finite field values (a NaN loss in a health event) must not
    // produce invalid JSON — they become null
    let num = |v: f64| if v.is_finite() { Json::num(v) } else { Json::Null };
    let events = snapshot_events()
        .into_iter()
        .map(|e| {
            let fields = e
                .fields
                .iter()
                .map(|(k, v)| (k.as_str(), num(*v)))
                .collect::<Vec<_>>();
            Json::obj(vec![
                ("seq", Json::num(e.seq as f64)),
                ("ts_us", Json::num(e.ts_us)),
                ("kind", Json::str(e.kind.name())),
                ("name", Json::str(e.name)),
                ("fields", Json::obj(fields)),
            ])
        })
        .collect::<Vec<_>>();
    let run_id = dump_config().lock().unwrap().run_id.clone();
    Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("run", Json::str(run_id)),
        ("events", Json::Arr(events)),
        ("metrics", metrics::snapshot().to_json()),
    ])
}

/// Schema tag written into every dump (checked by `deltanet trace-check`).
pub const SCHEMA: &str = "deltanet.flight.v1";

/// Write the recorder state to [`dump_path`] and return it.
pub fn dump() -> crate::Result<PathBuf> {
    let path = dump_path();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&path, snapshot_json().render() + "\n")?;
    Ok(path)
}

static HOOK_INSTALLED: AtomicBool = AtomicBool::new(false);

/// Install the process-wide panic hook (idempotent).  The hook records a
/// [`EventKind::Panic`] event and dumps `FLIGHT_<run>.json`, then chains
/// to the previously installed hook — so a panic a pool worker catches
/// still leaves a post-mortem artifact on disk before the pool recovers.
pub fn install_panic_hook() {
    if HOOK_INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        if enabled() {
            let name = info
                .location()
                .map(|l| format!("panic@{}:{}", l.file(), l.line()))
                .unwrap_or_else(|| "panic".to_string());
            record(EventKind::Panic, &name, &[]);
            // best effort: a failing dump must not double-panic the hook
            let _ = dump();
        }
        prev(info);
    }));
}

/// Configure the recorder from the environment and arm the panic hook:
/// the standard one-call setup used by `main` and the benches.  Returns
/// the dump path the hook will use, or `None` when `DELTANET_FLIGHT=off`.
pub fn init_from_env() -> Option<PathBuf> {
    if std::env::var("DELTANET_FLIGHT").ok().as_deref() == Some("off") {
        set_enabled(false);
        return None;
    }
    let _ = dump_config(); // pick up env run id / dir
    install_panic_hook();
    Some(dump_path())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot_roundtrip() {
        let before = snapshot_events().len();
        record(EventKind::Mark, "test.flight.mark",
               &[("a", 1.0), ("b", 2.5)]);
        record(EventKind::Step, "test.flight.step",
               &[("step", 3.0), ("loss", 0.25)]);
        let evs = snapshot_events();
        assert!(evs.len() >= before + 2);
        // strictly increasing sequence numbers
        for w in evs.windows(2) {
            assert!(w[1].seq > w[0].seq, "seq not increasing");
        }
        let step = evs.iter().rev()
            .find(|e| e.name == "test.flight.step")
            .expect("step event present");
        assert_eq!(step.kind, EventKind::Step);
        assert_eq!(step.fields[0], ("step".to_string(), 3.0));
        assert_eq!(step.fields[1], ("loss".to_string(), 0.25));
    }

    #[test]
    fn ring_keeps_only_the_newest_events() {
        let cap = ring().slots.len();
        for i in 0..(cap + 64) {
            record(EventKind::Mark, "test.flight.flood", &[("i", i as f64)]);
        }
        let evs = snapshot_events();
        assert!(evs.len() <= cap);
        // the newest flood event must have survived
        let max_i = evs.iter()
            .filter(|e| e.name == "test.flight.flood")
            .filter_map(|e| e.fields.first().map(|f| f.1))
            .fold(f64::MIN, f64::max);
        assert_eq!(max_i, (cap + 63) as f64);
    }

    #[test]
    fn concurrent_writers_never_produce_torn_events() {
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..2000 {
                        let v = (t * 10_000 + i) as f64;
                        record(EventKind::Mark, "test.flight.race",
                               &[("x", v), ("y", v), ("z", v)]);
                    }
                })
            })
            .collect();
        // read concurrently with the writers
        for _ in 0..50 {
            for e in snapshot_events() {
                if e.name == "test.flight.race" {
                    // all three fields written atomically per event: a torn
                    // slot would mix values from different events
                    assert_eq!(e.fields[0].1, e.fields[1].1);
                    assert_eq!(e.fields[1].1, e.fields[2].1);
                }
            }
        }
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn snapshot_json_has_schema_events_and_metrics() {
        record(EventKind::Mark, "test.flight.json", &[]);
        let j = snapshot_json();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert!(!j.get("events").unwrap().as_arr().unwrap().is_empty());
        assert!(j.get("metrics").unwrap().get("counters").is_some());
        // render → parse stability (the dump is machine-readable)
        let re = Json::parse(&j.render()).unwrap();
        assert_eq!(re.get("schema").unwrap().as_str().unwrap(), SCHEMA);
    }

    #[test]
    fn counter_snapshot_event_carries_metric_values() {
        metrics::counter("test.flight.counter").add(7);
        record_counters(&["test.flight.counter"]);
        let evs = snapshot_events();
        let ev = evs.iter().rev()
            .find(|e| e.kind == EventKind::Counter)
            .expect("counter event");
        let (k, v) = &ev.fields[0];
        assert_eq!(k, "test.flight.counter");
        assert!(*v >= 7.0);
    }
}
