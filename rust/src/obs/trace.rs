//! Hierarchical trace spans with Chrome trace-event JSON export.
//!
//! A [`Span`] is a scoped RAII timer: created by [`span`]/[`span_with`],
//! it records a complete ("ph":"X") event when dropped.  Each thread keeps
//! its own span stack and event sink, so tracing adds no cross-thread
//! contention on the hot path; nesting is reconstructed by the viewer from
//! time containment per thread (and recorded explicitly as a `depth` arg).
//!
//! Tracing is **disabled by default** and costs one relaxed atomic load
//! per span while disabled — cheap enough to leave instrumentation in
//! kernels permanently.  [`span_with`] takes a closure for its arguments
//! so no argument vector is built unless tracing is on.
//!
//! Typical wiring (what `train_lm` / `serve_decode` / `bench_train` do):
//!
//! ```text
//! DELTANET_TRACE=trace.json cargo run --release --example train_lm
//! ```
//!
//! with `init_from_env()` at startup and `write_trace_from_env()` at exit.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Spans recorded per thread before further events are dropped (a runaway
/// trace caps memory instead of exhausting it; drops are counted).
const MAX_EVENTS_PER_THREAD: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

/// One completed span, ready for export.
struct Event {
    name: &'static str,
    ts_us: f64,
    dur_us: f64,
    tid: u64,
    depth: usize,
    args: Vec<(&'static str, f64)>,
}

/// Per-thread event buffer; registered globally so [`write_trace`] can
/// collect events from every thread that ever recorded a span.
struct ThreadSink {
    tid: u64,
    name: String,
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

impl ThreadSink {
    fn push(&self, ev: Event) {
        let mut evs = self.events.lock().unwrap();
        if evs.len() >= MAX_EVENTS_PER_THREAD {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        evs.push(ev);
    }
}

fn sinks() -> &'static Mutex<Vec<Arc<ThreadSink>>> {
    static SINKS: OnceLock<Mutex<Vec<Arc<ThreadSink>>>> = OnceLock::new();
    SINKS.get_or_init(|| Mutex::new(Vec::new()))
}

fn trace_path() -> &'static Mutex<Option<PathBuf>> {
    static P: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    P.get_or_init(|| Mutex::new(None))
}

struct LocalState {
    sink: Arc<ThreadSink>,
    stack: Vec<&'static str>,
}

thread_local! {
    static LOCAL: RefCell<Option<LocalState>> = const { RefCell::new(None) };
}

fn new_local_state() -> LocalState {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let name = std::thread::current()
        .name()
        .map(|s| s.to_string())
        .unwrap_or_else(|| format!("thread-{tid}"));
    let sink = Arc::new(ThreadSink {
        tid,
        name,
        events: Mutex::new(Vec::new()),
        dropped: AtomicU64::new(0),
    });
    sinks().lock().unwrap().push(sink.clone());
    LocalState { sink, stack: Vec::new() }
}

/// Scoped span guard: records a trace event covering its lifetime.
/// Inert (one atomic load, zero allocation) while tracing is disabled.
pub struct Span {
    name: &'static str,
    start_us: f64,
    args: Vec<(&'static str, f64)>,
    active: bool,
}

/// Spans at this depth or shallower also land in the flight-recorder ring
/// (the coarse run structure, without flooding the ring with per-chunk
/// kernel spans).
const FLIGHT_MAX_DEPTH: usize = 1;

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end_us = now_us();
        let args = std::mem::take(&mut self.args);
        let name = self.name;
        let start_us = self.start_us;
        let mut flight_depth = None;
        // try_with: spans dropped during thread teardown are discarded
        // rather than panicking on destroyed TLS
        let _ = LOCAL.try_with(|cell| {
            let mut borrow = cell.borrow_mut();
            if let Some(st) = borrow.as_mut() {
                st.stack.pop();
                let depth = st.stack.len();
                if depth <= FLIGHT_MAX_DEPTH {
                    flight_depth = Some(depth);
                }
                st.sink.push(Event {
                    name,
                    ts_us: start_us,
                    dur_us: (end_us - start_us).max(0.0),
                    tid: st.sink.tid,
                    depth,
                    args,
                });
            }
        });
        if let Some(depth) = flight_depth {
            super::flight::record(
                super::flight::EventKind::SpanClose,
                name,
                &[("depth", depth as f64),
                  ("dur_ms", (end_us - start_us).max(0.0) / 1e3)],
            );
        }
    }
}

fn begin(name: &'static str, args: Vec<(&'static str, f64)>) -> Span {
    let start_us = now_us();
    let mut depth = usize::MAX;
    let registered = LOCAL
        .try_with(|cell| {
            let mut borrow = cell.borrow_mut();
            let st = borrow.get_or_insert_with(new_local_state);
            st.stack.push(name);
            depth = st.stack.len() - 1;
        })
        .is_ok();
    if registered && depth <= FLIGHT_MAX_DEPTH {
        super::flight::record(
            super::flight::EventKind::SpanOpen,
            name,
            &[("depth", depth as f64)],
        );
    }
    Span { name, start_us, args, active: registered }
}

/// Open a span; it closes (and records) when the guard drops.
///
/// ```ignore
/// let _sp = obs::trace::span("kernel.chunkwise.forward");
/// ```
#[inline]
#[must_use = "the span measures its guard's lifetime; bind it to a variable"]
pub fn span(name: &'static str) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { name, start_us: 0.0, args: Vec::new(), active: false };
    }
    begin(name, Vec::new())
}

/// Like [`span`] with numeric arguments attached to the event.  The
/// closure only runs when tracing is enabled, so argument construction is
/// free on the disabled path.
#[inline]
#[must_use = "the span measures its guard's lifetime; bind it to a variable"]
pub fn span_with<F>(name: &'static str, args: F) -> Span
where
    F: FnOnce() -> Vec<(&'static str, f64)>,
{
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { name, start_us: 0.0, args: Vec::new(), active: false };
    }
    begin(name, args())
}

/// Turn span recording on (idempotent).
pub fn enable() {
    // touch the epoch so timestamps are anchored before the first span
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span recording off; already-buffered events are kept.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Is span recording currently on?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable tracing if `DELTANET_TRACE=<path>` is set, remembering the path
/// for [`write_trace_from_env`].  Returns the path when tracing was
/// enabled.
pub fn init_from_env() -> Option<PathBuf> {
    let raw = std::env::var_os("DELTANET_TRACE")?;
    if raw.is_empty() {
        return None;
    }
    let path = PathBuf::from(raw);
    *trace_path().lock().unwrap() = Some(path.clone());
    enable();
    Some(path)
}

/// Write the buffered trace to the `DELTANET_TRACE` path, if tracing was
/// enabled through [`init_from_env`].  Returns the path written.
pub fn write_trace_from_env() -> crate::Result<Option<PathBuf>> {
    let path = trace_path().lock().unwrap().clone();
    match path {
        Some(p) => {
            write_trace(&p)?;
            Ok(Some(p))
        }
        None => Ok(None),
    }
}

/// Serialize every buffered span (all threads) as Chrome trace-event JSON:
/// `{"traceEvents": [...]}` with complete ("X") events in microseconds,
/// loadable in Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`.
pub fn write_trace(path: &Path) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let json = render_trace();
    std::fs::write(path, json.render() + "\n")?;
    Ok(())
}

fn render_trace() -> Json {
    let sinks: Vec<Arc<ThreadSink>> = sinks().lock().unwrap().clone();
    let mut events: Vec<Json> = Vec::new();
    events.push(Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("process_name")),
        ("pid", Json::num(1.0)),
        ("args", Json::obj(vec![("name", Json::str("deltanet"))])),
    ]));
    for sink in &sinks {
        let dropped = sink.dropped.load(Ordering::Relaxed);
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(sink.tid as f64)),
            ("args", Json::obj(vec![
                ("name", Json::str(sink.name.clone())),
                ("dropped_events", Json::num(dropped as f64)),
            ])),
        ]));
        for ev in sink.events.lock().unwrap().iter() {
            let mut args: Vec<(&str, Json)> =
                vec![("depth", Json::num(ev.depth as f64))];
            for &(k, v) in &ev.args {
                args.push((k, Json::num(v)));
            }
            events.push(Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str(ev.name)),
                ("cat", Json::str("deltanet")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(ev.tid as f64)),
                ("ts", Json::num(ev.ts_us)),
                ("dur", Json::num(ev.dur_us)),
                ("args", Json::obj(args)),
            ]));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // one ordered test: the enable flag is process-global, so the
    // disabled-state assertions must run before anything enables it
    #[test]
    fn span_lifecycle_disabled_then_enabled() {
        if !enabled() {
            // disabled spans must not register a sink for this thread
            let before = sinks().lock().unwrap().len();
            {
                let _a = span("test.noop");
                let _b = span_with("test.noop.args", || vec![("x", 1.0)]);
            }
            assert_eq!(sinks().lock().unwrap().len(), before);
        }
        enable();
        {
            let _outer = span("test.outer");
            std::thread::sleep(std::time::Duration::from_millis(1));
            {
                let _inner =
                    span_with("test.inner", || vec![("k", 42.0)]);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        let json = render_trace();
        let evs =
            json.get("traceEvents").and_then(|e| e.as_arr().ok()).unwrap();
        let find = |n: &str| {
            evs.iter().find(|e| {
                e.get("name").and_then(|x| x.as_str().ok()) == Some(n)
            })
        };
        let outer = find("test.outer").expect("outer span recorded");
        let inner = find("test.inner").expect("inner span recorded");
        let f =
            |e: &Json, k: &str| e.get(k).and_then(|x| x.as_f64().ok()).unwrap();
        // same thread, inner contained in outer, depth one greater
        assert_eq!(f(outer, "tid"), f(inner, "tid"));
        assert!(f(inner, "ts") >= f(outer, "ts"));
        assert!(f(inner, "ts") + f(inner, "dur")
                    <= f(outer, "ts") + f(outer, "dur") + 1.0);
        let depth = |e: &Json| {
            f(e.get("args").unwrap(), "depth")
        };
        assert_eq!(depth(inner), depth(outer) + 1.0);
        assert_eq!(
            f(inner.get("args").unwrap(), "k"), 42.0);
    }
}
