//! Bench regression gate: compare a `BENCH_*.json` report against a
//! committed baseline and flag metrics that moved the wrong way by more
//! than a noise threshold.
//!
//! The benches (`bench_train`, `bench_kernels`, `bench_reference`, ...)
//! all write reports built from the same vocabulary:
//!
//! * a `results` array of `util::bench::BenchResult` objects
//!   (`name` / `median_s` / `p10_s` / `p90_s`) — *lower is better*;
//! * suite-specific top-level scalars (`tokens_per_sec`, `gflops_mean`,
//!   `loss_last`, `span_overhead_frac`, ...) with a known direction;
//! * `bench_kernels`' `primitives` array (`gflops_simd`, `speedup`) —
//!   *higher is better*;
//! * `bench_prefill`'s `speedups` object (parallel speedup per
//!   thread-count config) — *higher is better*.
//!
//! [`extract_metrics`] flattens any such report into named scalars with a
//! direction, [`diff`] joins current against baseline by name and computes
//! relative deltas, and [`DiffReport`] renders both a human table and a
//! machine JSON.  A metric **regresses** when it moves in its bad
//! direction by more than its threshold — timing medians and throughput
//! share a default relative threshold (generous, because CI machines are
//! noisy); loss metrics get a wider one (stochastic trajectories).
//!
//! The `deltanet bench-diff` CLI wraps this: it loads the current report,
//! resolves the baseline (explicit `--baseline PATH` or the committed
//! `rust/benches/baselines/<name>`), prints the report, optionally writes
//! the JSON, and exits non-zero on regression unless `--warn-only`.

use std::path::Path;

use crate::util::json::Json;

/// Default relative noise threshold for timing/throughput metrics.
pub const DEFAULT_THRESHOLD: f64 = 0.25;
/// Wider threshold for loss metrics (stochastic across seeds/machines).
pub const LOSS_THRESHOLD: f64 = 0.60;

/// One comparable scalar pulled out of a bench report.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub higher_is_better: bool,
}

/// Per-metric comparison outcome.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed relative change `(current - baseline) / |baseline|`.
    pub rel_delta: f64,
    pub higher_is_better: bool,
    pub threshold: f64,
    pub regressed: bool,
    pub improved: bool,
}

/// Full comparison of one report against its baseline.
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub suite: String,
    pub metrics: Vec<MetricDelta>,
    /// Metric names present in only one of the two reports.
    pub only_in_current: Vec<String>,
    pub only_in_baseline: Vec<String>,
}

/// Direction + threshold for a known top-level scalar field.
fn scalar_spec(key: &str) -> Option<(bool, f64)> {
    // (higher_is_better, threshold)
    match key {
        "tokens_per_sec" | "gflops_mean" => Some((true, DEFAULT_THRESHOLD)),
        "span_overhead_frac" => Some((false, 1.0)), // tiny + very noisy
        "loss_last" | "loss_first" => Some((false, LOSS_THRESHOLD)),
        _ => None,
    }
}

fn num(v: &Json, key: &str) -> Option<f64> {
    v.get(key).and_then(|x| x.as_f64().ok())
}

/// Flatten a `BENCH_*.json` report into comparable metrics.
pub fn extract_metrics(report: &Json) -> Vec<Metric> {
    let mut out = Vec::new();
    // top-level scalars with a known direction
    if let Json::Obj(map) = report {
        for key in map.keys() {
            if scalar_spec(key).is_some() {
                if let Some(v) = num(report, key) {
                    let (hib, _) = scalar_spec(key).unwrap();
                    out.push(Metric {
                        name: key.clone(),
                        value: v,
                        higher_is_better: hib,
                    });
                }
            }
        }
    }
    // results[]: BenchResult medians (lower is better)
    if let Some(results) = report.get("results").and_then(|r| r.as_arr().ok())
    {
        for r in results {
            let (Some(name), Some(median)) = (
                r.get("name").and_then(|n| n.as_str().ok()),
                num(r, "median_s"),
            ) else {
                continue;
            };
            out.push(Metric {
                name: format!("results.{name}.median_s"),
                value: median,
                higher_is_better: false,
            });
        }
    }
    // speedups{}: parallel speedups keyed by config (higher is better) —
    // bench_prefill's thread-scaling block
    if let Some(Json::Obj(sp)) = report.get("speedups") {
        for (k, v) in sp {
            if let Ok(x) = v.as_f64() {
                out.push(Metric {
                    name: format!("speedups.{k}"),
                    value: x,
                    higher_is_better: true,
                });
            }
        }
    }
    // primitives[]: scalar-vs-SIMD comparison (higher is better)
    if let Some(prims) =
        report.get("primitives").and_then(|p| p.as_arr().ok())
    {
        for p in prims {
            let Some(name) = p.get("name").and_then(|n| n.as_str().ok())
            else {
                continue;
            };
            for field in ["gflops_simd", "speedup"] {
                if let Some(v) = num(p, field) {
                    out.push(Metric {
                        name: format!("primitives.{name}.{field}"),
                        value: v,
                        higher_is_better: true,
                    });
                }
            }
        }
    }
    out
}

fn threshold_for(name: &str, override_thresh: Option<f64>) -> f64 {
    if let Some(t) = override_thresh {
        return t;
    }
    if let Some((_, t)) = scalar_spec(name) {
        return t;
    }
    DEFAULT_THRESHOLD
}

/// Compare current vs baseline reports.  `threshold` overrides every
/// per-metric default when given.
pub fn diff(current: &Json, baseline: &Json, threshold: Option<f64>)
            -> DiffReport {
    let suite = current
        .get("suite")
        .and_then(|s| s.as_str().ok())
        .unwrap_or("unknown")
        .to_string();
    let cur = extract_metrics(current);
    let base = extract_metrics(baseline);

    let mut metrics = Vec::new();
    let mut only_in_current = Vec::new();
    let mut only_in_baseline: Vec<String> =
        base.iter().map(|m| m.name.clone()).collect();

    for c in &cur {
        let Some(b) = base.iter().find(|b| b.name == c.name) else {
            only_in_current.push(c.name.clone());
            continue;
        };
        only_in_baseline.retain(|n| n != &c.name);
        let denom = b.value.abs().max(1e-12);
        let rel = (c.value - b.value) / denom;
        let t = threshold_for(&c.name, threshold);
        // "worse" is lower for higher-is-better metrics and vice versa
        let worse_by = if c.higher_is_better { -rel } else { rel };
        metrics.push(MetricDelta {
            name: c.name.clone(),
            baseline: b.value,
            current: c.value,
            rel_delta: rel,
            higher_is_better: c.higher_is_better,
            threshold: t,
            regressed: worse_by > t,
            improved: worse_by < -t,
        });
    }
    DiffReport { suite, metrics, only_in_current, only_in_baseline }
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.metrics.iter().filter(|m| m.regressed).count()
    }

    /// Human-readable table, one metric per line.
    pub fn render_text(&self) -> String {
        let mut out = format!(
            "bench-diff suite={} ({} metrics, {} regressed)\n",
            self.suite, self.metrics.len(), self.regressions());
        for m in &self.metrics {
            let dir = if m.higher_is_better { "↑" } else { "↓" };
            let flag = if m.regressed {
                "REGRESSED"
            } else if m.improved {
                "improved"
            } else {
                "ok"
            };
            out.push_str(&format!(
                "  {flag:<9} {:<44} {dir} base {:>12.4} -> {:>12.4} \
                 ({:+.1}%, threshold {:.0}%)\n",
                m.name, m.baseline, m.current, m.rel_delta * 100.0,
                m.threshold * 100.0));
        }
        for n in &self.only_in_current {
            out.push_str(&format!("  new       {n} (not in baseline)\n"));
        }
        for n in &self.only_in_baseline {
            out.push_str(&format!("  missing   {n} (baseline only)\n"));
        }
        out
    }

    /// Machine JSON (`--json PATH` payload).
    pub fn to_json(&self) -> Json {
        let metrics = self.metrics.iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::str(m.name.clone())),
                    ("baseline", Json::num(m.baseline)),
                    ("current", Json::num(m.current)),
                    ("rel_delta", Json::num(m.rel_delta)),
                    ("higher_is_better", Json::Bool(m.higher_is_better)),
                    ("threshold", Json::num(m.threshold)),
                    ("regressed", Json::Bool(m.regressed)),
                    ("improved", Json::Bool(m.improved)),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("schema", Json::str("deltanet.bench_diff.v1")),
            ("suite", Json::str(self.suite.clone())),
            ("regressions", Json::num(self.regressions() as f64)),
            ("metrics", Json::Arr(metrics)),
            ("only_in_current",
             Json::Arr(self.only_in_current.iter()
                 .map(|s| Json::str(s.clone())).collect())),
            ("only_in_baseline",
             Json::Arr(self.only_in_baseline.iter()
                 .map(|s| Json::str(s.clone())).collect())),
        ])
    }
}

/// Load a JSON report from disk.
pub fn load_report(path: &Path) -> crate::Result<Json> {
    use crate::util::error::Context;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench report {}",
                                 path.display()))?;
    Json::parse(&text)
        .with_context(|| format!("{} is not valid JSON", path.display()))
}

/// The committed baseline for a report file name
/// (`rust/benches/baselines/<file_name>` under the repo root).
pub fn default_baseline_path(current: &Path) -> crate::Result<
    std::path::PathBuf,
> {
    use crate::util::error::Context;
    let file = current.file_name()
        .context("bench report path has no file name")?;
    Ok(crate::util::bench::repo_root()
        .join("rust/benches/baselines")
        .join(file))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn train_report(tokens_per_sec: f64, median_s: f64) -> Json {
        Json::parse(&format!(
            r#"{{"suite":"train","steps":20,"loss_first":3.0,
                 "loss_last":1.5,"tokens_per_sec":{tokens_per_sec},
                 "gflops_mean":2.0,"simd_level":"avx2",
                 "losses":[3.0,1.5],
                 "results":[{{"name":"host_train_step_tiny_mqar",
                              "reps":20,"median_s":{median_s},
                              "p10_s":{median_s},"p90_s":{median_s}}}]}}"#
        )).unwrap()
    }

    #[test]
    fn extracts_scalars_results_and_directions() {
        let m = extract_metrics(&train_report(1000.0, 0.05));
        let find = |n: &str| m.iter().find(|x| x.name == n).unwrap();
        assert!(find("tokens_per_sec").higher_is_better);
        assert!(!find("loss_last").higher_is_better);
        let med = find("results.host_train_step_tiny_mqar.median_s");
        assert!(!med.higher_is_better);
        assert_eq!(med.value, 0.05);
        // loss trajectory array is not a metric
        assert!(m.iter().all(|x| x.name != "losses"));
    }

    #[test]
    fn identical_reports_have_no_regressions() {
        let r = train_report(1000.0, 0.05);
        let d = diff(&r, &r, None);
        assert_eq!(d.regressions(), 0);
        assert!(d.only_in_current.is_empty());
        assert!(d.only_in_baseline.is_empty());
        assert!(d.metrics.iter().all(|m| m.rel_delta.abs() < 1e-12));
    }

    #[test]
    fn two_x_throughput_drop_regresses_and_improvement_does_not() {
        // baseline claims 2x the current throughput → regression
        let current = train_report(1000.0, 0.10);
        let baseline = train_report(2000.0, 0.05);
        let d = diff(&current, &baseline, None);
        assert!(d.regressions() >= 2, "{}", d.render_text());
        let tps = d.metrics.iter()
            .find(|m| m.name == "tokens_per_sec").unwrap();
        assert!(tps.regressed && !tps.improved);
        assert!((tps.rel_delta + 0.5).abs() < 1e-9); // −50%

        // the mirror image is an improvement, not a regression
        let d2 = diff(&baseline, &current, None);
        assert_eq!(d2.regressions(), 0, "{}", d2.render_text());
        assert!(d2.metrics.iter()
            .find(|m| m.name == "tokens_per_sec").unwrap().improved);
    }

    #[test]
    fn noise_within_threshold_passes() {
        // 10% slower is inside the default 25% noise band
        let d = diff(&train_report(900.0, 0.055),
                     &train_report(1000.0, 0.05), None);
        assert_eq!(d.regressions(), 0, "{}", d.render_text());
        // but a tightened explicit threshold flags it
        let d = diff(&train_report(900.0, 0.055),
                     &train_report(1000.0, 0.05), Some(0.05));
        assert!(d.regressions() >= 2);
    }

    #[test]
    fn kernels_primitives_compare_higher_is_better() {
        let mk = |gflops: f64| Json::parse(&format!(
            r#"{{"suite":"kernels","primitives":[
                 {{"name":"matmul_into_64","flops_per_call":1e6,
                   "gflops_scalar":1.0,"gflops_simd":{gflops},
                   "speedup":{gflops}}}],"results":[]}}"#)).unwrap();
        let d = diff(&mk(2.0), &mk(8.0), None);
        assert_eq!(d.regressions(), 2, "{}", d.render_text());
        let d = diff(&mk(8.0), &mk(2.0), None);
        assert_eq!(d.regressions(), 0);
    }

    #[test]
    fn prefill_speedups_compare_higher_is_better() {
        let mk = |s: f64| Json::parse(&format!(
            r#"{{"suite":"prefill","tokens_per_sec":1000.0,
                 "speedups":{{"prefill_h4_l2048_t8":{s},
                              "prefill_h4_l2048_t1":1.0}},
                 "results":[]}}"#)).unwrap();
        let m = extract_metrics(&mk(3.0));
        let sp = m.iter()
            .find(|x| x.name == "speedups.prefill_h4_l2048_t8").unwrap();
        assert!(sp.higher_is_better);
        assert_eq!(sp.value, 3.0);
        // losing the parallel speedup is a regression...
        let d = diff(&mk(1.0), &mk(3.0), None);
        assert_eq!(d.regressions(), 1, "{}", d.render_text());
        // ...gaining it is not
        let d = diff(&mk(3.0), &mk(1.0), None);
        assert_eq!(d.regressions(), 0, "{}", d.render_text());
    }

    #[test]
    fn schema_drift_reported_not_regressed() {
        let current = train_report(1000.0, 0.05);
        let mut baseline = train_report(1000.0, 0.05);
        if let Json::Obj(m) = &mut baseline {
            m.remove("gflops_mean");
            m.insert("old_metric_gone".into(), Json::num(1.0));
        }
        let d = diff(&current, &baseline, None);
        assert_eq!(d.regressions(), 0);
        assert_eq!(d.only_in_current, vec!["gflops_mean".to_string()]);
    }

    #[test]
    fn report_renders_text_and_json() {
        let d = diff(&train_report(1000.0, 0.10),
                     &train_report(2000.0, 0.05), None);
        let text = d.render_text();
        assert!(text.contains("REGRESSED"));
        assert!(text.contains("tokens_per_sec"));
        let j = Json::parse(&d.to_json().render()).unwrap();
        assert_eq!(j.get("suite").unwrap().as_str().unwrap(), "train");
        assert!(j.get("regressions").unwrap().as_f64().unwrap() >= 2.0);
    }
}
