//! Global metrics registry: atomic counters, gauges, and log-linear
//! latency histograms addressable by static name.
//!
//! Handles are interned once and live for the process
//! (`&'static Counter`), so hot paths cache them in a `OnceLock` and pay
//! only a relaxed atomic op per update:
//!
//! ```ignore
//! fn steps() -> &'static Counter {
//!     static C: OnceLock<&'static Counter> = OnceLock::new();
//!     *C.get_or_init(|| counter("train.steps"))
//! }
//! steps().inc();
//! ```
//!
//! Histograms use HdrHistogram-style log-linear buckets over integer
//! microseconds: exact below 16 µs, then 16 sub-buckets per power of two
//! (≤ ~6.25% relative quantile error), covering the full `u64` range in
//! 976 fixed buckets with no allocation on record.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};
use std::collections::BTreeMap;

use crate::util::json::Json;

/// Monotonically increasing event count.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Self {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depth, live workers, ...).
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub const fn new() -> Self {
        Gauge { v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.v.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn set(&self, value: i64) {
        self.v.store(value, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// 16 linear sub-buckets per power of two above 2^SUB_BITS.
const SUB_BITS: u32 = 4;
const SUB: u32 = 1 << SUB_BITS; // 16
/// 16 exact + 16 per octave for octaves 4..=63.
const N_BUCKETS: usize = (SUB + (64 - SUB_BITS) * SUB) as usize; // 976

/// Lock-free latency histogram over integer microseconds.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// Point-in-time quantile summary of one [`Histogram`].
#[derive(Debug, Clone, Copy, Default)]
pub struct HistStats {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

fn bucket_index(us: u64) -> usize {
    if us < SUB as u64 {
        return us as usize;
    }
    let msb = 63 - us.leading_zeros(); // >= SUB_BITS
    let octave = (msb - SUB_BITS) as u64;
    let sub = (us >> (msb - SUB_BITS)) & (SUB as u64 - 1);
    (SUB as u64 + octave * SUB as u64 + sub) as usize
}

/// Inclusive upper edge of a bucket — the value reported for quantiles
/// falling in it (over-estimate bounded by the bucket width).
fn bucket_upper_us(idx: usize) -> u64 {
    if idx < SUB as usize {
        return idx as u64;
    }
    let octave = ((idx - SUB as usize) / SUB as usize) as u32;
    let sub = ((idx - SUB as usize) % SUB as usize) as u64;
    let msb = octave + SUB_BITS;
    let lower = (1u64 << msb) + (sub << (msb - SUB_BITS));
    lower + ((1u64 << (msb - SUB_BITS)) - 1)
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one observation in milliseconds (negative / non-finite
    /// values clamp to zero).
    pub fn record(&self, ms: f64) {
        let us = if ms.is_finite() && ms > 0.0 {
            (ms * 1e3).round() as u64 // saturating float→int cast
        } else {
            0
        };
        self.record_us(us);
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }

    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Quantile in milliseconds, `q` in [0, 1]; 0 when empty.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                let us =
                    bucket_upper_us(i).min(self.max_us.load(Ordering::Relaxed));
                return us as f64 / 1e3;
            }
        }
        self.max_ms()
    }

    pub fn stats(&self) -> HistStats {
        HistStats {
            count: self.count(),
            mean_ms: self.mean_ms(),
            p50_ms: self.quantile_ms(0.50),
            p95_ms: self.quantile_ms(0.95),
            p99_ms: self.quantile_ms(0.99),
            max_ms: self.max_ms(),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Default)]
struct Registry {
    counters: RwLock<BTreeMap<&'static str, &'static Counter>>,
    gauges: RwLock<BTreeMap<&'static str, &'static Gauge>>,
    histograms: RwLock<BTreeMap<&'static str, &'static Histogram>>,
}

fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

fn intern<T: Default>(
    map: &RwLock<BTreeMap<&'static str, &'static T>>,
    name: &'static str,
) -> &'static T {
    if let Some(&found) = map.read().unwrap().get(name) {
        return found;
    }
    let mut w = map.write().unwrap();
    let slot = w.entry(name).or_insert_with(|| {
        let leaked: &'static T = Box::leak(Box::new(T::default()));
        leaked
    });
    *slot
}

/// Interned counter for `name` (created on first use, lives forever).
pub fn counter(name: &'static str) -> &'static Counter {
    intern(&registry().counters, name)
}

/// Interned gauge for `name`.
pub fn gauge(name: &'static str) -> &'static Gauge {
    intern(&registry().gauges, name)
}

/// Interned latency histogram for `name`.
pub fn histogram(name: &'static str) -> &'static Histogram {
    intern(&registry().histograms, name)
}

/// Point-in-time copy of every registered metric.
pub struct MetricsSnapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, i64)>,
    pub histograms: Vec<(&'static str, HistStats)>,
}

/// Snapshot the whole registry (sorted by name — BTreeMap order).
pub fn snapshot() -> MetricsSnapshot {
    let r = registry();
    MetricsSnapshot {
        counters: r.counters.read().unwrap().iter()
            .map(|(&n, c)| (n, c.get())).collect(),
        gauges: r.gauges.read().unwrap().iter()
            .map(|(&n, g)| (n, g.get())).collect(),
        histograms: r.histograms.read().unwrap().iter()
            .map(|(&n, h)| (n, h.stats())).collect(),
    }
}

impl MetricsSnapshot {
    /// One metric per line — the `/metrics` text payload.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("counter {name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("gauge {name} {v}\n"));
        }
        for (name, h) in &self.histograms {
            out.push_str(&format!(
                "hist {name} count {} mean_ms {:.3} p50_ms {:.3} \
                 p95_ms {:.3} p99_ms {:.3} max_ms {:.3}\n",
                h.count, h.mean_ms, h.p50_ms, h.p95_ms, h.p99_ms, h.max_ms));
        }
        out
    }

    /// The `/metrics.json` payload.
    pub fn to_json(&self) -> Json {
        let counters = self.counters.iter()
            .map(|&(n, v)| (n, Json::num(v as f64)))
            .collect::<Vec<_>>();
        let gauges = self.gauges.iter()
            .map(|&(n, v)| (n, Json::num(v as f64)))
            .collect::<Vec<_>>();
        let hists = self.histograms.iter()
            .map(|&(n, h)| {
                (n, Json::obj(vec![
                    ("count", Json::num(h.count as f64)),
                    ("mean_ms", Json::num(h.mean_ms)),
                    ("p50_ms", Json::num(h.p50_ms)),
                    ("p95_ms", Json::num(h.p95_ms)),
                    ("p99_ms", Json::num(h.p99_ms)),
                    ("max_ms", Json::num(h.max_ms)),
                ]))
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = counter("test.metrics.counter");
        let before = c.get();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), before + 5);
        // same name → same interned handle
        assert!(std::ptr::eq(c, counter("test.metrics.counter")));

        let g = gauge("test.metrics.gauge");
        g.set(3);
        g.add(-5);
        assert_eq!(g.get(), -2);
    }

    #[test]
    fn bucket_index_is_monotone_and_bounded() {
        let mut prev = 0usize;
        for us in [0u64, 1, 15, 16, 17, 31, 32, 100, 1000, 123_456,
                   u64::MAX / 2, u64::MAX] {
            let i = bucket_index(us);
            assert!(i < N_BUCKETS, "index {i} out of range for {us}");
            assert!(i >= prev, "index not monotone at {us}");
            // the bucket's upper edge must not under-report the value
            // by more than one sub-bucket width
            assert!(bucket_upper_us(i) >= us,
                    "upper edge {} < value {us}", bucket_upper_us(i));
            prev = i;
        }
    }

    #[test]
    fn bucket_edges_cover_every_power_of_two_boundary() {
        // around each power of two the index must stay monotone, the
        // bucket's upper edge must never under-report the value, and the
        // over-estimate must stay within one sub-bucket (2^-SUB_BITS of
        // the value, i.e. the documented ≤6.25% relative error)
        for msb in SUB_BITS..64 {
            let p = 1u64 << msb;
            for v in [p - 1, p, p + 1, p + p / 2, p.saturating_add(p - 1)] {
                let i = bucket_index(v);
                assert!(i < N_BUCKETS, "index {i} out of range at {v}");
                let upper = bucket_upper_us(i);
                assert!(upper >= v,
                        "upper edge {upper} < value {v} (msb {msb})");
                // upper - v < one sub-bucket width = 2^(msb-SUB_BITS)
                let width = 1u64 << (v.ilog2().max(SUB_BITS) - SUB_BITS);
                assert!(upper - v < width,
                        "over-estimate {} ≥ sub-bucket width {width} at {v}",
                        upper - v);
                // adjacent boundary values map to non-decreasing indices
                assert!(bucket_index(v.saturating_add(1)) >= i);
            }
        }
        // exact region: values below SUB are their own bucket
        for v in 0..SUB as u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_upper_us(v as usize), v);
        }
    }

    #[test]
    fn quantile_over_estimate_is_within_six_point_25_percent() {
        // p50 lands in the lower value's bucket and reports its upper
        // edge; the much larger second value keeps max_us from masking
        // the edge, so this pins the documented ≤6.25% over-estimate
        for us in [17u64, 31, 100, 1000, 4097, 65_535, 1_000_000] {
            let h = Histogram::new();
            h.record_us(us);
            h.record_us(us * 1000);
            let got_us = h.quantile_ms(0.50) * 1e3;
            let rel = (got_us - us as f64) / us as f64;
            assert!(rel >= -1e-6, "quantile under-reports at {us}");
            assert!(rel <= 0.0625, "over-estimate {rel} > 6.25% at {us}");
        }
    }

    #[test]
    fn histogram_quantiles_bound_relative_error() {
        let h = Histogram::new();
        for ms in 1..=1000u64 {
            h.record(ms as f64);
        }
        assert_eq!(h.count(), 1000);
        for (q, want_ms) in [(0.50, 500.0), (0.95, 950.0), (0.99, 990.0)] {
            let got = h.quantile_ms(q);
            let rel = (got - want_ms).abs() / want_ms;
            assert!(rel < 0.07, "p{q}: got {got} want ~{want_ms}");
            assert!(got >= want_ms * 0.999,
                    "quantile must not under-report: {got} < {want_ms}");
        }
        assert!((h.max_ms() - 1000.0).abs() < 1e-9);
        assert!((h.mean_ms() - 500.5).abs() < 0.01);
    }

    #[test]
    fn snapshot_renders_all_kinds() {
        counter("test.snap.counter").inc();
        gauge("test.snap.gauge").set(7);
        histogram("test.snap.hist").record(2.5);
        let s = snapshot();
        let text = s.render_text();
        assert!(text.contains("counter test.snap.counter"));
        assert!(text.contains("gauge test.snap.gauge 7"));
        assert!(text.contains("hist test.snap.hist"));
        assert!(text.contains("p95_ms"));
        let j = s.to_json();
        let parsed = Json::parse(&j.render()).unwrap();
        assert!(parsed.get("histograms").unwrap()
            .get("test.snap.hist").unwrap()
            .get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
