//! Minimal host-side f32 matrix library.
//!
//! Used by the pure-Rust reference implementation of the paper's algorithm
//! (`crate::reference`), the synthetic data generators, and the evaluation
//! harnesses.  Row-major, no broadcasting magic — just the operations the
//! DeltaNet algebra needs, written to be obviously correct.  The
//! throughput-oriented counterparts (tiled/accumulating matmuls, causal
//! triangle products) live in [`blocked`] and back `crate::kernels`.

pub mod blocked;
pub mod rng;
pub mod simd;

use crate::bail;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Borrowed row-major matrix view — a (rows, cols) window over someone
/// else's storage.  The blocked primitives accept `impl Into<MatRef>` so
/// the chunkwise hot loop can hand them row windows of the full-sequence
/// tensors (`Mat::rows_window`) without `slice_rows`-style copies; a
/// `&Mat` converts implicitly, so existing call sites are unchanged.
#[derive(Debug, Clone, Copy)]
pub struct MatRef<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> MatRef<'a> {
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

impl<'a> From<&'a Mat> for MatRef<'a> {
    fn from(m: &'a Mat) -> MatRef<'a> {
        MatRef { rows: m.rows, cols: m.cols, data: &m.data }
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> crate::Result<Self> {
        if rows.is_empty() {
            bail!("empty matrix");
        }
        let cols = rows[0].len();
        if rows.iter().any(|r| r.len() != cols) {
            bail!("ragged rows");
        }
        Ok(Mat {
            rows: rows.len(),
            cols,
            data: rows.into_iter().flatten().collect(),
        })
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> crate::Result<Self> {
        if data.len() != rows * cols {
            bail!("{}x{} wants {} elems, got {}", rows, cols, rows * cols,
                  data.len());
        }
        Ok(Mat { rows, cols, data })
    }

    pub fn random(rows: usize, cols: usize, rng: &mut rng::Rng, std: f32) -> Self {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal() * std).collect(),
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrowed view of the whole matrix.
    pub fn view(&self) -> MatRef<'_> {
        MatRef { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Borrowed view of rows `start..start + n` (no copy — the chunkwise
    /// kernels' replacement for `slice_rows`).
    pub fn rows_window(&self, start: usize, n: usize) -> MatRef<'_> {
        MatRef {
            rows: n,
            cols: self.cols,
            data: &self.data[start * self.cols..(start + n) * self.cols],
        }
    }

    /// Reshape to `rows × cols` and zero the contents WITHOUT releasing
    /// the backing allocation — the workspace-reuse primitive: once the
    /// buffer has grown to its steady-state size, `reset` never touches
    /// the allocator again.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// self @ other
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: streams through `other` rows, autovectorizes well
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    /// Keep entries with col ≤ row + diag, zero the rest (jnp.tril).
    pub fn tril(&self, diag: i64) -> Mat {
        let mut out = self.clone();
        for i in 0..self.rows {
            for j in 0..self.cols {
                if (j as i64) > (i as i64) + diag {
                    out.data[i * self.cols + j] = 0.0;
                }
            }
        }
        out
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn allclose(&self, other: &Mat, atol: f32, rtol: f32) -> bool {
        self.rows == other.rows && self.cols == other.cols
            && self.data.iter().zip(&other.data).all(|(a, b)| {
                (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
            })
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// v ⋅ w
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// a ← a + s·b
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += s * y;
    }
}

/// L2-normalize in place; returns the original norm.
pub fn l2_normalize(v: &mut [f32]) -> f32 {
    let n = dot(v, v).sqrt();
    if n > 1e-12 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
    n
}

/// Numerically-stable softmax in place.
pub fn softmax(v: &mut [f32]) {
    let m = v.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for x in v.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut r = rng::Rng::new(1);
        let a = Mat::random(4, 4, &mut r, 1.0);
        assert!(a.matmul(&Mat::eye(4)).allclose(&a, 1e-6, 1e-6));
        assert!(Mat::eye(4).matmul(&a).allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]).unwrap();
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut r = rng::Rng::new(2);
        let a = Mat::random(3, 5, &mut r, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn tril_masks_upper() {
        let a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]).unwrap();
        let t = a.tril(0);
        assert_eq!(t.data, vec![1.0, 0.0, 0.0, 4.0, 5.0, 0.0, 7.0, 8.0, 9.0]);
        let t1 = a.tril(-1);
        assert_eq!(t1.data, vec![0.0, 0.0, 0.0, 4.0, 0.0, 0.0, 7.0, 8.0, 0.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        softmax(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn l2_normalize_unit() {
        let mut v = vec![3.0, 4.0];
        let n = l2_normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((dot(&v, &v) - 1.0).abs() < 1e-6);
    }
}
