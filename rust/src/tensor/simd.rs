//! Runtime-dispatched SIMD microkernels for the f32 primitives the
//! blocked tensor layer (and through it the chunkwise DeltaNet kernels)
//! spends its time in: `dot`, `axpy`, a fused four-source `axpy4`, a
//! register-tiled 4×16 matmul microkernel with a packed B panel, and a
//! 2×4 dot-product microkernel for A·Bᵀ.
//!
//! Dispatch is decided ONCE per process (`level()`), from two inputs:
//!
//! * `DELTANET_SIMD=off|0|scalar` forces the portable scalar fallback —
//!   the debugging escape hatch, also exercised by CI so the portable
//!   path stays green;
//! * otherwise `is_x86_feature_detected!` picks AVX2+FMA when the CPU has
//!   both, scalar everywhere else (non-x86_64 builds compile only the
//!   scalar path; there is no `unsafe` outside this module's `avx2`
//!   submodule).
//!
//! The scalar fallbacks are the pre-existing loops from `tensor`/
//! `tensor::blocked`, kept as the semantic reference: `tests/simd_equiv.rs`
//! pins every SIMD kernel to its fallback across odd sizes and unaligned
//! tails, and the AVX2 kernels use FMA so results may differ from scalar
//! by normal f32 rounding (well inside the 1e-4 tolerances every kernel
//! test uses).

use std::sync::atomic::{AtomicU8, Ordering};

/// Which microkernel set the process dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar loops (autovectorized at best).
    Scalar,
    /// AVX2 + FMA `std::arch` kernels (x86_64 only).
    Avx2,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
        }
    }
}

/// 0 = undecided, 1 = scalar, 2 = avx2.
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn level_code(l: Level) -> u8 {
    match l {
        Level::Scalar => 1,
        Level::Avx2 => 2,
    }
}

/// What dispatch WOULD pick right now: the `DELTANET_SIMD` override, else
/// CPU feature detection.  Does not consult or touch the cached decision —
/// benches use it to recover the hardware level after forcing scalar.
pub fn detect_level() -> Level {
    if matches!(
        std::env::var("DELTANET_SIMD").ok().as_deref(),
        Some("off") | Some("0") | Some("scalar")
    ) {
        return Level::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        {
            return Level::Avx2;
        }
    }
    Level::Scalar
}

/// The process-wide dispatch decision, resolved on first use.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        1 => Level::Scalar,
        2 => Level::Avx2,
        _ => {
            let l = detect_level();
            LEVEL.store(level_code(l), Ordering::Relaxed);
            l
        }
    }
}

/// Override the dispatch decision (benches compare scalar vs SIMD legs
/// in one process; single-threaded callers only — a concurrent kernel
/// call may observe either level, both of which are correct).
pub fn force_level(l: Level) {
    LEVEL.store(level_code(l), Ordering::Relaxed);
}

// ---------------------------------------------------------------- dot --

/// v ⋅ w, SIMD-dispatched.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        // SAFETY: Level::Avx2 is only selected when avx2+fma are detected
        return unsafe { avx2::dot(a, b) };
    }
    crate::tensor::dot(a, b)
}

// --------------------------------------------------------------- axpy --

/// a ← a + s·b, SIMD-dispatched.
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        // SAFETY: Level::Avx2 is only selected when avx2+fma are detected
        unsafe { avx2::axpy(a, s, b) };
        return;
    }
    crate::tensor::axpy(a, s, b)
}

/// out ← out + s[0]·b[0] + s[1]·b[1] + s[2]·b[2] + s[3]·b[3] in one pass:
/// the destination row is loaded and stored once instead of four times
/// (the inner step of Aᵀ·B accumulation over four source rows).
pub fn axpy4(out: &mut [f32], s: [f32; 4], b: [&[f32]; 4]) {
    for r in b {
        debug_assert_eq!(out.len(), r.len());
    }
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        // SAFETY: Level::Avx2 is only selected when avx2+fma are detected
        unsafe { avx2::axpy4(out, s, b) };
        return;
    }
    // element-wise accumulation order matches the vector kernel
    for (i, o) in out.iter_mut().enumerate() {
        *o += s[0] * b[0][i] + s[1] * b[1][i] + s[2] * b[2][i]
            + s[3] * b[3][i];
    }
}

// ------------------------------------------------------------- matmul --

/// out += A·B over row-major slices: `a: [m,kd]`, `b: [kd,n]`,
/// `out: [m,n]`.  AVX2 path: depth-tiled packed B panels driven through a
/// 4×16 register-tiled microkernel; scalar path: the i/k-tiled axpy
/// formulation.
pub fn matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize,
                  kd: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), kd * n);
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        avx2::matmul_acc(out, a, b, m, kd, n);
        return;
    }
    scalar_matmul_acc(out, a, b, m, kd, n);
}

/// out += A·Bᵀ over row-major slices: `a: [m,kd]`, `b: [n,kd]`,
/// `out: [m,n]`.  Both paths are depth-tiled so long k extents stream
/// through cache-sized slabs; the AVX2 path computes 2×4 output tiles so
/// each loaded B row is reused across A rows (and vice versa).
pub fn matmul_nt_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize,
                     kd: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), n * kd);
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        avx2::matmul_nt_acc(out, a, b, m, kd, n);
        return;
    }
    scalar_matmul_nt_acc(out, a, b, m, kd, n);
}

/// Row tile of the scalar fallbacks (matches the historical
/// `tensor::blocked` tiling).
const TILE_I: usize = 32;
/// Depth tile: one slab of the k extent per pass.
const TILE_K: usize = 256;

fn scalar_matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize,
                     kd: usize, n: usize) {
    for ib in (0..m).step_by(TILE_I) {
        let ie = (ib + TILE_I).min(m);
        for kb in (0..kd).step_by(TILE_K) {
            let ke = (kb + TILE_K).min(kd);
            for i in ib..ie {
                let arow = &a[i * kd..(i + 1) * kd];
                let orow = &mut out[i * n..(i + 1) * n];
                for k in kb..ke {
                    let av = arow[k];
                    if av != 0.0 {
                        crate::tensor::axpy(orow, av, &b[k * n..(k + 1) * n]);
                    }
                }
            }
        }
    }
}

fn scalar_matmul_nt_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize,
                        kd: usize, n: usize) {
    // depth tiling keeps the streamed B rows inside a cache-sized k slab
    // (the fix for the historically untiled A·Bᵀ)
    for kb in (0..kd).step_by(TILE_K) {
        let ke = (kb + TILE_K).min(kd);
        for ib in (0..m).step_by(TILE_I) {
            let ie = (ib + TILE_I).min(m);
            for i in ib..ie {
                let arow = &a[i * kd + kb..i * kd + ke];
                let orow = &mut out[i * n..(i + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o += crate::tensor::dot(arow, &b[j * kd + kb..j * kd + ke]);
                }
            }
        }
    }
}

// ------------------------------------------------------- AVX2 kernels --

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;
    use std::cell::RefCell;

    /// Microkernel output-tile width (two 8-lane registers).
    const NR: usize = 16;
    /// Microkernel output-tile height.
    const MR: usize = 4;
    /// Depth slab per packed panel.
    const TILE_K: usize = 256;
    /// Row tile of the NT driver (B rows stay hot across it).
    const TILE_I: usize = 32;

    /// Reusable packed-panel buffer, one per thread: steady-state matmuls
    /// never touch the allocator.
    fn with_panel<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
        thread_local! {
            static PANEL: RefCell<Vec<f32>> = RefCell::new(Vec::new());
        }
        PANEL.with(|p| f(&mut p.borrow_mut()))
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)),
                                   _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i + 8)),
                                   _mm256_loadu_ps(pb.add(i + 8)), acc1);
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)),
                                   _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
        let n = a.len();
        let sv = _mm256_set1_ps(s);
        let (pa, pb) = (a.as_mut_ptr(), b.as_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(pa.add(i));
            let bv = _mm256_loadu_ps(pb.add(i));
            _mm256_storeu_ps(pa.add(i), _mm256_fmadd_ps(sv, bv, av));
            i += 8;
        }
        while i < n {
            a[i] += s * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy4(out: &mut [f32], s: [f32; 4],
                               b: [&[f32]; 4]) {
        let n = out.len();
        let po = out.as_mut_ptr();
        let sv = [_mm256_set1_ps(s[0]), _mm256_set1_ps(s[1]),
                  _mm256_set1_ps(s[2]), _mm256_set1_ps(s[3])];
        let mut i = 0;
        while i + 8 <= n {
            let mut o = _mm256_loadu_ps(po.add(i));
            o = _mm256_fmadd_ps(sv[0], _mm256_loadu_ps(b[0].as_ptr().add(i)), o);
            o = _mm256_fmadd_ps(sv[1], _mm256_loadu_ps(b[1].as_ptr().add(i)), o);
            o = _mm256_fmadd_ps(sv[2], _mm256_loadu_ps(b[2].as_ptr().add(i)), o);
            o = _mm256_fmadd_ps(sv[3], _mm256_loadu_ps(b[3].as_ptr().add(i)), o);
            _mm256_storeu_ps(po.add(i), o);
            i += 8;
        }
        while i < n {
            out[i] += s[0] * b[0][i] + s[1] * b[1][i] + s[2] * b[2][i]
                + s[3] * b[3][i];
            i += 1;
        }
    }

    /// out += A·B: pack B column panels, drive the 4×16 microkernel.
    pub(super) fn matmul_acc(out: &mut [f32], a: &[f32], b: &[f32],
                             m: usize, kd: usize, n: usize) {
        with_panel(|panel| {
            let mut kb = 0;
            while kb < kd {
                let ke = (kb + TILE_K).min(kd);
                let mut j0 = 0;
                while j0 < n {
                    let jw = NR.min(n - j0);
                    pack_panel(panel, b, n, kb, ke, j0, jw);
                    let mut i0 = 0;
                    while i0 < m {
                        let rows = MR.min(m - i0);
                        // SAFETY: caller checked avx2+fma; indices bounded
                        unsafe {
                            mm_tile_4x16(out, n, i0, j0, rows, jw, a, kd,
                                         kb, ke, panel);
                        }
                        i0 += MR;
                    }
                    j0 += NR;
                }
                kb = ke;
            }
        })
    }

    /// Pack `b[kb..ke, j0..j0+jw]` into a contiguous `(ke−kb)×NR` panel,
    /// zero-padded to NR columns so the microkernel always loads full
    /// registers.
    fn pack_panel(panel: &mut Vec<f32>, b: &[f32], n: usize, kb: usize,
                  ke: usize, j0: usize, jw: usize) {
        panel.clear();
        panel.resize((ke - kb) * NR, 0.0);
        for (kk, k) in (kb..ke).enumerate() {
            panel[kk * NR..kk * NR + jw]
                .copy_from_slice(&b[k * n + j0..k * n + j0 + jw]);
        }
    }

    /// One 4×16 output tile: 8 accumulator registers over the packed
    /// panel's k slab.  For edge tiles with fewer than 4 rows the last
    /// valid A row is duplicated (reads stay in bounds) and the
    /// write-back skips the duplicates.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn mm_tile_4x16(out: &mut [f32], ldo: usize, i0: usize,
                           j0: usize, rows: usize, jw: usize, a: &[f32],
                           lda: usize, kb: usize, ke: usize,
                           panel: &[f32]) {
        let ridx = |r: usize| i0 + r.min(rows - 1);
        let pp = panel.as_ptr();
        let mut acc = [_mm256_setzero_ps(); 2 * MR];
        for kk in 0..(ke - kb) {
            let p0 = _mm256_loadu_ps(pp.add(kk * NR));
            let p1 = _mm256_loadu_ps(pp.add(kk * NR + 8));
            let k = kb + kk;
            for r in 0..MR {
                let av = _mm256_set1_ps(a[ridx(r) * lda + k]);
                acc[2 * r] = _mm256_fmadd_ps(av, p0, acc[2 * r]);
                acc[2 * r + 1] = _mm256_fmadd_ps(av, p1, acc[2 * r + 1]);
            }
        }
        let mut buf = [0f32; NR];
        for r in 0..rows {
            _mm256_storeu_ps(buf.as_mut_ptr(), acc[2 * r]);
            _mm256_storeu_ps(buf.as_mut_ptr().add(8), acc[2 * r + 1]);
            let o0 = (i0 + r) * ldo + j0;
            for (o, &x) in out[o0..o0 + jw].iter_mut().zip(&buf[..jw]) {
                *o += x;
            }
        }
    }

    /// out += A·Bᵀ: depth-tiled 2×4 dot-product tiles — each loaded B
    /// vector feeds 2 FMAs, each A vector 4, instead of one dot per
    /// (i, j) streaming the full k extent.
    pub(super) fn matmul_nt_acc(out: &mut [f32], a: &[f32], b: &[f32],
                                m: usize, kd: usize, n: usize) {
        let mut kb = 0;
        while kb < kd {
            let ke = (kb + TILE_K).min(kd);
            let mut ib = 0;
            while ib < m {
                let ie = (ib + TILE_I).min(m);
                let mut j0 = 0;
                while j0 < n {
                    let jr = 4.min(n - j0);
                    let mut i0 = ib;
                    while i0 < ie {
                        let rows = 2.min(ie - i0);
                        // SAFETY: caller checked avx2+fma; indices bounded
                        unsafe {
                            nt_tile_2x4(out, n, i0, j0, rows, jr, a, b, kd,
                                        kb, ke);
                        }
                        i0 += 2;
                    }
                    j0 += 4;
                }
                ib = ie;
            }
            kb = ke;
        }
    }

    /// One 2×4 tile of dots over `k ∈ [kb, ke)`; duplicate-row/col
    /// clamping handles the edges like [`mm_tile_4x16`].
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn nt_tile_2x4(out: &mut [f32], ldo: usize, i0: usize,
                          j0: usize, rows: usize, jr: usize, a: &[f32],
                          b: &[f32], kd: usize, kb: usize, ke: usize) {
        let ridx = |r: usize| i0 + r.min(rows - 1);
        let cidx = |c: usize| j0 + c.min(jr - 1);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = [_mm256_setzero_ps(); 8];
        let mut k = kb;
        while k + 8 <= ke {
            let b0 = _mm256_loadu_ps(pb.add(cidx(0) * kd + k));
            let b1 = _mm256_loadu_ps(pb.add(cidx(1) * kd + k));
            let b2 = _mm256_loadu_ps(pb.add(cidx(2) * kd + k));
            let b3 = _mm256_loadu_ps(pb.add(cidx(3) * kd + k));
            for r in 0..2 {
                let av = _mm256_loadu_ps(pa.add(ridx(r) * kd + k));
                acc[4 * r] = _mm256_fmadd_ps(av, b0, acc[4 * r]);
                acc[4 * r + 1] = _mm256_fmadd_ps(av, b1, acc[4 * r + 1]);
                acc[4 * r + 2] = _mm256_fmadd_ps(av, b2, acc[4 * r + 2]);
                acc[4 * r + 3] = _mm256_fmadd_ps(av, b3, acc[4 * r + 3]);
            }
            k += 8;
        }
        for r in 0..rows {
            let arow = (i0 + r) * kd;
            for c in 0..jr {
                let brow = (j0 + c) * kd;
                let mut s = hsum(acc[4 * r + c]);
                for kt in k..ke {
                    s += a[arow + kt] * b[brow + kt];
                }
                out[(i0 + r) * ldo + j0 + c] += s;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut buf = [0f32; 8];
        _mm256_storeu_ps(buf.as_mut_ptr(), v);
        buf.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn close(x: f32, y: f32) -> bool {
        (x - y).abs() <= 1e-4 + 1e-4 * x.abs().max(y.abs())
    }

    // these compare the dispatched kernels against the scalar reference;
    // on hardware without AVX2 both sides are the same code and the tests
    // degenerate to identities (the SIMD leg is then covered by CI's
    // x86_64 runners)

    #[test]
    fn dot_matches_scalar_across_tails() {
        let mut rng = Rng::new(91);
        for n in [0usize, 1, 7, 8, 15, 16, 31, 33, 100] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            assert!(close(dot(&a, &b), crate::tensor::dot(&a, &b)), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_scalar_across_tails() {
        let mut rng = Rng::new(92);
        for n in [1usize, 7, 8, 31, 33, 100] {
            let b = rand_vec(&mut rng, n);
            let mut x = rand_vec(&mut rng, n);
            let mut y = x.clone();
            axpy(&mut x, 0.37, &b);
            crate::tensor::axpy(&mut y, 0.37, &b);
            for (p, q) in x.iter().zip(&y) {
                assert!(close(*p, *q), "n={n}");
            }
        }
    }

    #[test]
    fn axpy4_matches_four_axpys() {
        let mut rng = Rng::new(93);
        for n in [1usize, 7, 33, 100] {
            let rows: Vec<Vec<f32>> =
                (0..4).map(|_| rand_vec(&mut rng, n)).collect();
            let s = [0.5, -1.25, 2.0, 0.125];
            let mut fused = rand_vec(&mut rng, n);
            let mut serial = fused.clone();
            axpy4(&mut fused, s,
                  [&rows[0], &rows[1], &rows[2], &rows[3]]);
            for (r, &sr) in s.iter().enumerate() {
                crate::tensor::axpy(&mut serial, sr, &rows[r]);
            }
            for (p, q) in fused.iter().zip(&serial) {
                assert!(close(*p, *q), "n={n}");
            }
        }
    }

    #[test]
    fn matmul_acc_matches_triple_loop() {
        let mut rng = Rng::new(94);
        for (m, kd, n) in [(1, 1, 1), (3, 7, 5), (4, 16, 16), (5, 31, 17),
                           (33, 65, 33), (64, 64, 100)] {
            let a = rand_vec(&mut rng, m * kd);
            let b = rand_vec(&mut rng, kd * n);
            let mut got = rand_vec(&mut rng, m * n);
            let init = got.clone();
            matmul_acc(&mut got, &a, &b, m, kd, n);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = init[i * n + j]
                        + (0..kd).map(|k| a[i * kd + k] * b[k * n + j])
                            .sum::<f32>();
                    assert!(close(got[i * n + j], want),
                            "{m}x{kd}x{n} [{i},{j}]");
                }
            }
        }
    }

    #[test]
    fn matmul_nt_acc_matches_triple_loop() {
        let mut rng = Rng::new(95);
        for (m, kd, n) in [(1, 1, 1), (2, 8, 4), (3, 7, 5), (5, 31, 17),
                           (33, 100, 9), (31, 64, 33)] {
            let a = rand_vec(&mut rng, m * kd);
            let b = rand_vec(&mut rng, n * kd);
            let mut got = rand_vec(&mut rng, m * n);
            let init = got.clone();
            matmul_nt_acc(&mut got, &a, &b, m, kd, n);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = init[i * n + j]
                        + (0..kd).map(|k| a[i * kd + k] * b[j * kd + k])
                            .sum::<f32>();
                    assert!(close(got[i * n + j], want),
                            "{m}x{kd}x{n} [{i},{j}]");
                }
            }
        }
    }

    #[test]
    fn level_name_is_stable() {
        // whatever hardware this runs on, the decision must be one of the
        // two published names (README documents both)
        assert!(matches!(level().name(), "scalar" | "avx2"));
    }
}
