//! Small deterministic RNG (xoshiro256**) — used for parameter init and all
//! synthetic data generators, so every run is reproducible under a seed
//! without depending on platform RNG behaviour.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices sampled from [0, n) (k ≤ n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a child RNG (stable derivation for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(3);
        let s = r.sample_distinct(20, 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
        assert!(s.iter().all(|&i| i < 20));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f32 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.05);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
