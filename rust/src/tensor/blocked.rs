//! Cache-blocked matmul / tril-matmul primitives backing the chunkwise
//! kernel layer (`crate::kernels`).
//!
//! The naive `Mat::matmul` streams the whole right-hand operand once per
//! output row; for the chunk-sized operands the kernels use (C×C, C×d with
//! C, d ∈ {16..128}) that already fits cache, but state-sized and
//! attention-shaped products benefit from tiling and from computing only
//! the causal triangle.  The inner loops all dispatch through
//! [`super::simd`] (AVX2+FMA microkernels with a scalar fallback), so this
//! module owns shapes, masks and triangular structure while `simd` owns
//! the flop loops.
//!
//! Two conventions serve the zero-allocation chunk loop in
//! `crate::kernels`:
//!
//! * inputs are `impl Into<MatRef>` — a `&Mat` converts implicitly (all
//!   pre-existing call sites unchanged), and the kernels pass borrowed row
//!   windows (`Mat::rows_window`) instead of copied chunk slices;
//! * non-accumulating `_into` entry points RESHAPE their output via
//!   [`Mat::reset`] instead of asserting its shape, so a reused workspace
//!   buffer adapts to tail chunks without reallocating.  Accumulating
//!   calls still assert — accumulation onto a wrongly-shaped output is a
//!   bug, not a resize request.

use super::{simd, Mat, MatRef};

/// out = A·B (or out += A·B when `accumulate`), tiled + SIMD-dispatched.
pub fn matmul_into<'a, 'b>(out: &mut Mat, a: impl Into<MatRef<'a>>,
                           b: impl Into<MatRef<'b>>, accumulate: bool) {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols, b.rows, "matmul dims");
    let (m, kd, n) = (a.rows, a.cols, b.cols);
    if accumulate {
        assert_eq!((out.rows, out.cols), (m, n), "matmul out shape");
    } else {
        out.reset(m, n);
    }
    simd::matmul_acc(&mut out.data, a.data, b.data, m, kd, n);
}

/// A·B as a fresh matrix (blocked).
pub fn matmul<'a, 'b>(a: impl Into<MatRef<'a>>,
                      b: impl Into<MatRef<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_into(&mut out, a, b, true);
    out
}

/// out = tril(A·Bᵀ, diag) computing ONLY the kept triangle (the causal
/// masks of the chunkwise form: diag=0 for Q·Kᵀ, diag=−1 for the UT
/// transform's strictly-lower K·Kᵀ).  Entries above the diagonal are
/// exact zeros — `reset` wipes the whole output before the triangle is
/// filled, so a reused workspace can't leak stale upper entries.
pub fn tril_matmul_nt_into<'a, 'b>(out: &mut Mat, a: impl Into<MatRef<'a>>,
                                   b: impl Into<MatRef<'b>>, diag: i64) {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols, b.cols, "tril_matmul_nt dims");
    let (m, n) = (a.rows, b.rows);
    out.reset(m, n);
    for i in 0..m {
        let hi = (i as i64 + diag + 1).clamp(0, n as i64) as usize;
        if hi == 0 {
            continue;
        }
        let arow = a.row(i);
        // one 1×hi A·Bᵀ strip: B rows 0..hi stay hot across the 2×4 tile
        simd::matmul_nt_acc(&mut out.data[i * n..i * n + hi], arow,
                            &b.data[..hi * b.cols], 1, a.cols, hi);
    }
}

/// tril(A·Bᵀ, diag) as a fresh matrix.
pub fn tril_matmul_nt<'a, 'b>(a: impl Into<MatRef<'a>>,
                              b: impl Into<MatRef<'b>>, diag: i64) -> Mat {
    let mut out = Mat::zeros(0, 0);
    tril_matmul_nt_into(&mut out, a, b, diag);
    out
}

/// out = A·Bᵀ (or out += A·Bᵀ when `accumulate`) with `a: [m,k]`,
/// `b: [n,k]`, `out: [m,n]` — the transposed products of the backward pass
/// (dQ = dO·Sᵀ, dW = −dU̅·Sᵀ, dT = dW·Kᵦᵀ + dU·Vᵦᵀ) without materializing
/// the transpose: both operands stream row-major, depth-tiled so long k
/// extents are consumed in cache-sized slabs.
pub fn matmul_nt_into<'a, 'b>(out: &mut Mat, a: impl Into<MatRef<'a>>,
                              b: impl Into<MatRef<'b>>, accumulate: bool) {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols, b.cols, "matmul_nt dims");
    let (m, n) = (a.rows, b.rows);
    if accumulate {
        assert_eq!((out.rows, out.cols), (m, n), "matmul_nt out shape");
    } else {
        out.reset(m, n);
    }
    simd::matmul_nt_acc(&mut out.data, a.data, b.data, m, a.cols, n);
}

/// A·Bᵀ as a fresh matrix.
pub fn matmul_nt<'a, 'b>(a: impl Into<MatRef<'a>>,
                         b: impl Into<MatRef<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    let mut out = Mat::zeros(a.rows, b.rows);
    matmul_nt_into(&mut out, a, b, true);
    out
}

/// Copy `src` into `out`, reusing `out`'s allocation.
pub fn copy_into<'a>(out: &mut Mat, src: impl Into<MatRef<'a>>) {
    let src = src.into();
    out.rows = src.rows;
    out.cols = src.cols;
    out.data.clear();
    out.data.extend_from_slice(src.data);
}

/// out = Aᵀ, reusing `out`'s allocation.
pub fn transpose_into<'a>(out: &mut Mat, a: impl Into<MatRef<'a>>) {
    let a = a.into();
    out.reset(a.cols, a.rows);
    for i in 0..a.rows {
        for (j, &x) in a.row(i).iter().enumerate() {
            out.data[j * a.rows + i] = x;
        }
    }
}

/// In-place core of [`solve_unit_lower`]: overwrite `x` (initially B) with
/// the solution of (I + A)·X = B by forward substitution over rows:
/// X[i] = B[i] − Σ_{j<i} A[i,j]·X[j].  Cheaper and better-conditioned than
/// materializing (I+A)⁻¹ when only the product is needed (the backward
/// pass solves against dT twice instead of forming Tᵀ·dT·Tᵀ).
pub fn solve_unit_lower_in_place(a: &Mat, x: &mut Mat) {
    assert_eq!(a.rows, a.cols, "solve_unit_lower wants square A");
    assert_eq!(a.rows, x.rows, "solve_unit_lower dims");
    let (c, n) = (x.rows, x.cols);
    for i in 0..c {
        // rows j < i of x are final; subtract their weighted sum from row i
        let (done, rest) = x.data.split_at_mut(i * n);
        let xi = &mut rest[..n];
        for j in 0..i {
            let aij = a[(i, j)];
            if aij != 0.0 {
                simd::axpy(xi, -aij, &done[j * n..(j + 1) * n]);
            }
        }
    }
}

/// Solve (I + A)·X = B into `out` (workspace-reusing).
pub fn solve_unit_lower_into<'a>(out: &mut Mat, a: &Mat,
                                 b: impl Into<MatRef<'a>>) {
    copy_into(out, b);
    solve_unit_lower_in_place(a, out);
}

/// Solve (I + A)·X = B as a fresh matrix.
pub fn solve_unit_lower(a: &Mat, b: &Mat) -> Mat {
    let mut x = b.clone();
    solve_unit_lower_in_place(a, &mut x);
    x
}

/// In-place core of [`solve_unit_lower_t`]: overwrite `x` (initially B)
/// with the solution of (I + A)ᵀ·X = B by backward substitution:
/// X[i] = B[i] − Σ_{j>i} A[j,i]·X[j], i from c−1 down.
pub fn solve_unit_lower_t_in_place(a: &Mat, x: &mut Mat) {
    assert_eq!(a.rows, a.cols, "solve_unit_lower_t wants square A");
    assert_eq!(a.rows, x.rows, "solve_unit_lower_t dims");
    let (c, n) = (x.rows, x.cols);
    for i in (0..c).rev() {
        // rows j > i of x are final; subtract their weighted sum from row i
        let (head, done) = x.data.split_at_mut((i + 1) * n);
        let xi = &mut head[i * n..];
        for j in i + 1..c {
            let aji = a[(j, i)];
            if aji != 0.0 {
                simd::axpy(xi, -aji, &done[(j - i - 1) * n..(j - i) * n]);
            }
        }
    }
}

/// Solve (I + A)ᵀ·X = B into `out` (workspace-reusing).
pub fn solve_unit_lower_t_into<'a>(out: &mut Mat, a: &Mat,
                                   b: impl Into<MatRef<'a>>) {
    copy_into(out, b);
    solve_unit_lower_t_in_place(a, out);
}

/// Solve (I + A)ᵀ·X = B as a fresh matrix.
pub fn solve_unit_lower_t(a: &Mat, b: &Mat) -> Mat {
    let mut x = b.clone();
    solve_unit_lower_t_in_place(a, &mut x);
    x
}

/// out += Aᵀ·B with `a: [t,m]`, `b: [t,n]`, `out: [m,n]` — the inter-chunk
/// state update S += Kᵀ·U̅, streamed over t.  Four t-rows are fused per
/// pass ([`simd::axpy4`]) so each destination row is loaded and stored
/// once per quad instead of once per source row; all-zero coefficient
/// quads (the upper triangle when A is a causal attention block) are
/// skipped outright.
pub fn matmul_tn_acc<'a, 'b>(out: &mut Mat, a: impl Into<MatRef<'a>>,
                             b: impl Into<MatRef<'b>>) {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.rows, b.rows, "matmul_tn_acc dims");
    assert_eq!(out.rows, a.cols, "matmul_tn_acc out rows");
    assert_eq!(out.cols, b.cols, "matmul_tn_acc out cols");
    let (t_total, m) = (a.rows, a.cols);
    let mut t = 0;
    while t + 4 <= t_total {
        let (a0, a1, a2, a3) = (a.row(t), a.row(t + 1), a.row(t + 2),
                                a.row(t + 3));
        let bq = [b.row(t), b.row(t + 1), b.row(t + 2), b.row(t + 3)];
        for i in 0..m {
            let s = [a0[i], a1[i], a2[i], a3[i]];
            if s != [0.0; 4] {
                simd::axpy4(out.row_mut(i), s, bq);
            }
        }
        t += 4;
    }
    while t < t_total {
        let arow = a.row(t);
        let brow = b.row(t);
        for (i, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                simd::axpy(out.row_mut(i), av, brow);
            }
        }
        t += 1;
    }
}

/// a −= b, elementwise.
pub fn sub_in_place<'a>(a: &mut Mat, b: impl Into<MatRef<'a>>) {
    let b = b.into();
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, y) in a.data.iter_mut().zip(b.data) {
        *x -= y;
    }
}

/// out = diag(s)·A — rows of `a` scaled by `s` (workspace-reusing).
pub fn scale_rows_into<'a>(out: &mut Mat, a: impl Into<MatRef<'a>>,
                           s: &[f32]) {
    let a = a.into();
    assert_eq!(a.rows, s.len(), "scale_rows dims");
    copy_into(out, a);
    for (i, &si) in s.iter().enumerate() {
        for x in out.row_mut(i) {
            *x *= si;
        }
    }
}

/// diag(s)·A as a fresh matrix.
pub fn scale_rows<'a>(a: impl Into<MatRef<'a>>, s: &[f32]) -> Mat {
    let mut out = Mat::zeros(0, 0);
    scale_rows_into(&mut out, a, s);
    out
}

/// out = (I + A)⁻¹ for strictly-lower-triangular A, by forward
/// substitution: row i of the inverse = e_i − Σ_{j<i} A[i,j] · row j.
/// Exploits the triangular fill-in (row j of the inverse has support
/// [0, j]).
pub fn tri_inv_unit_lower_into(out: &mut Mat, a: &Mat) {
    assert_eq!(a.rows, a.cols, "tri_inv_unit_lower wants square");
    let c = a.rows;
    out.reset(c, c);
    for i in 0..c {
        out.data[i * c + i] = 1.0;
    }
    for i in 0..c {
        for j in 0..i {
            let aij = a[(i, j)];
            if aij != 0.0 {
                // rows i and j of out are disjoint slices; split to borrow both
                let (head, tail) = out.data.split_at_mut(i * c);
                simd::axpy(&mut tail[..j + 1], -aij,
                           &head[j * c..j * c + j + 1]);
            }
        }
    }
}

/// (I + A)⁻¹ as a fresh matrix.
pub fn tri_inv_unit_lower(a: &Mat) -> Mat {
    let mut t = Mat::zeros(0, 0);
    tri_inv_unit_lower_into(&mut t, a);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        a.matmul(b)
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (33, 65, 17), (64, 64, 64),
                          (100, 70, 130)] {
            let a = Mat::random(m, k, &mut rng, 1.0);
            let b = Mat::random(k, n, &mut rng, 1.0);
            let got = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(got.allclose(&want, 1e-4, 1e-4), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_into_accumulates() {
        let mut rng = Rng::new(12);
        let a = Mat::random(8, 6, &mut rng, 1.0);
        let b = Mat::random(6, 4, &mut rng, 1.0);
        let mut out = Mat::zeros(8, 4);
        matmul_into(&mut out, &a, &b, false);
        matmul_into(&mut out, &a, &b, true);
        let want = naive_matmul(&a, &b).scale(2.0);
        assert!(out.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn into_variants_reshape_stale_workspaces() {
        // a workspace Mat left at the wrong shape by a previous (larger)
        // chunk must be adapted, not trip an assert or leak stale values
        let mut rng = Rng::new(13);
        let a = Mat::random(5, 6, &mut rng, 1.0);
        let b = Mat::random(6, 3, &mut rng, 1.0);
        let mut ws = Mat::random(64, 64, &mut rng, 1.0);
        matmul_into(&mut ws, &a, &b, false);
        assert!(ws.allclose(&naive_matmul(&a, &b), 1e-4, 1e-4));

        let bt = Mat::random(7, 6, &mut rng, 1.0);
        matmul_nt_into(&mut ws, &a, &bt, false);
        assert!(ws.allclose(&a.matmul(&bt.transpose()), 1e-4, 1e-4));

        let sq = Mat::random(4, 6, &mut rng, 1.0);
        tril_matmul_nt_into(&mut ws, &sq, &sq, -1);
        assert!(ws.allclose(&sq.matmul(&sq.transpose()).tril(-1),
                            1e-4, 1e-4));

        transpose_into(&mut ws, &a);
        assert!(ws.allclose(&a.transpose(), 1e-6, 1e-6));

        scale_rows_into(&mut ws, &a, &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!((ws.rows, ws.cols), (5, 6));
    }

    #[test]
    fn windows_give_same_products_as_copies() {
        // MatRef row windows must be interchangeable with sliced copies
        let mut rng = Rng::new(21);
        let big = Mat::random(20, 6, &mut rng, 1.0);
        let b = Mat::random(6, 4, &mut rng, 1.0);
        let copy = Mat::from_vec(
            4, 6, big.data[5 * 6..9 * 6].to_vec()).unwrap();
        let got = matmul(big.rows_window(5, 4), &b);
        assert!(got.allclose(&matmul(&copy, &b), 0.0, 0.0));
        let got_nt = matmul_nt(big.rows_window(5, 4), big.rows_window(0, 3));
        let copy0 = Mat::from_vec(3, 6, big.data[..3 * 6].to_vec()).unwrap();
        assert!(got_nt.allclose(&matmul_nt(&copy, &copy0), 0.0, 0.0));
    }

    #[test]
    fn tril_nt_masks_exactly() {
        let mut rng = Rng::new(14);
        let a = Mat::random(12, 6, &mut rng, 1.0);
        let b = Mat::random(12, 6, &mut rng, 1.0);
        for diag in [-1i64, 0] {
            let got = tril_matmul_nt(&a, &b, diag);
            let want = a.matmul(&b.transpose()).tril(diag);
            assert!(got.allclose(&want, 1e-4, 1e-4), "diag={diag}");
            // kept-out entries are exact zeros, not epsilon garbage
            for i in 0..12 {
                for j in 0..12 {
                    if (j as i64) > (i as i64) + diag {
                        assert_eq!(got[(i, j)], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn tn_acc_matches_transpose_matmul() {
        let mut rng = Rng::new(15);
        // sizes straddle the 4-row quad boundary of the fused update
        for t in [1usize, 3, 4, 7, 10, 16] {
            let a = Mat::random(t, 6, &mut rng, 1.0);
            let b = Mat::random(t, 4, &mut rng, 1.0);
            let mut out = Mat::random(6, 4, &mut rng, 1.0);
            let want = out.add(&a.transpose().matmul(&b));
            matmul_tn_acc(&mut out, &a, &b);
            assert!(out.allclose(&want, 1e-4, 1e-4), "t={t}");
        }
    }

    #[test]
    fn scale_rows_and_sub() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let s = scale_rows(&a, &[2.0, 0.5]);
        assert_eq!(s.data, vec![2.0, 4.0, 1.5, 2.0]);
        let mut x = a.clone();
        sub_in_place(&mut x, &a);
        assert!(x.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nt_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(17);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (33, 65, 17), (64, 16, 64)] {
            let a = Mat::random(m, k, &mut rng, 1.0);
            let b = Mat::random(n, k, &mut rng, 1.0);
            let got = matmul_nt(&a, &b);
            let want = a.matmul(&b.transpose());
            assert!(got.allclose(&want, 1e-4, 1e-4), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn nt_matmul_into_accumulates() {
        let mut rng = Rng::new(18);
        let a = Mat::random(7, 5, &mut rng, 1.0);
        let b = Mat::random(9, 5, &mut rng, 1.0);
        let mut out = Mat::zeros(7, 9);
        matmul_nt_into(&mut out, &a, &b, false);
        matmul_nt_into(&mut out, &a, &b, true);
        let want = a.matmul(&b.transpose()).scale(2.0);
        assert!(out.allclose(&want, 1e-4, 1e-4));
    }

    fn random_strict_lower(c: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::zeros(c, c);
        for i in 0..c {
            for j in 0..i {
                a[(i, j)] = rng.normal() * 0.5;
            }
        }
        a
    }

    #[test]
    fn solves_really_solve() {
        let mut rng = Rng::new(19);
        for c in [1usize, 2, 7, 24, 64] {
            let a = random_strict_lower(c, &mut rng);
            let b = Mat::random(c, 5, &mut rng, 1.0);
            let mut ia = Mat::eye(c);
            for i in 0..c {
                for j in 0..i {
                    ia[(i, j)] += a[(i, j)];
                }
            }
            let x = solve_unit_lower(&a, &b);
            assert!(ia.matmul(&x).allclose(&b, 1e-3, 1e-3), "fwd C={c}");
            let xt = solve_unit_lower_t(&a, &b);
            assert!(ia.transpose().matmul(&xt).allclose(&b, 1e-3, 1e-3),
                    "bwd C={c}");
            // the _into forms write the same solutions into a workspace
            let mut ws = Mat::zeros(1, 1);
            solve_unit_lower_into(&mut ws, &a, &b);
            assert!(ws.allclose(&x, 0.0, 0.0), "into fwd C={c}");
            solve_unit_lower_t_into(&mut ws, &a, &b);
            assert!(ws.allclose(&xt, 0.0, 0.0), "into bwd C={c}");
        }
    }

    #[test]
    fn solve_agrees_with_explicit_inverse() {
        let mut rng = Rng::new(20);
        let c = 16;
        let a = random_strict_lower(c, &mut rng);
        let b = Mat::random(c, 3, &mut rng, 1.0);
        let t = tri_inv_unit_lower(&a);
        assert!(solve_unit_lower(&a, &b)
            .allclose(&t.matmul(&b), 1e-3, 1e-3));
        assert!(solve_unit_lower_t(&a, &b)
            .allclose(&t.transpose().matmul(&b), 1e-3, 1e-3));
    }

    #[test]
    fn tri_inv_really_inverts() {
        let mut rng = Rng::new(16);
        for c in [1usize, 2, 7, 24, 64] {
            let mut a = Mat::zeros(c, c);
            for i in 0..c {
                for j in 0..i {
                    a[(i, j)] = rng.normal() * 0.5;
                }
            }
            let inv = tri_inv_unit_lower(&a);
            let mut ia = Mat::eye(c);
            for i in 0..c {
                for j in 0..i {
                    ia[(i, j)] += a[(i, j)];
                }
            }
            let prod = ia.matmul(&inv);
            assert!(prod.allclose(&Mat::eye(c), 1e-3, 1e-3), "C={c}");
        }
    }
}
