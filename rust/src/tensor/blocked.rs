//! Cache-blocked matmul / tril-matmul primitives backing the chunkwise
//! kernel layer (`crate::kernels`).
//!
//! The naive `Mat::matmul` streams the whole right-hand operand once per
//! output row; for the chunk-sized operands the kernels use (C×C, C×d with
//! C, d ∈ {16..128}) that already fits cache, but state-sized and
//! attention-shaped products benefit from i/k tiling and from computing
//! only the causal triangle.  These free functions also provide in-place /
//! accumulating variants so the per-chunk hot loop allocates O(C·d)
//! instead of reallocating every intermediate.

use super::{axpy, dot, Mat};

/// Row tile for the output (fits comfortably in L1 alongside a B panel).
const TILE_I: usize = 32;
/// Depth tile: one panel of B rows streamed per output tile.
const TILE_K: usize = 64;

/// out = A·B (or out += A·B when `accumulate`), i/k-tiled.
pub fn matmul_into(out: &mut Mat, a: &Mat, b: &Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows, "matmul dims");
    assert_eq!(out.rows, a.rows, "matmul out rows");
    assert_eq!(out.cols, b.cols, "matmul out cols");
    if !accumulate {
        out.data.fill(0.0);
    }
    let (m, kd, n) = (a.rows, a.cols, b.cols);
    for ib in (0..m).step_by(TILE_I) {
        let ie = (ib + TILE_I).min(m);
        for kb in (0..kd).step_by(TILE_K) {
            let ke = (kb + TILE_K).min(kd);
            for i in ib..ie {
                let arow = &a.data[i * kd..(i + 1) * kd];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for k in kb..ke {
                    let av = arow[k];
                    if av != 0.0 {
                        axpy(orow, av, &b.data[k * n..(k + 1) * n]);
                    }
                }
            }
        }
    }
}

/// A·B as a fresh matrix (blocked).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_into(&mut out, a, b, true);
    out
}

/// tril(A·Bᵀ, diag) computing ONLY the kept triangle (the causal masks of
/// the chunkwise form: diag=0 for Q·Kᵀ, diag=−1 for the UT transform's
/// strictly-lower K·Kᵀ).  Entries above the diagonal are exact zeros.
pub fn tril_matmul_nt(a: &Mat, b: &Mat, diag: i64) -> Mat {
    assert_eq!(a.cols, b.cols, "tril_matmul_nt dims");
    let (m, n) = (a.rows, b.rows);
    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let hi = (i as i64 + diag + 1).clamp(0, n as i64) as usize;
        let arow = a.row(i);
        let orow = out.row_mut(i);
        for (j, o) in orow.iter_mut().enumerate().take(hi) {
            *o = dot(arow, b.row(j));
        }
    }
    out
}

/// out = A·Bᵀ (or out += A·Bᵀ when `accumulate`) with `a: [m,k]`,
/// `b: [n,k]`, `out: [m,n]` — the transposed products of the backward pass
/// (dQ = dO·Sᵀ, dW = −dU̅·Sᵀ, dT = dW·Kᵦᵀ + dU·Vᵦᵀ) without materializing
/// the transpose: both operands stream row-major.
pub fn matmul_nt_into(out: &mut Mat, a: &Mat, b: &Mat, accumulate: bool) {
    assert_eq!(a.cols, b.cols, "matmul_nt dims");
    assert_eq!(out.rows, a.rows, "matmul_nt out rows");
    assert_eq!(out.cols, b.rows, "matmul_nt out cols");
    if !accumulate {
        out.data.fill(0.0);
    }
    let (m, n) = (a.rows, b.rows);
    for ib in (0..m).step_by(TILE_I) {
        let ie = (ib + TILE_I).min(m);
        for i in ib..ie {
            let arow = a.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                *o += dot(arow, b.row(j));
            }
        }
    }
}

/// A·Bᵀ as a fresh matrix.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.rows);
    matmul_nt_into(&mut out, a, b, true);
    out
}

/// Solve (I + A)·X = B for strictly-lower-triangular A by forward
/// substitution over rows: X[i] = B[i] − Σ_{j<i} A[i,j]·X[j].  Cheaper and
/// better-conditioned than materializing (I+A)⁻¹ when only the product is
/// needed (the backward pass solves against dT twice instead of forming
/// Tᵀ·dT·Tᵀ).
pub fn solve_unit_lower(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols, "solve_unit_lower wants square A");
    assert_eq!(a.rows, b.rows, "solve_unit_lower dims");
    let (c, n) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in 0..c {
        // rows j < i of x are final; subtract their weighted sum from row i
        let (done, rest) = x.data.split_at_mut(i * n);
        let xi = &mut rest[..n];
        for j in 0..i {
            let aij = a[(i, j)];
            if aij != 0.0 {
                let xj = &done[j * n..(j + 1) * n];
                for (p, q) in xi.iter_mut().zip(xj) {
                    *p -= aij * q;
                }
            }
        }
    }
    x
}

/// Solve (I + A)ᵀ·X = B for strictly-lower-triangular A by backward
/// substitution: X[i] = B[i] − Σ_{j>i} A[j,i]·X[j], i from c−1 down.
pub fn solve_unit_lower_t(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols, "solve_unit_lower_t wants square A");
    assert_eq!(a.rows, b.rows, "solve_unit_lower_t dims");
    let (c, n) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in (0..c).rev() {
        // rows j > i of x are final; subtract their weighted sum from row i
        let (head, done) = x.data.split_at_mut((i + 1) * n);
        let xi = &mut head[i * n..];
        for j in i + 1..c {
            let aji = a[(j, i)];
            if aji != 0.0 {
                let xj = &done[(j - i - 1) * n..(j - i) * n];
                for (p, q) in xi.iter_mut().zip(xj) {
                    *p -= aji * q;
                }
            }
        }
    }
    x
}

/// out += Aᵀ·B with `a: [t,m]`, `b: [t,n]`, `out: [m,n]` — the inter-chunk
/// state update S += Kᵀ·U̅, streamed row-by-row over t.
pub fn matmul_tn_acc(out: &mut Mat, a: &Mat, b: &Mat) {
    assert_eq!(a.rows, b.rows, "matmul_tn_acc dims");
    assert_eq!(out.rows, a.cols, "matmul_tn_acc out rows");
    assert_eq!(out.cols, b.cols, "matmul_tn_acc out cols");
    for t in 0..a.rows {
        let arow = a.row(t);
        let brow = b.row(t);
        for (i, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                axpy(out.row_mut(i), av, brow);
            }
        }
    }
}

/// a −= b, elementwise.
pub fn sub_in_place(a: &mut Mat, b: &Mat) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x -= y;
    }
}

/// diag(s)·A — rows of `a` scaled by `s`.
pub fn scale_rows(a: &Mat, s: &[f32]) -> Mat {
    assert_eq!(a.rows, s.len(), "scale_rows dims");
    let mut out = a.clone();
    for (i, &si) in s.iter().enumerate() {
        for x in out.row_mut(i) {
            *x *= si;
        }
    }
    out
}

/// (I + A)⁻¹ for strictly-lower-triangular A, by forward substitution:
/// row i of the inverse = e_i − Σ_{j<i} A[i,j] · row j.  Exploits the
/// triangular fill-in (row j of the inverse has support [0, j]).
pub fn tri_inv_unit_lower(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols, "tri_inv_unit_lower wants square");
    let c = a.rows;
    let mut t = Mat::eye(c);
    for i in 0..c {
        for j in 0..i {
            let aij = a[(i, j)];
            if aij != 0.0 {
                // rows i and j of t are disjoint slices; split to borrow both
                let (head, tail) = t.data.split_at_mut(i * c);
                let tj = &head[j * c..j * c + j + 1];
                let ti = &mut tail[..c];
                for (x, y) in ti.iter_mut().zip(tj) {
                    *x -= aij * y;
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        a.matmul(b)
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (33, 65, 17), (64, 64, 64),
                          (100, 70, 130)] {
            let a = Mat::random(m, k, &mut rng, 1.0);
            let b = Mat::random(k, n, &mut rng, 1.0);
            let got = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(got.allclose(&want, 1e-4, 1e-4), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_into_accumulates() {
        let mut rng = Rng::new(12);
        let a = Mat::random(8, 6, &mut rng, 1.0);
        let b = Mat::random(6, 4, &mut rng, 1.0);
        let mut out = Mat::zeros(8, 4);
        matmul_into(&mut out, &a, &b, false);
        matmul_into(&mut out, &a, &b, true);
        let want = naive_matmul(&a, &b).scale(2.0);
        assert!(out.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn tril_nt_masks_exactly() {
        let mut rng = Rng::new(14);
        let a = Mat::random(12, 6, &mut rng, 1.0);
        let b = Mat::random(12, 6, &mut rng, 1.0);
        for diag in [-1i64, 0] {
            let got = tril_matmul_nt(&a, &b, diag);
            let want = a.matmul(&b.transpose()).tril(diag);
            assert!(got.allclose(&want, 1e-4, 1e-4), "diag={diag}");
            // kept-out entries are exact zeros, not epsilon garbage
            for i in 0..12 {
                for j in 0..12 {
                    if (j as i64) > (i as i64) + diag {
                        assert_eq!(got[(i, j)], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn tn_acc_matches_transpose_matmul() {
        let mut rng = Rng::new(15);
        let a = Mat::random(10, 6, &mut rng, 1.0);
        let b = Mat::random(10, 4, &mut rng, 1.0);
        let mut out = Mat::random(6, 4, &mut rng, 1.0);
        let want = out.add(&a.transpose().matmul(&b));
        matmul_tn_acc(&mut out, &a, &b);
        assert!(out.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn scale_rows_and_sub() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let s = scale_rows(&a, &[2.0, 0.5]);
        assert_eq!(s.data, vec![2.0, 4.0, 1.5, 2.0]);
        let mut x = a.clone();
        sub_in_place(&mut x, &a);
        assert!(x.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nt_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(17);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (33, 65, 17), (64, 16, 64)] {
            let a = Mat::random(m, k, &mut rng, 1.0);
            let b = Mat::random(n, k, &mut rng, 1.0);
            let got = matmul_nt(&a, &b);
            let want = a.matmul(&b.transpose());
            assert!(got.allclose(&want, 1e-4, 1e-4), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn nt_matmul_into_accumulates() {
        let mut rng = Rng::new(18);
        let a = Mat::random(7, 5, &mut rng, 1.0);
        let b = Mat::random(9, 5, &mut rng, 1.0);
        let mut out = Mat::zeros(7, 9);
        matmul_nt_into(&mut out, &a, &b, false);
        matmul_nt_into(&mut out, &a, &b, true);
        let want = a.matmul(&b.transpose()).scale(2.0);
        assert!(out.allclose(&want, 1e-4, 1e-4));
    }

    fn random_strict_lower(c: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::zeros(c, c);
        for i in 0..c {
            for j in 0..i {
                a[(i, j)] = rng.normal() * 0.5;
            }
        }
        a
    }

    #[test]
    fn solves_really_solve() {
        let mut rng = Rng::new(19);
        for c in [1usize, 2, 7, 24, 64] {
            let a = random_strict_lower(c, &mut rng);
            let b = Mat::random(c, 5, &mut rng, 1.0);
            let mut ia = Mat::eye(c);
            for i in 0..c {
                for j in 0..i {
                    ia[(i, j)] += a[(i, j)];
                }
            }
            let x = solve_unit_lower(&a, &b);
            assert!(ia.matmul(&x).allclose(&b, 1e-3, 1e-3), "fwd C={c}");
            let xt = solve_unit_lower_t(&a, &b);
            assert!(ia.transpose().matmul(&xt).allclose(&b, 1e-3, 1e-3),
                    "bwd C={c}");
        }
    }

    #[test]
    fn solve_agrees_with_explicit_inverse() {
        let mut rng = Rng::new(20);
        let c = 16;
        let a = random_strict_lower(c, &mut rng);
        let b = Mat::random(c, 3, &mut rng, 1.0);
        let t = tri_inv_unit_lower(&a);
        assert!(solve_unit_lower(&a, &b)
            .allclose(&t.matmul(&b), 1e-3, 1e-3));
        assert!(solve_unit_lower_t(&a, &b)
            .allclose(&t.transpose().matmul(&b), 1e-3, 1e-3));
    }

    #[test]
    fn tri_inv_really_inverts() {
        let mut rng = Rng::new(16);
        for c in [1usize, 2, 7, 24, 64] {
            let mut a = Mat::zeros(c, c);
            for i in 0..c {
                for j in 0..i {
                    a[(i, j)] = rng.normal() * 0.5;
                }
            }
            let inv = tri_inv_unit_lower(&a);
            let mut ia = Mat::eye(c);
            for i in 0..c {
                for j in 0..i {
                    ia[(i, j)] += a[(i, j)];
                }
            }
            let prod = ia.matmul(&inv);
            assert!(prod.allclose(&Mat::eye(c), 1e-3, 1e-3), "C={c}");
        }
    }
}
