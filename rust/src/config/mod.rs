//! Run configuration (JSON-loadable; offline build — no serde/toml).
//!
//! The model *shape* lives inside each artifact's manifest (fixed at AOT
//! time); this config selects which artifact to run and owns everything the
//! coordinator controls at run time: schedules, step counts, data sources,
//! logging.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::Context;
use crate::util::json::Json;

/// Learning-rate schedule (the paper: cosine with warmup, peak 3e-4,
/// floor 3e-5).
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant { lr: f64 },
    Cosine { peak: f64, floor: f64, warmup_steps: usize, total_steps: usize },
    Linear { start: f64, end: f64, total_steps: usize },
}

impl LrSchedule {
    /// lr at a 0-based step index.
    pub fn at(&self, step: usize) -> f64 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::Cosine { peak, floor, warmup_steps, total_steps } => {
                if warmup_steps > 0 && step < warmup_steps {
                    return floor
                        + (peak - floor) * (step as f64 / warmup_steps as f64);
                }
                let t = (step - warmup_steps) as f64
                    / (total_steps.saturating_sub(warmup_steps)).max(1) as f64;
                let t = t.min(1.0);
                floor + 0.5 * (peak - floor)
                    * (1.0 + (std::f64::consts::PI * t).cos())
            }
            LrSchedule::Linear { start, end, total_steps } => {
                let t = (step as f64 / total_steps.max(1) as f64).min(1.0);
                start + (end - start) * t
            }
        }
    }

    pub fn paper_default(total_steps: usize) -> Self {
        LrSchedule::Cosine {
            peak: 3e-4,
            floor: 3e-5,
            warmup_steps: (total_steps / 30).max(1),
            total_steps,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            LrSchedule::Constant { lr } => Json::obj(vec![
                ("kind", Json::str("constant")), ("lr", Json::num(*lr))]),
            LrSchedule::Cosine { peak, floor, warmup_steps, total_steps } =>
                Json::obj(vec![
                    ("kind", Json::str("cosine")),
                    ("peak", Json::num(*peak)),
                    ("floor", Json::num(*floor)),
                    ("warmup_steps", Json::num(*warmup_steps as f64)),
                    ("total_steps", Json::num(*total_steps as f64))]),
            LrSchedule::Linear { start, end, total_steps } => Json::obj(vec![
                ("kind", Json::str("linear")),
                ("start", Json::num(*start)),
                ("end", Json::num(*end)),
                ("total_steps", Json::num(*total_steps as f64))]),
        }
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        Ok(match v.req("kind")?.as_str()? {
            "constant" => LrSchedule::Constant {
                lr: v.req("lr")?.as_f64()?,
            },
            "cosine" => LrSchedule::Cosine {
                peak: v.req("peak")?.as_f64()?,
                floor: v.req("floor")?.as_f64()?,
                warmup_steps: v.req("warmup_steps")?.as_usize()?,
                total_steps: v.req("total_steps")?.as_usize()?,
            },
            "linear" => LrSchedule::Linear {
                start: v.req("start")?.as_f64()?,
                end: v.req("end")?.as_f64()?,
                total_steps: v.req("total_steps")?.as_usize()?,
            },
            other => bail!("unknown lr schedule {other:?}"),
        })
    }
}

/// What to train on.
#[derive(Debug, Clone)]
pub enum DataConfig {
    /// The synthetic text corpus (LM pretraining path).
    Corpus { seed: u64 },
    /// Multi-query associative recall (Fig. 2).
    Mqar { num_pairs: usize, seed: u64 },
    /// One of the MAD tasks (Table 1).
    Mad { task: String, seed: u64 },
    /// RegBench in-context language learning (Fig. 3).
    RegBench { seed: u64 },
    /// Recall-intensive kv-extraction (SWDE/SQuAD/FDA analogs, Table 2).
    Recall { style: String, seed: u64 },
}

impl DataConfig {
    pub fn to_json(&self) -> Json {
        match self {
            DataConfig::Corpus { seed } => Json::obj(vec![
                ("kind", Json::str("corpus")),
                ("seed", Json::num(*seed as f64))]),
            DataConfig::Mqar { num_pairs, seed } => Json::obj(vec![
                ("kind", Json::str("mqar")),
                ("num_pairs", Json::num(*num_pairs as f64)),
                ("seed", Json::num(*seed as f64))]),
            DataConfig::Mad { task, seed } => Json::obj(vec![
                ("kind", Json::str("mad")),
                ("task", Json::str(task.clone())),
                ("seed", Json::num(*seed as f64))]),
            DataConfig::RegBench { seed } => Json::obj(vec![
                ("kind", Json::str("regbench")),
                ("seed", Json::num(*seed as f64))]),
            DataConfig::Recall { style, seed } => Json::obj(vec![
                ("kind", Json::str("recall")),
                ("style", Json::str(style.clone())),
                ("seed", Json::num(*seed as f64))]),
        }
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let seed = v.get("seed").map(|s| s.as_u64()).transpose()?.unwrap_or(0);
        Ok(match v.req("kind")?.as_str()? {
            "corpus" => DataConfig::Corpus { seed },
            "mqar" => DataConfig::Mqar {
                num_pairs: v.req("num_pairs")?.as_usize()?,
                seed,
            },
            "mad" => DataConfig::Mad {
                task: v.req("task")?.as_str()?.to_string(),
                seed,
            },
            "regbench" => DataConfig::RegBench { seed },
            "recall" => DataConfig::Recall {
                style: v.req("style")?.as_str()?.to_string(),
                seed,
            },
            other => bail!("unknown data kind {other:?}"),
        })
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// artifact base name, e.g. "deltanet_tiny" — `.train`/`.eval`/`.decode`
    /// suffixes are appended per phase
    pub artifact: String,
    pub artifacts_dir: PathBuf,
    pub steps: usize,
    pub seed: u64,
    pub lr: LrSchedule,
    pub data: DataConfig,
    /// evaluate every N steps (0 = only at the end)
    pub eval_every: usize,
    /// number of eval batches per evaluation
    pub eval_batches: usize,
    /// write run metrics JSONL here
    pub log_path: Option<PathBuf>,
    /// save a checkpoint here at the end (npz)
    pub checkpoint_path: Option<PathBuf>,
}

impl RunConfig {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().render())?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifact", Json::str(self.artifact.clone())),
            ("artifacts_dir",
             Json::str(self.artifacts_dir.display().to_string())),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", self.lr.to_json()),
            ("data", self.data.to_json()),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("log_path", match &self.log_path {
                Some(p) => Json::str(p.display().to_string()),
                None => Json::Null,
            }),
            ("checkpoint_path", match &self.checkpoint_path {
                Some(p) => Json::str(p.display().to_string()),
                None => Json::Null,
            }),
        ])
    }

    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let opt_path = |key: &str| -> Option<PathBuf> {
            v.get(key).and_then(|x| x.as_str().ok().map(PathBuf::from))
        };
        Ok(RunConfig {
            artifact: v.req("artifact")?.as_str()?.to_string(),
            artifacts_dir: PathBuf::from(
                v.get("artifacts_dir").and_then(|x| x.as_str().ok())
                    .unwrap_or("artifacts")),
            steps: v.req("steps")?.as_usize()?,
            seed: v.get("seed").map(|s| s.as_u64()).transpose()?.unwrap_or(0),
            lr: LrSchedule::from_json(v.req("lr")?)?,
            data: DataConfig::from_json(v.req("data")?)?,
            eval_every: v.get("eval_every").map(|x| x.as_usize())
                .transpose()?.unwrap_or(0),
            eval_batches: v.get("eval_batches").map(|x| x.as_usize())
                .transpose()?.unwrap_or(4),
            log_path: opt_path("log_path"),
            checkpoint_path: opt_path("checkpoint_path"),
        })
    }

    pub fn quick(artifact: &str, steps: usize, data: DataConfig) -> Self {
        RunConfig {
            artifact: artifact.into(),
            artifacts_dir: PathBuf::from("artifacts"),
            steps,
            seed: 0,
            lr: LrSchedule::paper_default(steps),
            data,
            eval_every: 0,
            eval_batches: 4,
            log_path: None,
            checkpoint_path: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_shape() {
        let s = LrSchedule::Cosine {
            peak: 3e-4, floor: 3e-5, warmup_steps: 10, total_steps: 110,
        };
        assert!((s.at(0) - 3e-5).abs() < 1e-9);
        assert!((s.at(10) - 3e-4).abs() < 1e-9);       // peak after warmup
        assert!(s.at(60) < 3e-4 && s.at(60) > 3e-5);   // mid-decay
        assert!((s.at(110) - 3e-5).abs() < 1e-9);      // floor at end
        assert!((s.at(10_000) - 3e-5).abs() < 1e-9);   // clamped past end
        for i in 10..109 {
            assert!(s.at(i) >= s.at(i + 1), "not monotone at {i}");
        }
    }

    #[test]
    fn linear_and_constant() {
        let c = LrSchedule::Constant { lr: 1e-3 };
        assert_eq!(c.at(0), 1e-3);
        assert_eq!(c.at(999), 1e-3);
        let l = LrSchedule::Linear { start: 1.0, end: 0.0, total_steps: 10 };
        assert!((l.at(5) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = RunConfig::quick("deltanet_tiny", 100,
                                   DataConfig::Mqar { num_pairs: 4, seed: 1 });
        let text = cfg.to_json().render();
        let back = RunConfig::from_json(
            &crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.artifact, "deltanet_tiny");
        assert_eq!(back.steps, 100);
        match back.data {
            DataConfig::Mqar { num_pairs, seed } => {
                assert_eq!(num_pairs, 4);
                assert_eq!(seed, 1);
            }
            _ => panic!("wrong data kind"),
        }
        match back.lr {
            LrSchedule::Cosine { warmup_steps, .. } =>
                assert!(warmup_steps >= 1),
            _ => panic!("wrong schedule"),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("deltanet_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.json");
        let cfg = RunConfig::quick("x", 5, DataConfig::Corpus { seed: 2 });
        cfg.save(&path).unwrap();
        let back = RunConfig::load(&path).unwrap();
        assert_eq!(back.steps, 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
