//! Evaluation reports and table rendering for the reproduce harnesses.

use std::fmt::Write as _;

/// A simple fixed-width table printer (the reproduce harnesses print the
/// same rows/series the paper's tables and figures report).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells.iter().zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a fraction as "xx.x" percent.
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Format a float to 2 decimals.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["model", "acc"]);
        t.row(vec!["deltanet".into(), "99.1".into()]);
        t.row(vec!["gla".into(), "7.0".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("deltanet"));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines equal width
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.5), "50.0");
        assert_eq!(f2(1.234), "1.23");
    }
}
