//! Finite-difference oracle for the chunkwise backward pass.
//!
//! The analytic gradients in `kernels::backward` are checked against
//! central differences of a *scalar f64* delta-rule recurrence: f32
//! differences lose too many digits to resolve a 1e-3 tolerance, while f64
//! central differences with ε = 1e-3 carry O(ε²) = 1e-6 truncation error.
//! The loss is a fixed random linear functional of the outputs and the
//! final state, L = ⟨W_o, O⟩ + ⟨W_s, S_L⟩, so the matching analytic
//! backward simply takes d_o = W_o and d_state = W_s.

use crate::tensor::Mat;

/// Token-by-token f64 delta-rule recurrence over flat row-major slices:
/// `q,k: [l*dk]`, `v: [l*dv]`, `beta: [l]`, optional `s0: [dk*dv]`.
/// Returns (o: [l*dv], s: [dk*dv]).
pub fn delta_recurrent_f64(q: &[f64], k: &[f64], v: &[f64], beta: &[f64],
                           l: usize, dk: usize, dv: usize,
                           s0: Option<&[f64]>) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(q.len(), l * dk);
    assert_eq!(k.len(), l * dk);
    assert_eq!(v.len(), l * dv);
    assert_eq!(beta.len(), l);
    let mut s = match s0 {
        Some(s0) => {
            assert_eq!(s0.len(), dk * dv);
            s0.to_vec()
        }
        None => vec![0.0; dk * dv],
    };
    let mut o = vec![0.0; l * dv];
    let mut v_old = vec![0.0; dv];
    for t in 0..l {
        let kt = &k[t * dk..(t + 1) * dk];
        let vt = &v[t * dv..(t + 1) * dv];
        // v_old = kᵀ S
        v_old.fill(0.0);
        for (i, &ki) in kt.iter().enumerate() {
            for (j, x) in v_old.iter_mut().enumerate() {
                *x += ki * s[i * dv + j];
            }
        }
        // S += β k (v − v_old)ᵀ
        let b = beta[t];
        for (i, &ki) in kt.iter().enumerate() {
            let c = b * ki;
            for j in 0..dv {
                s[i * dv + j] += c * (vt[j] - v_old[j]);
            }
        }
        // o = q S
        let qt = &q[t * dk..(t + 1) * dk];
        let orow = &mut o[t * dv..(t + 1) * dv];
        for (i, &qi) in qt.iter().enumerate() {
            for (j, x) in orow.iter_mut().enumerate() {
                *x += qi * s[i * dv + j];
            }
        }
    }
    (o, s)
}

/// L = ⟨w_o, O⟩ + ⟨w_s, S_L⟩ of the f64 recurrence.
pub fn linear_loss_f64(q: &[f64], k: &[f64], v: &[f64], beta: &[f64],
                       l: usize, dk: usize, dv: usize, s0: Option<&[f64]>,
                       w_o: &[f64], w_s: &[f64]) -> f64 {
    let (o, s) = delta_recurrent_f64(q, k, v, beta, l, dk, dv, s0);
    assert_eq!(w_o.len(), o.len());
    assert_eq!(w_s.len(), s.len());
    let mut acc = 0.0;
    for (a, b) in o.iter().zip(w_o) {
        acc += a * b;
    }
    for (a, b) in s.iter().zip(w_s) {
        acc += a * b;
    }
    acc
}

/// Central-difference gradients of [`linear_loss_f64`] w.r.t. every input,
/// including the initial state (zeros when `s0` is None).
#[derive(Debug, Clone)]
pub struct FdGrads {
    pub dq: Vec<f64>,
    pub dk: Vec<f64>,
    pub dv: Vec<f64>,
    pub dbeta: Vec<f64>,
    pub dstate: Vec<f64>,
}

#[allow(clippy::too_many_arguments)]
pub fn fd_grads(q: &[f64], k: &[f64], v: &[f64], beta: &[f64],
                l: usize, dk: usize, dv: usize, s0: Option<&[f64]>,
                w_o: &[f64], w_s: &[f64], eps: f64) -> FdGrads {
    let s0_vec = match s0 {
        Some(s0) => s0.to_vec(),
        None => vec![0.0; dk * dv],
    };
    let central = |q: &[f64], k: &[f64], v: &[f64], beta: &[f64],
                   s0: &[f64]| {
        linear_loss_f64(q, k, v, beta, l, dk, dv, Some(s0), w_o, w_s)
    };
    let grad_of = |target: usize| -> Vec<f64> {
        // target: 0=q, 1=k, 2=v, 3=beta, 4=s0
        let base = [q, k, v, beta, &s0_vec[..]][target];
        let mut g = vec![0.0; base.len()];
        let mut work = base.to_vec();
        for i in 0..base.len() {
            let x0 = work[i];
            let pick = |w: &[f64], t: usize| -> f64 {
                let args: [&[f64]; 5] = [
                    if t == 0 { w } else { q },
                    if t == 1 { w } else { k },
                    if t == 2 { w } else { v },
                    if t == 3 { w } else { beta },
                    if t == 4 { w } else { &s0_vec },
                ];
                central(args[0], args[1], args[2], args[3], args[4])
            };
            work[i] = x0 + eps;
            let up = pick(&work, target);
            work[i] = x0 - eps;
            let down = pick(&work, target);
            work[i] = x0;
            g[i] = (up - down) / (2.0 * eps);
        }
        g
    };
    FdGrads {
        dq: grad_of(0),
        dk: grad_of(1),
        dv: grad_of(2),
        dbeta: grad_of(3),
        dstate: grad_of(4),
    }
}

/// Flatten an f32 [`Mat`] to f64.
pub fn to_f64(m: &Mat) -> Vec<f64> {
    m.data.iter().map(|&x| x as f64).collect()
}

/// Flatten an f32 slice to f64.
pub fn slice_to_f64(s: &[f32]) -> Vec<f64> {
    s.iter().map(|&x| x as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::{delta_recurrent, random_problem};

    #[test]
    fn f64_recurrence_matches_f32_reference() {
        let (q, k, v, beta) = random_problem(24, 6, 5, 61);
        let want = delta_recurrent(&q, &k, &v, &beta, None);
        let (o, s) = delta_recurrent_f64(
            &to_f64(&q), &to_f64(&k), &to_f64(&v), &slice_to_f64(&beta),
            24, 6, 5, None);
        for (a, b) in o.iter().zip(&want.o.data) {
            assert!((a - *b as f64).abs() < 1e-4);
        }
        for (a, b) in s.iter().zip(&want.state.data) {
            assert!((a - *b as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn fd_gradient_of_v_is_exact_for_single_write() {
        // one token, q = k = e0, β = 1: o = v, S = k vᵀ, so
        // dL/dv = w_o + w_s-row-0 exactly
        let (l, dk, dv) = (1usize, 3usize, 2usize);
        let q = vec![1.0, 0.0, 0.0];
        let k = q.clone();
        let v = vec![0.3, -0.7];
        let beta = vec![1.0];
        let w_o = vec![2.0, 5.0];
        let w_s = vec![0.5, 0.25, 0.0, 0.0, 0.0, 0.0];
        let g = fd_grads(&q, &k, &v, &beta, l, dk, dv, None, &w_o, &w_s,
                         1e-3);
        assert!((g.dv[0] - 2.5).abs() < 1e-6, "{:?}", g.dv);
        assert!((g.dv[1] - 5.25).abs() < 1e-6, "{:?}", g.dv);
    }
}
