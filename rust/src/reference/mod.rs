//! Pure-Rust reference implementation of the paper's algorithms.
//!
//! Three jobs:
//!   1. cross-check PJRT numerics (integration tests execute the AOT kernel
//!      artifacts and compare against this implementation);
//!   2. proptest target for the WY-representation invariants (chunkwise ≡
//!      recurrent, eigenvalue bounds, state chaining);
//!   3. scalar *oracle* for the blocked/batched host kernels in
//!      `crate::kernels` — [`delta_recurrent`] and
//!      [`delta_chunkwise_scalar`] stay deliberately naive (token loops,
//!      dense matmuls) so the fast paths have an obviously-correct target.
//!
//! [`delta_chunkwise`] itself is routed through the blocked kernel layer;
//! callers get the throughput engine, tests pin it to the oracle.
//!
//! Layout matches the Python side: state S ∈ R^{d_k×d_v} (row convention),
//! o_t = q_t S,  S_t = (I − β_t k_t k_tᵀ) S_{t-1} + β_t k_t v_tᵀ.

pub mod fd;

use crate::tensor::{axpy, dot, Mat};

pub use crate::kernels::Forward;
pub use crate::tensor::blocked::tri_inv_unit_lower;

/// Token-by-token delta-rule recurrence (DeltaNet, Schlag et al. 2021).
/// q,k: [L,dk], v: [L,dv], beta: [L].  O(L·dk·dv) work, O(L) steps.
pub fn delta_recurrent(q: &Mat, k: &Mat, v: &Mat, beta: &[f32],
                       initial_state: Option<&Mat>) -> Forward {
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;
    assert_eq!(k.rows, l);
    assert_eq!(beta.len(), l);
    let mut s = initial_state.cloned().unwrap_or_else(|| Mat::zeros(dk, dv));
    let mut o = Mat::zeros(l, dv);
    let mut v_old = vec![0.0f32; dv];
    for t in 0..l {
        let kt = k.row(t);
        // v_old = kᵀ S
        for j in 0..dv {
            v_old[j] = 0.0;
        }
        for i in 0..dk {
            let ki = kt[i];
            if ki != 0.0 {
                axpy(&mut v_old, ki, s.row(i));
            }
        }
        // S += β k (v − v_old)ᵀ
        let b = beta[t];
        let vt = v.row(t);
        for i in 0..dk {
            let c = b * kt[i];
            if c != 0.0 {
                let srow = s.row_mut(i);
                for j in 0..dv {
                    srow[j] += c * (vt[j] - v_old[j]);
                }
            }
        }
        // o = q S
        let qt = q.row(t);
        let orow = o.row_mut(t);
        for i in 0..dk {
            let qi = qt[i];
            if qi != 0.0 {
                axpy(orow, qi, s.row(i));
            }
        }
    }
    Forward { o, state: s }
}

/// UT transform for one chunk (Eq. 10–11, Listing-1 sign convention):
/// returns (W, U) with T = (I + tril(diag(β)KKᵀ, −1))⁻¹ diag(β).
pub fn ut_transform(k: &Mat, v: &Mat, beta: &[f32]) -> (Mat, Mat) {
    let c = k.rows;
    // A = tril(diag(β) K Kᵀ, −1)
    let mut a = Mat::zeros(c, c);
    for i in 0..c {
        for j in 0..i {
            a[(i, j)] = beta[i] * dot(k.row(i), k.row(j));
        }
    }
    // T = (I + A)⁻¹ by forward substitution (unit lower triangular):
    // row i of T = e_i − Σ_{j<i} A[i,j]·T[j,:]
    let t = tri_inv_unit_lower(&a);
    // W = T diag(β) K, U = T diag(β) V
    let mut kb = k.clone();
    let mut vb = v.clone();
    for i in 0..c {
        for x in kb.row_mut(i) {
            *x *= beta[i];
        }
        for x in vb.row_mut(i) {
            *x *= beta[i];
        }
    }
    (t.matmul(&kb), t.matmul(&vb))
}

/// Chunkwise-parallel DeltaNet forward (the paper's algorithm, Eq. 8–9).
/// Routed through the blocked kernel layer (`crate::kernels`); the scalar
/// cross-check lives in [`delta_chunkwise_scalar`].
pub fn delta_chunkwise(q: &Mat, k: &Mat, v: &Mat, beta: &[f32],
                       chunk: usize, initial_state: Option<&Mat>) -> Forward {
    crate::kernels::chunkwise_forward(q, k, v, beta, chunk, initial_state)
}

/// Scalar chunkwise forward — exactly the computation the Pallas kernel
/// performs, written with dense Mat ops; kept as the oracle for the
/// blocked path.
pub fn delta_chunkwise_scalar(q: &Mat, k: &Mat, v: &Mat, beta: &[f32],
                              chunk: usize, initial_state: Option<&Mat>)
                              -> Forward {
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;
    assert!(l % chunk == 0, "L={l} % C={chunk} != 0");
    let mut s = initial_state.cloned().unwrap_or_else(|| Mat::zeros(dk, dv));
    let mut o = Mat::zeros(l, dv);

    for t0 in (0..l).step_by(chunk) {
        let qc = slice_rows(q, t0, chunk);
        let kc = slice_rows(k, t0, chunk);
        let vc = slice_rows(v, t0, chunk);
        let bc = &beta[t0..t0 + chunk];
        let (w, u) = ut_transform(&kc, &vc, bc);
        // U̅ = U − W S
        let u_bar = u.sub(&w.matmul(&s));
        // O = Q S + tril(Q Kᵀ) U̅
        let attn = qc.matmul(&kc.transpose()).tril(0);
        let oc = qc.matmul(&s).add(&attn.matmul(&u_bar));
        for (i, row) in (t0..t0 + chunk).enumerate() {
            o.row_mut(row).copy_from_slice(oc.row(i));
        }
        // S += Kᵀ U̅
        s = s.add(&kc.transpose().matmul(&u_bar));
    }
    Forward { o, state: s }
}

/// Vanilla linear attention, recurrent (baseline in the family table).
pub fn linear_attn_recurrent(q: &Mat, k: &Mat, v: &Mat) -> Forward {
    let (l, dk) = (q.rows, q.cols);
    let dv = v.cols;
    let mut s = Mat::zeros(dk, dv);
    let mut o = Mat::zeros(l, dv);
    for t in 0..l {
        let kt = k.row(t);
        let vt = v.row(t);
        for i in 0..dk {
            let ki = kt[i];
            if ki != 0.0 {
                axpy(s.row_mut(i), ki, vt);
            }
        }
        let qt = q.row(t);
        let orow = o.row_mut(t);
        for i in 0..dk {
            axpy(orow, qt[i], s.row(i));
        }
    }
    Forward { o, state: s }
}

/// The delta-rule "attention matrix" of the fully-parallel form (§3.2):
/// A = (QKᵀ ⊙ M)(I + tril(diag(β)KKᵀ,−1))⁻¹ diag(β) — O(L³), for
/// interpretability tooling and tests.
pub fn delta_attention_matrix(q: &Mat, k: &Mat, beta: &[f32]) -> Mat {
    let l = q.rows;
    let mut a = Mat::zeros(l, l);
    for i in 0..l {
        for j in 0..i {
            a[(i, j)] = beta[i] * dot(k.row(i), k.row(j));
        }
    }
    let mut tm = tri_inv_unit_lower(&a);
    // T·diag(β): scale columns by β
    for i in 0..l {
        for j in 0..l {
            tm[(i, j)] *= beta[j];
        }
    }
    q.matmul(&k.transpose()).tril(0).matmul(&tm)
}

fn slice_rows(m: &Mat, start: usize, n: usize) -> Mat {
    Mat {
        rows: n,
        cols: m.cols,
        data: m.data[start * m.cols..(start + n) * m.cols].to_vec(),
    }
}

/// Convenience: generate a random (q, k, v, β) problem with L2-normalized
/// keys — the regime the model layer produces.
pub fn random_problem(l: usize, dk: usize, dv: usize, seed: u64)
                      -> (Mat, Mat, Mat, Vec<f32>) {
    let mut rng = crate::tensor::rng::Rng::new(seed);
    let q = Mat::random(l, dk, &mut rng, 1.0);
    let mut k = Mat::random(l, dk, &mut rng, 1.0);
    for i in 0..l {
        crate::tensor::l2_normalize(k.row_mut(i));
    }
    let v = Mat::random(l, dv, &mut rng, 1.0);
    let beta: Vec<f32> = (0..l)
        .map(|_| 1.0 / (1.0 + (-rng.normal()).exp()))
        .collect();
    (q, k, v, beta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunkwise_equals_recurrent() {
        let (q, k, v, beta) = random_problem(64, 16, 16, 7);
        let a = delta_recurrent(&q, &k, &v, &beta, None);
        for chunk in [1, 4, 16, 64] {
            let b = delta_chunkwise(&q, &k, &v, &beta, chunk, None);
            assert!(b.o.allclose(&a.o, 1e-4, 1e-4), "chunk={chunk}");
            assert!(b.state.allclose(&a.state, 1e-4, 1e-4), "chunk={chunk}");
        }
    }

    #[test]
    fn blocked_path_equals_scalar_oracle() {
        let (q, k, v, beta) = random_problem(64, 16, 16, 8);
        for chunk in [1, 4, 16, 64] {
            let blocked = delta_chunkwise(&q, &k, &v, &beta, chunk, None);
            let scalar = delta_chunkwise_scalar(&q, &k, &v, &beta, chunk,
                                                None);
            assert!(blocked.o.allclose(&scalar.o, 1e-4, 1e-4),
                    "chunk={chunk}");
            assert!(blocked.state.allclose(&scalar.state, 1e-4, 1e-4),
                    "chunk={chunk}");
        }
    }

    #[test]
    fn state_chaining() {
        let (q, k, v, beta) = random_problem(32, 8, 8, 9);
        let full = delta_chunkwise(&q, &k, &v, &beta, 8, None);
        let h1 = delta_chunkwise(&slice_rows(&q, 0, 16), &slice_rows(&k, 0, 16),
                                 &slice_rows(&v, 0, 16), &beta[..16], 8, None);
        let h2 = delta_chunkwise(&slice_rows(&q, 16, 16),
                                 &slice_rows(&k, 16, 16),
                                 &slice_rows(&v, 16, 16), &beta[16..], 8,
                                 Some(&h1.state));
        assert!(h2.state.allclose(&full.state, 1e-4, 1e-4));
        for i in 0..16 {
            assert_eq!(full.o.row(16 + i).len(), h2.o.row(i).len());
            for (a, b) in full.o.row(16 + i).iter().zip(h2.o.row(i)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn beta_one_overwrites_association() {
        // write v1 under key e0 with β=1, then v2 under e0: retrieval gives v2
        let dk = 4;
        let mut k = Mat::zeros(2, dk);
        k[(0, 0)] = 1.0;
        k[(1, 0)] = 1.0;
        let mut v = Mat::zeros(2, 3);
        v.row_mut(0).copy_from_slice(&[1.0, 2.0, 3.0]);
        v.row_mut(1).copy_from_slice(&[-1.0, -2.0, -3.0]);
        let q = k.clone();
        let f = delta_recurrent(&q, &k, &v, &[1.0, 1.0], None);
        assert_eq!(f.o.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(f.o.row(1), &[-1.0, -2.0, -3.0]);
    }

    #[test]
    fn attention_matrix_reproduces_output() {
        let (q, k, v, beta) = random_problem(24, 8, 8, 11);
        let f = delta_recurrent(&q, &k, &v, &beta, None);
        let a = delta_attention_matrix(&q, &k, &beta);
        let o2 = a.matmul(&v);
        assert!(o2.allclose(&f.o, 1e-3, 1e-3));
    }

    #[test]
    fn ut_transform_matches_recurrence() {
        // w_r = β_r(k_r − Σ_{i<r}(k_iᵀk_r) w_i) — Eq. 7
        let (_, k, v, beta) = random_problem(12, 6, 6, 13);
        let (w, u) = ut_transform(&k, &v, &beta);
        let mut w_seq = Mat::zeros(12, 6);
        let mut u_seq = Mat::zeros(12, 6);
        for r in 0..12 {
            let mut cw = vec![0.0; 6];
            let mut cu = vec![0.0; 6];
            for i in 0..r {
                let kk = dot(k.row(i), k.row(r));
                axpy(&mut cw, kk, w_seq.row(i));
                axpy(&mut cu, kk, u_seq.row(i));
            }
            for j in 0..6 {
                w_seq[(r, j)] = beta[r] * (k[(r, j)] - cw[j]);
                u_seq[(r, j)] = beta[r] * (v[(r, j)] - cu[j]);
            }
        }
        assert!(w.allclose(&w_seq, 1e-4, 1e-4));
        assert!(u.allclose(&u_seq, 1e-4, 1e-4));
    }

    #[test]
    fn linear_attention_is_prefix_sum() {
        let (q, k, v, _) = random_problem(16, 4, 4, 17);
        let f = linear_attn_recurrent(&q, &k, &v);
        // o_t = q_t (Σ_{i≤t} k_i v_iᵀ)
        let mut s = Mat::zeros(4, 4);
        for t in 0..16 {
            for i in 0..4 {
                for j in 0..4 {
                    s[(i, j)] += k[(t, i)] * v[(t, j)];
                }
            }
            let mut want = vec![0.0; 4];
            for i in 0..4 {
                axpy(&mut want, q[(t, i)], s.row(i));
            }
            for (a, b) in f.o.row(t).iter().zip(&want) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }
}
