//! DeltaNet — parallelizing linear transformers with the delta rule over
//! sequence length (Yang et al., NeurIPS 2024): Rust+JAX+Pallas three-layer
//! reproduction.
//!
//! Layer 3 (this crate) is the coordinator: it owns the PJRT runtime that
//! loads AOT-compiled HLO artifacts (`runtime`), the data pipeline and
//! synthetic benchmark generators (`data`), the training/eval/serving
//! orchestration (`coordinator`), the experiment harnesses that regenerate
//! every table and figure of the paper (`repro`), and a pure-Rust reference
//! implementation of the paper's algorithm used for cross-checking PJRT
//! numerics and property-based testing (`reference`).
//!
//! Python/JAX/Pallas exist only on the build path (`make artifacts`); the
//! binary produced from this crate is self-contained at run time.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod reference;
pub mod repro;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Convenient result alias used across the crate.
pub type Result<T> = anyhow::Result<T>;
