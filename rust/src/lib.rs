//! DeltaNet — parallelizing linear transformers with the delta rule over
//! sequence length (Yang et al., NeurIPS 2024): Rust+JAX+Pallas three-layer
//! reproduction.
//!
//! Layer 3 (this crate) is the coordinator: it owns the PJRT runtime that
//! loads AOT-compiled HLO artifacts (`runtime`), the data pipeline and
//! synthetic benchmark generators (`data`), the training/eval/serving
//! orchestration (`coordinator`), the experiment harnesses that regenerate
//! every table and figure of the paper (`repro`), a pure-Rust reference
//! implementation of the paper's algorithm used for cross-checking PJRT
//! numerics and property-based testing (`reference`), and a batched
//! multi-threaded host kernel layer implementing the paper's chunkwise
//! algorithm as a throughput engine (`kernels`).
//!
//! Python/JAX/Pallas exist only on the build path (`make artifacts`); the
//! binary produced from this crate is self-contained at run time.

// Index-heavy numerical kernels: explicit loops and short math names read
// closer to the paper's equations than iterator chains.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::should_implement_trait,
    clippy::type_complexity
)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod reference;
pub mod repro;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Convenient error/result aliases used across the crate (crate-local
/// `anyhow` replacement; see `util::error`).
pub use util::error::{Context, Error, Result};
