"""AdamW + gradient clipping (the paper's §D training recipe).

The learning-rate *schedule* (cosine with warmup) lives on the Rust side —
`lr` enters the train-step artifact as a scalar input every step, so the
coordinator owns scheduling without recompiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return {k: g * scale for k, g in grads.items()}, norm


def adamw_update(params, grads, m, v, step, lr, *, beta1=0.9, beta2=0.95,
                 eps=1e-8, weight_decay=0.01, clip=1.0):
    """One AdamW step over flat param/grad/moment dicts.

    step : f32 scalar (1-based).  Weight decay is decoupled and applied only
    to matrices (ndim ≥ 2), never to gains/biases — matching the paper's
    0.01 decay + 1.0 clip recipe."""
    grads, _ = clip_by_global_norm(grads, clip)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for k, p in params.items():
        g = grads[k]
        mk = beta1 * m[k] + (1.0 - beta1) * g
        vk = beta2 * v[k] + (1.0 - beta2) * jnp.square(g)
        update = (mk / bc1) / (jnp.sqrt(vk / bc2) + eps)
        if p.ndim >= 2 and weight_decay > 0.0:
            update = update + weight_decay * p
        new_p[k] = p - lr * update
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v
