"""AOT export: lower L2/L1 computations to HLO *text* + a JSON manifest.

HLO text (NOT `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly.  See /opt/xla-example/load_hlo/.

Each artifact `<name>.hlo.txt` ships with `<name>.manifest.json` describing
every input/output tensor in the exact flattened order jax.jit uses —
(positional args; flat dicts flatten in sorted-key order) — plus init specs
so the Rust runtime can construct parameter buffers without Python.

Usage:  cd python && python -m compile.aot --out ../artifacts [--set default]
        [--only substring]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim
from .kernels import delta_chunkwise, delta_recurrent
from .model import ModelConfig

# ---------------------------------------------------------------------------
# Presets: export-time shapes.  `batch`/`seq_len` are artifact shapes, the
# rest feeds ModelConfig.  (Paper scale: 340M/1.3B/3B on 8×H100 — see
# DESIGN.md §Substitutions for the scaling rationale.)
# ---------------------------------------------------------------------------

PRESETS = {
    # vocab 128: fits every synthetic task alphabet (MQAR ≤16 pairs needs
    # 2+2·48 = 98; the recall suites need 68; the corpus uses 128)
    "tiny": dict(vocab_size=128, d_model=64, n_layers=2, n_heads=2,
                 chunk_size=16, swa_window=16, max_seq_len=128,
                 batch=8, seq_len=64),
    "small": dict(vocab_size=512, d_model=128, n_layers=4, n_heads=4,
                  chunk_size=32, swa_window=32, max_seq_len=256,
                  batch=8, seq_len=128),
    "medium": dict(vocab_size=2048, d_model=256, n_layers=6, n_heads=4,
                   chunk_size=64, swa_window=64, max_seq_len=512,
                   batch=8, seq_len=256),
    # end-to-end LM training driver (examples/train_lm.rs): ~28M params
    "e2e": dict(vocab_size=8192, d_model=512, n_layers=8, n_heads=8,
                chunk_size=64, swa_window=64, max_seq_len=512,
                batch=8, seq_len=256),
    # ~100M-class configuration (paper's 340M row scaled to this testbed)
    "e2e100m": dict(vocab_size=16384, d_model=768, n_layers=12, n_heads=12,
                    chunk_size=64, swa_window=64, max_seq_len=512,
                    batch=4, seq_len=256),
    # long-sequence throughput probe (Fig. 4's crossover: linear-time
    # mixers vs O(L²) attention at L = 1024)
    "long": dict(vocab_size=128, d_model=128, n_layers=2, n_heads=2,
                 chunk_size=64, swa_window=64, max_seq_len=1024,
                 batch=1, seq_len=1024),
}

ARCHS = ["deltanet", "gla", "retnet", "mamba2", "linattn", "transformer",
         "hybrid_swa", "hybrid_global"]


def make_config(preset: str, arch: str, **overrides) -> ModelConfig:
    p = dict(PRESETS[preset])
    p.pop("batch"), p.pop("seq_len")
    p.update(overrides)
    return ModelConfig(arch=arch, **p)


# ---------------------------------------------------------------------------
# Lowering + manifest plumbing
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dt(dtype) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dtype).name]


def _entries(tree, arg_name: str, role: str, inits=None):
    """Flatten one positional arg into manifest entries, in the exact order
    jax.jit flattens it (tree_flatten_with_path matches tree_flatten)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = arg_name + "".join(
            f".{p.key}" for p in path)  # DictKey(.key); empty for scalars
        e = {"name": name, "shape": [int(d) for d in leaf.shape],
             "dtype": _dt(leaf.dtype), "role": role}
        if inits is not None:
            key = name.split(".", 1)[1] if "." in name else name
            e["init"] = inits[key]
        out.append(e)
    return out


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def param_abstract(cfg: ModelConfig):
    return {n: f32(*s) for n, s, _ in M.param_spec(cfg)}


def write_artifact(out_dir, name, lowered, in_entries, out_entries, meta):
    t0 = time.time()
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest = dict(name=name, inputs=in_entries, outputs=out_entries, **meta)
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {name}: {len(text)/1e6:.2f} MB hlo, "
          f"{len(in_entries)}→{len(out_entries)} tensors "
          f"({time.time()-t0:.1f}s)")
    return name


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------

def build_train(out_dir, arch: str, preset: str):
    cfg = make_config(preset, arch)
    B, L = PRESETS[preset]["batch"], PRESETS[preset]["seq_len"]
    pa = param_abstract(cfg)
    inits = {n: init for n, _, init in M.param_spec(cfg)}

    def train_fn(params, m, v, step, lr, tokens, mask):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, tokens, mask))(params)
        params, m, v = optim.adamw_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    args = (pa, pa, pa, f32(), f32(), i32(B, L + 1), f32(B, L))
    lowered = jax.jit(train_fn, keep_unused=True).lower(*args)
    ins = (_entries(pa, "params", "param", inits)
           + _entries(pa, "m", "opt_m")
           + _entries(pa, "v", "opt_v")
           + [{"name": "step", "shape": [], "dtype": "f32", "role": "data"},
              {"name": "lr", "shape": [], "dtype": "f32", "role": "data"},
              {"name": "tokens", "shape": [B, L + 1], "dtype": "i32",
               "role": "data"},
              {"name": "mask", "shape": [B, L], "dtype": "f32",
               "role": "data"}])
    outs = (_entries(pa, "params", "param")
            + _entries(pa, "m", "opt_m")
            + _entries(pa, "v", "opt_v")
            + [{"name": "loss", "shape": [], "dtype": "f32",
                "role": "metric"}])
    name = f"{arch}_{preset}.train"
    meta = dict(kind="train", config=cfg.to_dict(), batch=B, seq_len=L)
    return write_artifact(out_dir, name, lowered, ins, outs, meta)


def build_eval(out_dir, arch: str, preset: str):
    cfg = make_config(preset, arch)
    B, L = PRESETS[preset]["batch"], PRESETS[preset]["seq_len"]
    pa = param_abstract(cfg)
    inits = {n: init for n, _, init in M.param_spec(cfg)}

    def eval_fn(params, tokens, mask):
        return M.lm_eval(cfg, params, tokens, mask)

    args = (pa, i32(B, L + 1), f32(B, L))
    lowered = jax.jit(eval_fn, keep_unused=True).lower(*args)
    ins = (_entries(pa, "params", "param", inits)
           + [{"name": "tokens", "shape": [B, L + 1], "dtype": "i32",
               "role": "data"},
              {"name": "mask", "shape": [B, L], "dtype": "f32",
               "role": "data"}])
    outs = [
        {"name": "nll_sum", "shape": [], "dtype": "f32", "role": "metric"},
        {"name": "correct_sum", "shape": [], "dtype": "f32",
         "role": "metric"},
        {"name": "preds", "shape": [B, L], "dtype": "i32", "role": "metric"},
    ]
    name = f"{arch}_{preset}.eval"
    meta = dict(kind="eval", config=cfg.to_dict(), batch=B, seq_len=L)
    return write_artifact(out_dir, name, lowered, ins, outs, meta)


def build_decode(out_dir, arch: str, preset: str, batch: int | None = None):
    cfg = make_config(preset, arch)
    B = batch or PRESETS[preset]["batch"]
    pa = param_abstract(cfg)
    inits = {n: init for n, _, init in M.param_spec(cfg)}
    sa = {n: f32(*s) for n, s in M.state_spec(cfg, B)}

    def decode_fn(params, state, token, pos):
        return M.decode_step(cfg, params, state, token, pos)

    args = (pa, sa, i32(B), jax.ShapeDtypeStruct((), jnp.int32))
    lowered = jax.jit(decode_fn, keep_unused=True).lower(*args)
    ins = (_entries(pa, "params", "param", inits)
           + _entries(sa, "state", "state")
           + [{"name": "token", "shape": [B], "dtype": "i32",
               "role": "data"},
              {"name": "pos", "shape": [], "dtype": "i32", "role": "data"}])
    outs = ([{"name": "logits", "shape": [B, cfg.vocab_size],
              "dtype": "f32", "role": "metric"}]
            + _entries(sa, "state", "state"))
    name = f"{arch}_{preset}.decode"
    meta = dict(kind="decode", config=cfg.to_dict(), batch=B,
                seq_len=cfg.max_seq_len)
    return write_artifact(out_dir, name, lowered, ins, outs, meta)


def build_kernel(out_dir, form: str, L: int, d: int, C: int, B: int):
    """Standalone DeltaNet kernel artifacts for the Fig. 1 speed harness:
    chunkwise-parallel vs token-recurrent at various (L, d_head)."""
    if form == "chunkwise":
        def fn(q, k, v, beta):
            o, s = jax.vmap(
                lambda q, k, v, b: delta_chunkwise(q, k, v, b, C)
            )(q, k, v, beta)
            return o, s
    elif form == "recurrent":
        def fn(q, k, v, beta):
            o, s = jax.vmap(delta_recurrent)(q, k, v, beta)
            return o, s
    else:
        raise ValueError(form)

    args = (f32(B, L, d), f32(B, L, d), f32(B, L, d), f32(B, L))
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    ins = [{"name": n, "shape": [B, L, d] if n != "beta" else [B, L],
            "dtype": "f32", "role": "data"}
           for n in ("q", "k", "v", "beta")]
    outs = [{"name": "o", "shape": [B, L, d], "dtype": "f32",
             "role": "metric"},
            {"name": "s", "shape": [B, d, d], "dtype": "f32",
             "role": "metric"}]
    name = f"kernel_{form}_L{L}_d{d}_C{C}_B{B}"
    meta = dict(kind="kernel", form=form, L=L, d=d, C=C, batch=B,
                seq_len=L, config=None)
    return write_artifact(out_dir, name, lowered, ins, outs, meta)


def build_ablation(out_dir, feature_map: str, key_norm: str, preset="tiny"):
    """§4.2 ablation rows: feature map × key normalization for DeltaNet."""
    cfg = make_config(preset, "deltanet", feature_map=feature_map,
                      key_norm=key_norm)
    B, L = PRESETS[preset]["batch"], PRESETS[preset]["seq_len"]
    pa = param_abstract(cfg)
    inits = {n: init for n, _, init in M.param_spec(cfg)}

    def train_fn(params, m, v, step, lr, tokens, mask):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, tokens, mask))(params)
        params, m, v = optim.adamw_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    args = (pa, pa, pa, f32(), f32(), i32(B, L + 1), f32(B, L))
    lowered = jax.jit(train_fn, keep_unused=True).lower(*args)
    ins = (_entries(pa, "params", "param", inits)
           + _entries(pa, "m", "opt_m") + _entries(pa, "v", "opt_v")
           + [{"name": "step", "shape": [], "dtype": "f32", "role": "data"},
              {"name": "lr", "shape": [], "dtype": "f32", "role": "data"},
              {"name": "tokens", "shape": [B, L + 1], "dtype": "i32",
               "role": "data"},
              {"name": "mask", "shape": [B, L], "dtype": "f32",
               "role": "data"}])
    outs = (_entries(pa, "params", "param")
            + _entries(pa, "m", "opt_m") + _entries(pa, "v", "opt_v")
            + [{"name": "loss", "shape": [], "dtype": "f32",
                "role": "metric"}])
    name = f"deltanet_abl_{feature_map}_{key_norm}_{preset}.train"
    meta = dict(kind="train", config=cfg.to_dict(), batch=B, seq_len=L)
    return write_artifact(out_dir, name, lowered, ins, outs, meta)


# ---------------------------------------------------------------------------
# Artifact sets
# ---------------------------------------------------------------------------

def default_set(out_dir):
    """Everything tests, examples and reproduce-harnesses need.
    Each job is (artifact-name, thunk) so --only can filter before building."""
    jobs = []
    for arch in ARCHS:
        jobs.append((f"{arch}_tiny.train",
                     lambda a=arch: build_train(out_dir, a, "tiny")))
        jobs.append((f"{arch}_tiny.eval",
                     lambda a=arch: build_eval(out_dir, a, "tiny")))
    jobs.append(("deltanet_tiny.decode",
                 lambda: build_decode(out_dir, "deltanet", "tiny")))
    jobs.append(("hybrid_swa_tiny.decode",
                 lambda: build_decode(out_dir, "hybrid_swa", "tiny")))
    # small-preset deltanet + key baselines for fig2/fig4-style sweeps
    for arch in ("deltanet", "gla", "mamba2", "transformer"):
        jobs.append((f"{arch}_small.train",
                     lambda a=arch: build_train(out_dir, a, "small")))
        jobs.append((f"{arch}_small.eval",
                     lambda a=arch: build_eval(out_dir, a, "small")))
    jobs.append(("deltanet_small.decode",
                 lambda: build_decode(out_dir, "deltanet", "small")))
    # fig4 long-sequence crossover probes (train-step throughput only)
    for arch in ("deltanet", "gla", "transformer"):
        jobs.append((f"{arch}_long.train",
                     lambda a=arch: build_train(out_dir, a, "long")))
    # fig1: chunkwise vs recurrent kernel grid (B·L = 4096 tokens fixed)
    for L in (256, 512, 1024, 2048, 4096):
        B = 4096 // L
        for d in (32, 64):
            for form in ("chunkwise", "recurrent"):
                jobs.append((f"kernel_{form}_L{L}_d{d}_C64_B{B}",
                             lambda form=form, L=L, d=d, B=B: build_kernel(
                                 out_dir, form, L, d, 64, B)))
    # chunk-size ablation artifacts for the perf study
    for C in (16, 32, 64, 128):
        jobs.append((f"kernel_chunkwise_L1024_d64_C{C}_B4",
                     lambda C=C: build_kernel(
                         out_dir, "chunkwise", 1024, 64, C, 4)))
    # feature-map / norm ablations (paper Table 2, bottom)
    for fm, kn in (("silu", "l1"), ("elu1", "l2"), ("elu1", "l1"),
                   ("relu", "l2")):
        jobs.append((f"deltanet_abl_{fm}_{kn}_tiny.train",
                     lambda fm=fm, kn=kn: build_ablation(out_dir, fm, kn)))
    return jobs


def e2e_set(out_dir):
    return [
        ("deltanet_e2e.train",
         lambda: build_train(out_dir, "deltanet", "e2e")),
        ("deltanet_e2e.eval",
         lambda: build_eval(out_dir, "deltanet", "e2e")),
        ("deltanet_e2e.decode",
         lambda: build_decode(out_dir, "deltanet", "e2e", batch=4)),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="default",
                    choices=["default", "e2e", "all"])
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    jobs = []
    if args.set in ("default", "all"):
        jobs += default_set(args.out)
    if args.set in ("e2e", "all"):
        jobs += e2e_set(args.out)
    if args.only:
        jobs = [(n, j) for n, j in jobs if args.only in n]

    t0 = time.time()
    built = []
    for _, job in jobs:
        built.append(job())
    index_path = os.path.join(args.out, "index.json")
    existing = []
    if os.path.exists(index_path):
        existing = json.load(open(index_path))
    merged = sorted(set(existing) | set(built))
    with open(index_path, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"built {len(built)} artifacts in {time.time()-t0:.0f}s "
          f"→ {args.out}")


if __name__ == "__main__":
    main()
