"""Chunkwise-parallel DeltaNet forward — the paper's core contribution (§3.2).

One fused Pallas kernel sweeps the grid over sequence chunks, carrying the
d_k×d_v state in a revisited output block (never materializing per-step
states — the WY representation keeps everything rank-C inside a chunk):

  per chunk [t]:
    A    = tril(diag(β) K Kᵀ, −1)                 # C×C, strictly lower
    Tmat = (I + A)⁻¹                              # log₂C matmuls (UT transform)
    W    = Tmat diag(β) K        U = Tmat diag(β) V
    U̅    = U − W S                                # fold in inter-chunk state
    O    = Q S + (Q Kᵀ ⊙ M) U̅                     # Eq. 9
    S    ← S + Kᵀ U̅                               # Eq. 8

Hardware adaptation (paper: Triton/H100 → here: Pallas/TPU-shape):
  * the threadblock-per-chunk schedule becomes the Pallas grid over L/C;
  * K/V/Q chunk tiles live in VMEM via BlockSpec; the state S is a revisited
    output block (the TPU grid is sequential, so read-modify-write is sound);
  * everything is expressed as (C×d)·(d×d) / (C×C)·(C×d) matmuls → MXU.
  * interpret=True: the CPU PJRT plugin cannot execute Mosaic custom-calls;
    interpret mode lowers the identical schedule to plain HLO.

VMEM footprint per grid step (fp32): 3·C·d (q,k,v tiles) + C (β) + C·d (o)
+ d·d (state) + ~3·C² (A, Tmat, mask) + 2·C·d (W, U) floats.
For C=64, d=128: ≈ 64·128·6 + 3·4096 + 16384 ≈ 0.33 MiB « 16 MiB VMEM;
C=128, d=256: ≈ 1.4 MiB — comfortably double-bufferable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import wy


def _chunk_kernel(q_ref, k_ref, v_ref, beta_ref, o_ref, s_ref, *, C: int):
    """One grid step = one sequence chunk.  s_ref is the revisited state."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    Q = q_ref[...]                       # [C, d_k]
    K = k_ref[...]                       # [C, d_k]
    V = v_ref[...]                       # [C, d_v]
    beta = beta_ref[...]                 # [C]
    S = s_ref[...]                       # [d_k, d_v]

    Kb = K * beta[:, None]
    A = jnp.tril(jnp.dot(Kb, K.T), -1)                 # C×C
    Tmat = wy.tri_inv_unit_lower(A)                    # (I + A)⁻¹
    W = jnp.dot(Tmat, Kb)                              # [C, d_k]
    U = jnp.dot(Tmat, V * beta[:, None])               # [C, d_v]
    U_bar = U - jnp.dot(W, S)                          # [C, d_v]

    attn = jnp.tril(jnp.dot(Q, K.T))                   # (Q Kᵀ ⊙ M), C×C
    o_ref[...] = jnp.dot(Q, S) + jnp.dot(attn, U_bar)  # Eq. 9
    s_ref[...] = S + jnp.dot(K.T, U_bar)               # Eq. 8


@functools.partial(jax.jit, static_argnames=("chunk_size",))
def delta_chunkwise(q, k, v, beta, chunk_size: int = 64):
    """Chunkwise-parallel DeltaNet forward (Pallas, interpret mode).

    q, k : [L, d_k]   v : [L, d_v]   beta : [L]   L % chunk_size == 0.
    Returns (o [L, d_v], final_state [d_k, d_v]).
    """
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0, f"L={L} must be a multiple of chunk_size={C}"

    o, s = pl.pallas_call(
        functools.partial(_chunk_kernel, C=C),
        grid=(L // C,),
        in_specs=[
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((C,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((d_k, d_v), lambda t: (0, 0)),   # revisited: the carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, d_v), q.dtype),
            jax.ShapeDtypeStruct((d_k, d_v), q.dtype),
        ],
        interpret=True,
    )(q, k, v, beta)
    return o, s


def delta_chunkwise_jnp(q, k, v, beta, chunk_size: int = 64,
                        initial_state=None):
    """The same chunkwise algorithm in plain jnp (lax.scan over chunks).

    Three uses: (1) middle oracle between the step-by-step recurrence and the
    Pallas kernel, (2) the differentiable body for the custom-VJP backward
    (hidden states recomputed chunk-by-chunk, the paper's remat strategy),
    (3) supports a non-zero initial state for chunked prefill.
    """
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0
    n = L // C

    qc = q.reshape(n, C, d_k)
    kc = k.reshape(n, C, d_k)
    vc = v.reshape(n, C, d_v)
    bc = beta.reshape(n, C)

    # Intra-chunk UT transform for every chunk in parallel (vmapped matmuls).
    W, U = jax.vmap(wy.ut_transform)(kc, vc, bc)

    S0 = (jnp.zeros((d_k, d_v), q.dtype)
          if initial_state is None else initial_state)

    def chunk_step(S, inp):
        Qt, Kt, Ut, Wt = inp
        U_bar = Ut - Wt @ S
        attn = jnp.tril(Qt @ Kt.T)
        o = Qt @ S + attn @ U_bar
        S = S + Kt.T @ U_bar
        return S, o

    S, oc = jax.lax.scan(chunk_step, S0, (qc, kc, U, W))
    return oc.reshape(L, d_v), S


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward + recompute backward (custom VJP).
# The backward pass re-runs the jnp chunkwise body under jax.vjp — this is
# exactly the paper's "hidden states recomputed during the backward pass"
# strategy (§3.2, Practical considerations): only (q, k, v, β) are saved.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def delta_chunkwise_ad(q, k, v, beta, chunk_size: int = 64):
    o, _ = delta_chunkwise(q, k, v, beta, chunk_size)
    return o


def _ad_fwd(q, k, v, beta, chunk_size):
    o, _ = delta_chunkwise(q, k, v, beta, chunk_size)
    return o, (q, k, v, beta)


def _ad_bwd(chunk_size, res, g):
    q, k, v, beta = res
    _, vjp = jax.vjp(
        lambda q, k, v, b: delta_chunkwise_jnp(q, k, v, b, chunk_size)[0],
        q, k, v, beta)
    return vjp(g)


delta_chunkwise_ad.defvjp(_ad_fwd, _ad_bwd)
