"""Softmax attention operators for the Transformer++ baseline and hybrids.

Causal full attention and sliding-window attention (SWA), plus a blockwise
(flash-style) Pallas variant of causal attention used when L is large —
same online-softmax restructuring as FlashAttention, expressed as a Pallas
grid over query blocks with an inner lax.fori_loop over key blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # large-but-finite: keeps padded rows NaN-free


def causal_attention(q, k, v, scale=None):
    """Plain causal softmax attention, [L, d] → [L, d_v]."""
    L = q.shape[0]
    scale = (q.shape[-1] ** -0.5) if scale is None else scale
    logits = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((L, L), bool))
    logits = jnp.where(mask, logits, NEG_INF)
    return jax.nn.softmax(logits, axis=-1) @ v


def sliding_window_attention(q, k, v, window: int, scale=None):
    """Causal SWA: position i attends to (i−window, i]."""
    L = q.shape[0]
    scale = (q.shape[-1] ** -0.5) if scale is None else scale
    logits = (q @ k.T) * scale
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    mask = (j <= i) & (j > i - window)
    logits = jnp.where(mask, logits, NEG_INF)
    return jax.nn.softmax(logits, axis=-1) @ v


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block: int, scale: float):
    """Online-softmax causal attention: grid over query blocks, fori_loop
    over key blocks up to the diagonal."""
    qi = pl.program_id(0)
    Q = q_ref[...] * scale                                  # [B, d]
    B, d_v = Q.shape[0], v_ref.shape[-1]

    def body(kj, carry):
        acc, m, l = carry
        K = k_ref[pl.dslice(kj * block, block), :]
        V = v_ref[pl.dslice(kj * block, block), :]
        s = Q @ K.T                                         # [B, B]
        # causal mask on the diagonal block
        row = qi * block + jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
        col = kj * block + jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
        s = jnp.where(col <= row, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        acc = acc * alpha[:, None] + p @ V
        l = l * alpha + p.sum(-1)
        return acc, m_new, l

    acc = jnp.zeros((B, d_v), Q.dtype)
    m = jnp.full((B,), NEG_INF, Q.dtype)
    l = jnp.zeros((B,), Q.dtype)
    acc, m, l = jax.lax.fori_loop(0, qi + 1, body, (acc, m, l))
    o_ref[...] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block",))
def flash_attention(q, k, v, block: int = 64):
    """Blockwise causal attention (Pallas, interpret).  L % block == 0."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    assert L % block == 0
    scale = d_k ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, block=block, scale=scale),
        grid=(L // block,),
        in_specs=[
            pl.BlockSpec((block, d_k), lambda i: (i, 0)),
            pl.BlockSpec((L, d_k), lambda i: (0, 0)),   # full K visible
            pl.BlockSpec((L, d_v), lambda i: (0, 0)),   # full V visible
        ],
        out_specs=pl.BlockSpec((block, d_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, d_v), q.dtype),
        interpret=True,
    )(q, k, v)
