"""Shared WY-representation / UT-transform building blocks (paper §3.2).

The UT transform (Eq. 10–11, sign convention of Listing 1) turns the
intra-chunk recurrences for the pseudo-values u and transition vectors w
into matmuls plus one unit-lower-triangular inverse:

    A    = tril(diag(β) K Kᵀ, −1)            strictly lower, nilpotent
    Tmat = (I + A)⁻¹                          unit lower triangular
    W    = Tmat diag(β) K,   U = Tmat diag(β) V

(the paper's Eq. 10 writes (I − tril(·, −1))⁻¹; its Listing 1 initializes
T = −(K_β Kᵀ) — i.e. the inverse of (I + A) — which is the convention that
matches the recurrences in Eq. 7.  We follow Listing 1 and verify against
the Eq. 7 recurrence directly in pytest.)
"""

from __future__ import annotations

import jax.numpy as jnp


def tri_inv_unit_lower(A):
    """Invert (I + A) for strictly-lower-triangular A ∈ R^{C×C}.

    A is nilpotent (A^C = 0), so

        (I + A)⁻¹ = (I − A)(I + A²)(I + A⁴)(I + A⁸)…

    — ⌈log₂ C⌉ dense C×C matmuls.  This is the matmul-rich ("tensor-core
    friendly") counterpart of the forward-substitution loop in Listing 1;
    on the MXU each factor is one systolic pass.
    """
    C = A.shape[-1]
    eye = jnp.eye(C, dtype=A.dtype)
    X = eye - A
    P = -A  # holds (−A)^(2^i)
    p = 1
    while p < C - 1:
        P = P @ P                  # (−A)^(2^(i+1)) == (A²)^(2^i)
        X = (eye + P) @ X          # all factors are polynomials in A: commute
        p *= 2
    return X


def tri_inv_forward_substitution(A):
    """Reference forward-substitution inverse of (I + A) — the exact loop of
    Listing 1 (row i updated from rows < i).  O(C) sequential steps; used as
    an oracle for tri_inv_unit_lower and in the recurrent-form kernel."""
    C = A.shape[-1]
    T = -A
    for i in range(1, C):
        # T[i, :i] += Σ_{j<i} T[i, j] · T[j, :i]
        T = T.at[i, :i].add(T[i, :i] @ T[:i, :i])
    return T + jnp.eye(C, dtype=A.dtype)


def ut_transform(K, V, beta, tri_inv=tri_inv_unit_lower):
    """UT transform for one chunk: returns (W, U) with

        w_r = β_r (k_r − Σ_{i<r} (k_iᵀ k_r) w_i)
        u_r = β_r (v_r − Σ_{i<r} (k_iᵀ k_r) u_i)

    K : [C, d_k], V : [C, d_v], beta : [C].
    """
    Kb = K * beta[:, None]
    A = jnp.tril(Kb @ K.T, -1)
    Tmat = tri_inv(A)
    W = Tmat @ Kb
    U = Tmat @ (V * beta[:, None])
    return W, U


def causal_mask(C, dtype):
    """Lower-triangular (inclusive) mask as dtype — M_C in Eq. 2/9."""
    return jnp.tril(jnp.ones((C, C), dtype))
