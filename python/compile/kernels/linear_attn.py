"""Chunkwise vanilla linear attention (Eq. 1–2) as a Pallas kernel.

The simplest member of the family (Katharopoulos et al. 2020, unnormalized
form): S_{[t+1]} = S_{[t]} + K_{[t]}ᵀ V_{[t]},
O_{[t]} = Q_{[t]} S_{[t]} + (Q_{[t]} K_{[t]}ᵀ ⊙ M) V_{[t]}.
DeltaNet degenerates to this when the WY correction vanishes (orthogonal
keys within a chunk and β ≡ 1 wrt state read-out is *not* identical — see
tests for the exact relationship; this kernel is the baseline row in the
family table, not an approximation of DeltaNet).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_kernel(q_ref, k_ref, v_ref, o_ref, s_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    Q = q_ref[...]
    K = k_ref[...]
    V = v_ref[...]
    S = s_ref[...]

    attn = jnp.tril(jnp.dot(Q, K.T))
    o_ref[...] = jnp.dot(Q, S) + jnp.dot(attn, V)
    s_ref[...] = S + jnp.dot(K.T, V)


@functools.partial(jax.jit, static_argnames=("chunk_size",))
def linear_attn_chunkwise(q, k, v, chunk_size: int = 64):
    """q, k : [L, d_k]  v : [L, d_v];  returns (o, final_state)."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0

    o, s = pl.pallas_call(
        _chunk_kernel,
        grid=(L // C,),
        in_specs=[
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((d_k, d_v), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, d_v), q.dtype),
            jax.ShapeDtypeStruct((d_k, d_v), q.dtype),
        ],
        interpret=True,
    )(q, k, v)
    return o, s


def linear_attn_chunkwise_jnp(q, k, v, chunk_size: int = 64,
                              initial_state=None):
    """Plain-jnp twin (scan over chunks) — oracle + custom-VJP bwd body."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0
    n = L // C
    qc, kc = q.reshape(n, C, d_k), k.reshape(n, C, d_k)
    vc = v.reshape(n, C, d_v)
    S0 = (jnp.zeros((d_k, d_v), q.dtype)
          if initial_state is None else initial_state)

    def chunk_step(S, inp):
        Qt, Kt, Vt = inp
        o = Qt @ S + jnp.tril(Qt @ Kt.T) @ Vt
        return S + Kt.T @ Vt, o

    S, oc = jax.lax.scan(chunk_step, S0, (qc, kc, vc))
    return oc.reshape(L, d_v), S


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_attn_ad(q, k, v, chunk_size: int = 64):
    """Differentiable wrapper: Pallas forward, recompute-jnp backward."""
    return linear_attn_chunkwise(q, k, v, chunk_size)[0]


def _la_fwd(q, k, v, chunk_size):
    return linear_attn_chunkwise(q, k, v, chunk_size)[0], (q, k, v)


def _la_bwd(chunk_size, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: linear_attn_chunkwise_jnp(q, k, v, chunk_size)[0],
        q, k, v)
    return vjp(g)


linear_attn_ad.defvjp(_la_fwd, _la_bwd)
