"""Chunkwise scalar-decay linear attention — shared kernel for two baselines:

  * RetNet (Sun et al. 2023):  S_t = γ S_{t-1} + k_t v_tᵀ, γ fixed per head
  * Mamba-2 (Dao & Gu 2024):   S_t = γ_t S_{t-1} + k_t v_tᵀ, γ_t = f(x_t)

Both are the α_t = γ_t·1 specialization of GLA, but the scalar structure
admits a cheaper kernel (decay enters as a C-vector, not a C×d_k matrix):

  Λ_r  = ∏_{i≤r} γ_i
  o_r  = Λ_r (q_r S₀) + Σ_{j≤r} (Λ_r/Λ_j)(q_r·k_j) v_j
  S_C  = Λ_C S₀ + Σ_j (Λ_C/Λ_j) k_j v_jᵀ
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_kernel(q_ref, k_ref, v_ref, g_ref, o_ref, s_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    Q = q_ref[...]
    K = k_ref[...]
    V = v_ref[...]
    g = g_ref[...]                        # [C]
    S = s_ref[...]

    lam = jnp.cumprod(g)                  # [C], Λ_r inclusive
    lam_C = lam[-1]

    # decay ratio matrix D_rj = Λ_r/Λ_j for j ≤ r, 0 otherwise
    attn = jnp.dot(Q, K.T) * jnp.tril(lam[:, None] / lam[None, :])
    o_ref[...] = lam[:, None] * jnp.dot(Q, S) + jnp.dot(attn, V)
    s_ref[...] = lam_C * S + jnp.dot((K * (lam_C / lam)[:, None]).T, V)


@functools.partial(jax.jit, static_argnames=("chunk_size",))
def scalar_decay_chunkwise(q, k, v, gamma, chunk_size: int = 64):
    """q, k : [L, d_k]  v : [L, d_v]  gamma : [L] ∈ (0,1].
    RetNet: pass gamma = γ·ones(L).  Mamba-2: gamma = σ-gated per token.
    Returns (o [L, d_v], final_state [d_k, d_v])."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0

    o, s = pl.pallas_call(
        _chunk_kernel,
        grid=(L // C,),
        in_specs=[
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((C,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((d_k, d_v), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, d_v), q.dtype),
            jax.ShapeDtypeStruct((d_k, d_v), q.dtype),
        ],
        interpret=True,
    )(q, k, v, gamma)
    return o, s


def scalar_decay_chunkwise_jnp(q, k, v, gamma, chunk_size: int = 64,
                               initial_state=None):
    """Plain-jnp twin (scan over chunks) — oracle + custom-VJP bwd body."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0
    n = L // C
    qc, kc = q.reshape(n, C, d_k), k.reshape(n, C, d_k)
    vc, gc = v.reshape(n, C, d_v), gamma.reshape(n, C)
    S0 = (jnp.zeros((d_k, d_v), q.dtype)
          if initial_state is None else initial_state)

    def chunk_step(S, inp):
        Qt, Kt, Vt, gt = inp
        lam = jnp.cumprod(gt)
        lam_C = lam[-1]
        attn = (Qt @ Kt.T) * jnp.tril(lam[:, None] / lam[None, :])
        o = lam[:, None] * (Qt @ S) + attn @ Vt
        S = lam_C * S + (Kt * (lam_C / lam)[:, None]).T @ Vt
        return S, o

    S, oc = jax.lax.scan(chunk_step, S0, (qc, kc, vc, gc))
    return oc.reshape(L, d_v), S


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def scalar_decay_ad(q, k, v, gamma, chunk_size: int = 64):
    """Differentiable wrapper: Pallas forward, recompute-jnp backward."""
    return scalar_decay_chunkwise(q, k, v, gamma, chunk_size)[0]


def _sd_fwd(q, k, v, gamma, chunk_size):
    return (scalar_decay_chunkwise(q, k, v, gamma, chunk_size)[0],
            (q, k, v, gamma))


def _sd_bwd(chunk_size, res, g):
    q, k, v, gamma = res
    _, vjp = jax.vjp(
        lambda q, k, v, gm:
        scalar_decay_chunkwise_jnp(q, k, v, gm, chunk_size)[0],
        q, k, v, gamma)
    return vjp(g)


scalar_decay_ad.defvjp(_sd_fwd, _sd_bwd)
