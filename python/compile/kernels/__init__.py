"""L1 — Pallas kernels (build-time only; lowered into HLO by compile.aot).

Public surface:
    delta_chunkwise       — the paper's chunkwise-parallel DeltaNet forward
    delta_chunkwise_ad    — custom-VJP wrapper (recompute backward)
    delta_chunkwise_jnp   — same algorithm, plain jnp (oracle / bwd body)
    delta_recurrent       — token-by-token DeltaNet (Fig. 1 baseline)
    linear_attn_chunkwise — vanilla linear attention (Eq. 1–2)
    gla_chunkwise         — gated linear attention baseline
    scalar_decay_chunkwise— RetNet / Mamba-2 baseline
    causal_attention, sliding_window_attention, flash_attention
    ref                   — step-by-step oracles for all of the above
"""

from .attention import (causal_attention, flash_attention,
                        sliding_window_attention)
from .delta_chunkwise import (delta_chunkwise, delta_chunkwise_ad,
                              delta_chunkwise_jnp)
from .delta_recurrent import delta_recurrent
from .gla import gla_ad, gla_chunkwise, gla_chunkwise_jnp
from .linear_attn import (linear_attn_ad, linear_attn_chunkwise,
                          linear_attn_chunkwise_jnp)
from .scalar_decay import (scalar_decay_ad, scalar_decay_chunkwise,
                           scalar_decay_chunkwise_jnp)
from . import ref, wy

__all__ = [
    "delta_chunkwise", "delta_chunkwise_ad", "delta_chunkwise_jnp",
    "delta_recurrent",
    "linear_attn_chunkwise", "linear_attn_chunkwise_jnp", "linear_attn_ad",
    "gla_chunkwise", "gla_chunkwise_jnp", "gla_ad",
    "scalar_decay_chunkwise", "scalar_decay_chunkwise_jnp",
    "scalar_decay_ad",
    "causal_attention", "flash_attention", "sliding_window_attention",
    "ref", "wy",
]
