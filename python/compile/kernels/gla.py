"""Chunkwise Gated Linear Attention (Yang et al. 2023) — baseline kernel.

Recurrence: S_t = diag(α_t) S_{t-1} + k_t v_tᵀ with per-channel,
data-dependent decay α_t ∈ (0,1)^{d_k}.  Chunkwise form with the standard
secondary-chunking-free cumprod trick:

  Λ_r  = ∏_{i≤r} α_i                       (inclusive cumulative decay)
  o_r  = (q_r ⊙ Λ_r) S₀ + Σ_{j≤r} ((q_r⊙Λ_r)·(k_j/Λ_j)) v_j
  S_C  = diag(Λ_C) S₀ + Σ_j (k_j ⊙ Λ_C/Λ_j) v_jᵀ

The k/Λ division is numerically safe for α bounded away from 0 and C
moderate (the model layer lower-bounds α; see layers.gla_gate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_kernel(q_ref, k_ref, v_ref, a_ref, o_ref, s_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    Q = q_ref[...]
    K = k_ref[...]
    V = v_ref[...]
    alpha = a_ref[...]                    # [C, d_k]
    S = s_ref[...]

    lam = jnp.cumprod(alpha, axis=0)      # Λ_r, inclusive
    lam_C = lam[-1]
    q_t = Q * lam
    k_div = K / lam
    k_scl = K * (lam_C / lam)

    attn = jnp.tril(jnp.dot(q_t, k_div.T))
    o_ref[...] = jnp.dot(q_t, S) + jnp.dot(attn, V)
    s_ref[...] = lam_C[:, None] * S + jnp.dot(k_scl.T, V)


@functools.partial(jax.jit, static_argnames=("chunk_size",))
def gla_chunkwise(q, k, v, alpha, chunk_size: int = 64):
    """q, k : [L, d_k]  v : [L, d_v]  alpha : [L, d_k] ∈ (0,1).
    Returns (o [L, d_v], final_state [d_k, d_v])."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0

    o, s = pl.pallas_call(
        _chunk_kernel,
        grid=(L // C,),
        in_specs=[
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((C, d_k), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_v), lambda t: (t, 0)),
            pl.BlockSpec((d_k, d_v), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, d_v), q.dtype),
            jax.ShapeDtypeStruct((d_k, d_v), q.dtype),
        ],
        interpret=True,
    )(q, k, v, alpha)
    return o, s


def gla_chunkwise_jnp(q, k, v, alpha, chunk_size: int = 64,
                      initial_state=None):
    """Plain-jnp twin (scan over chunks) — oracle + custom-VJP bwd body."""
    L, d_k = q.shape
    d_v = v.shape[-1]
    C = chunk_size
    assert L % C == 0
    n = L // C
    qc, kc = q.reshape(n, C, d_k), k.reshape(n, C, d_k)
    vc, ac = v.reshape(n, C, d_v), alpha.reshape(n, C, d_k)
    S0 = (jnp.zeros((d_k, d_v), q.dtype)
          if initial_state is None else initial_state)

    def chunk_step(S, inp):
        Qt, Kt, Vt, At = inp
        lam = jnp.cumprod(At, axis=0)
        lam_C = lam[-1]
        q_t = Qt * lam
        o = q_t @ S + jnp.tril(q_t @ (Kt / lam).T) @ Vt
        S = lam_C[:, None] * S + (Kt * (lam_C / lam)).T @ Vt
        return S, o

    S, oc = jax.lax.scan(chunk_step, S0, (qc, kc, vc, ac))
    return oc.reshape(L, d_v), S


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def gla_ad(q, k, v, alpha, chunk_size: int = 64):
    """Differentiable wrapper: Pallas forward, recompute-jnp backward."""
    return gla_chunkwise(q, k, v, alpha, chunk_size)[0]


def _gla_fwd(q, k, v, alpha, chunk_size):
    return gla_chunkwise(q, k, v, alpha, chunk_size)[0], (q, k, v, alpha)


def _gla_bwd(chunk_size, res, g):
    q, k, v, alpha = res
    _, vjp = jax.vjp(
        lambda q, k, v, a: gla_chunkwise_jnp(q, k, v, a, chunk_size)[0],
        q, k, v, alpha)
    return vjp(g)


gla_ad.defvjp(_gla_fwd, _gla_bwd)
