"""Pure-jnp correctness oracles for every L1 kernel.

Every operator in this library has a step-by-step recurrent oracle here,
written for clarity (lax.scan over time steps, explicit state updates).
pytest compares the Pallas/chunkwise implementations against these — this is
the CORE correctness signal of the whole stack.

Conventions (single head; batching/heads are vmapped at L2):
  q, k : [L, d_k]      v : [L, d_v]      beta/gamma : [L]
  State S : [d_k, d_v] (row convention, as in the paper's Listing 1):
      o_t = q_t @ S_t
      paper's S_t = S_{t-1}(I − β k kᵀ) + β v kᵀ  becomes, transposed,
      S_t = (I − β k kᵀ) S_{t-1} + β k v_tᵀ.
All recurrent oracles return (outputs [L, d_v], final_state [d_k, d_v]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_rule_recurrent(q, k, v, beta, initial_state=None):
    """DeltaNet (Schlag et al. 2021) — the delta-rule recurrence, step by step.

    Retrieval/update form: v_old = S k; v_new = β v + (1−β) v_old;
    S ← S − k v_oldᵀ + k v_newᵀ (in [d_k, d_v] layout)."""
    d_k, d_v = q.shape[-1], v.shape[-1]
    S0 = jnp.zeros((d_k, d_v), q.dtype) if initial_state is None else initial_state

    def step(S, qkvb):
        q_t, k_t, v_t, b_t = qkvb
        v_old = k_t @ S                      # [d_v]
        v_new = b_t * v_t + (1.0 - b_t) * v_old
        S = S - jnp.outer(k_t, v_old) + jnp.outer(k_t, v_new)
        o_t = q_t @ S
        return S, o_t

    S, o = jax.lax.scan(step, S0, (q, k, v, beta))
    return o, S


def linear_attn_recurrent(q, k, v, initial_state=None):
    """Vanilla (unnormalized) linear attention: S_t = S_{t-1} + k_t v_tᵀ."""
    d_k, d_v = q.shape[-1], v.shape[-1]
    S0 = jnp.zeros((d_k, d_v), q.dtype) if initial_state is None else initial_state

    def step(S, qkv):
        q_t, k_t, v_t = qkv
        S = S + jnp.outer(k_t, v_t)
        return S, q_t @ S

    S, o = jax.lax.scan(step, S0, (q, k, v))
    return o, S


def gla_recurrent(q, k, v, alpha, initial_state=None):
    """Gated linear attention (Yang et al. 2023): S_t = diag(α_t) S_{t-1} + k_t v_tᵀ.

    alpha : [L, d_k], per-channel data-dependent decay in (0, 1)."""
    d_k, d_v = q.shape[-1], v.shape[-1]
    S0 = jnp.zeros((d_k, d_v), q.dtype) if initial_state is None else initial_state

    def step(S, qkva):
        q_t, k_t, v_t, a_t = qkva
        S = a_t[:, None] * S + jnp.outer(k_t, v_t)
        return S, q_t @ S

    S, o = jax.lax.scan(step, S0, (q, k, v, alpha))
    return o, S


def retnet_recurrent(q, k, v, gamma, initial_state=None):
    """RetNet (Sun et al. 2023): S_t = γ S_{t-1} + k_t v_tᵀ, fixed scalar γ."""
    d_k, d_v = q.shape[-1], v.shape[-1]
    S0 = jnp.zeros((d_k, d_v), q.dtype) if initial_state is None else initial_state

    def step(S, qkv):
        q_t, k_t, v_t = qkv
        S = gamma * S + jnp.outer(k_t, v_t)
        return S, q_t @ S

    S, o = jax.lax.scan(step, S0, (q, k, v))
    return o, S


def mamba2_recurrent(q, k, v, gamma, initial_state=None):
    """Mamba-2-style (Dao & Gu 2024): S_t = γ_t S_{t-1} + k_t v_tᵀ,
    data-dependent scalar decay γ_t ∈ (0, 1) per step.  gamma : [L]."""
    d_k, d_v = q.shape[-1], v.shape[-1]
    S0 = jnp.zeros((d_k, d_v), q.dtype) if initial_state is None else initial_state

    def step(S, qkvg):
        q_t, k_t, v_t, g_t = qkvg
        S = g_t * S + jnp.outer(k_t, v_t)
        return S, q_t @ S

    S, o = jax.lax.scan(step, S0, (q, k, v, gamma))
    return o, S


def softmax_attention(q, k, v, scale=None):
    """Causal softmax attention (single head). Returns [L, d_v]."""
    L = q.shape[0]
    scale = (q.shape[-1] ** -0.5) if scale is None else scale
    logits = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((L, L), bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    return jax.nn.softmax(logits, axis=-1) @ v


def sliding_window_attention(q, k, v, window, scale=None):
    """Causal sliding-window attention: position i attends to [i−window+1, i]."""
    L = q.shape[0]
    scale = (q.shape[-1] ** -0.5) if scale is None else scale
    logits = (q @ k.T) * scale
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    mask = (j <= i) & (j > i - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    return jax.nn.softmax(logits, axis=-1) @ v


def delta_rule_wy(q, k, v, beta, initial_state=None):
    """DeltaNet via the *sequential* WY recurrence (Eq. 3 / Eq. 7 with one
    chunk = the whole sequence).  Middle oracle: validates the WY
    reparameterization u_t = β_t (v_t − Σ_{i<t} (k_iᵀ k_t) u_i) and
    w_t = β_t (k_t − Σ_{i<t} (k_iᵀ k_t) w_i) independently of chunking."""
    L, d_k = k.shape
    d_v = v.shape[-1]
    S0 = jnp.zeros((d_k, d_v), q.dtype) if initial_state is None else initial_state

    def step(carry, t):
        u_acc, w_acc = carry                              # rows < t are valid
        kkt = k @ k[t]                                    # [L]
        mask = (jnp.arange(L) < t)
        corr_u = (u_acc * jnp.where(mask, kkt, 0.0)[:, None]).sum(0)
        corr_w = (w_acc * jnp.where(mask, kkt, 0.0)[:, None]).sum(0)
        u_acc = u_acc.at[t].set(beta[t] * (v[t] - corr_u))
        w_acc = w_acc.at[t].set(beta[t] * (k[t] - corr_w))
        return (u_acc, w_acc), None

    (u, w), _ = jax.lax.scan(
        step,
        (jnp.zeros((L, d_v), q.dtype), jnp.zeros((L, d_k), q.dtype)),
        jnp.arange(L))

    # With initial state: S_L = S0 P + H  ⇒  u̅ = u − W S0 (rows).
    u_bar = u - w @ S0
    mask = jnp.tril(jnp.ones((L, L), bool))
    attn = jnp.where(mask, q @ k.T, 0.0)
    o = q @ S0 + attn @ u_bar
    S = S0 + k.T @ u_bar
    return o, S


def delta_attention_matrix(q, k, beta):
    """The paper's fully-parallel-form 'attention matrix' (§3.2):
    A = (QKᵀ ⊙ M) T with T = (I + tril(diag(β)KKᵀ, −1))⁻¹ diag(β).
    A_ij is the weight of v_j in o_i.  O(L³) — interpretability tooling."""
    L = q.shape[0]
    kb = k * beta[:, None]
    A_strict = jnp.tril(kb @ k.T, -1)
    Tmat = jnp.linalg.inv(jnp.eye(L, dtype=q.dtype) + A_strict) * beta[None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, q @ k.T, 0.0) @ Tmat


# ---------------------------------------------------------------------------
# Single-token decode steps (used by the L2 decode_step artifacts and tests).
# Each takes (S, q_t, k_t, v_t, ...) and returns (o_t, S_new).
# ---------------------------------------------------------------------------

def delta_step(S, q_t, k_t, v_t, b_t):
    v_old = k_t @ S
    v_new = b_t * v_t + (1.0 - b_t) * v_old
    S = S + jnp.outer(k_t, v_new - v_old)
    return q_t @ S, S


def linear_attn_step(S, q_t, k_t, v_t):
    S = S + jnp.outer(k_t, v_t)
    return q_t @ S, S


def gla_step(S, q_t, k_t, v_t, a_t):
    S = a_t[:, None] * S + jnp.outer(k_t, v_t)
    return q_t @ S, S


def scalar_decay_step(S, q_t, k_t, v_t, g_t):
    """Shared by RetNet (fixed γ) and Mamba-2 (data-dependent γ_t)."""
    S = g_t * S + jnp.outer(k_t, v_t)
    return q_t @ S, S
