"""Recurrent-form DeltaNet forward as a Pallas kernel (the paper's baseline).

This is the form the original Schlag et al. (2021) implementation used: one
grid step per *token*, state carried across steps.  It exists to reproduce
Figure 1 (chunkwise-parallel vs recurrent speedup): the recurrent form does
O(L) sequential steps of rank-1 (outer-product) work — no matmul richness,
no sequence-level parallelism — while the chunkwise kernel does O(L/C) steps
of dense-matmul work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _token_kernel(q_ref, k_ref, v_ref, beta_ref, o_ref, s_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q_t = q_ref[...].reshape(-1)         # [d_k]
    k_t = k_ref[...].reshape(-1)         # [d_k]
    v_t = v_ref[...].reshape(-1)         # [d_v]
    b_t = beta_ref[...].reshape(())      # scalar
    S = s_ref[...]                       # [d_k, d_v]

    v_old = k_t @ S                      # retrieve:  S_{t-1} k_t
    v_new = b_t * v_t + (1.0 - b_t) * v_old
    S = S + jnp.outer(k_t, v_new - v_old)
    o_ref[...] = (q_t @ S).reshape(o_ref.shape)
    s_ref[...] = S


@jax.jit
def delta_recurrent(q, k, v, beta):
    """Token-by-token DeltaNet forward (Pallas, interpret mode).

    q, k : [L, d_k]   v : [L, d_v]   beta : [L].
    Returns (o [L, d_v], final_state [d_k, d_v]).
    """
    L, d_k = q.shape
    d_v = v.shape[-1]

    o, s = pl.pallas_call(
        _token_kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, d_k), lambda t: (t, 0)),
            pl.BlockSpec((1, d_k), lambda t: (t, 0)),
            pl.BlockSpec((1, d_v), lambda t: (t, 0)),
            pl.BlockSpec((1,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((1, d_v), lambda t: (t, 0)),
            pl.BlockSpec((d_k, d_v), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, d_v), q.dtype),
            jax.ShapeDtypeStruct((d_k, d_v), q.dtype),
        ],
        interpret=True,
    )(q, k, v, beta)
    return o, s
