"""L2 building blocks: norms, FFN, short convolution, rotary, feature maps.

Everything is a pure function over explicit parameter dicts (no flax/haiku)
so that the parameter pytree ↔ manifest mapping stays trivial for the Rust
side, which constructs and owns the actual parameter buffers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, g, eps: float = 1e-6):
    """RMSNorm over the last axis with learned gain g."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def swiglu_ffn(x, p):
    """SwiGLU feed-forward (Shazeer 2020): down(silu(gate(x)) * up(x)).
    p: {w_gate [d,f], w_up [d,f], w_down [f,d]} — the paper's 8d² block
    when f = 8d/3·… (we use f = 8d/3 rounded to a multiple of 64)."""
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def short_conv(x, w):
    """Depthwise causal short convolution (Mamba-style, §3.4), kernel size
    K: y_t = Σ_{j=0..K-1} w_j · x_{t-K+1+j}, per channel, then SiLU.

    x : [L, d]   w : [K, d].  Expressed as K shifted multiplies — cheap,
    differentiable, and trivially fusable by XLA."""
    K = w.shape[0]
    y = jnp.zeros_like(x)
    for j in range(K):
        shift = K - 1 - j  # how far in the past tap j looks
        xs = jnp.pad(x, ((shift, 0), (0, 0)))[: x.shape[0]]
        y = y + xs * w[j]
    return jax.nn.silu(y)


def short_conv_step(state, x_t, w):
    """Single-token short conv for decoding.  state : [K-1, d] holds the
    previous K-1 inputs (oldest first); returns (y_t, new_state)."""
    K = w.shape[0]
    window = jnp.concatenate([state, x_t[None]], axis=0)      # [K, d]
    y_t = (window * w).sum(0)
    return jax.nn.silu(y_t), window[1:]


def rotary(x, pos0: int = 0, base: float = 10000.0):
    """Rotary position embedding over the last axis. x : [L, d] (d even)."""
    L, d = x.shape
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    t = (jnp.arange(L, dtype=jnp.float32) + pos0)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(t), jnp.sin(t)
    x1, x2 = x[:, :half], x[:, half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def feature_map(x, kind: str):
    """Query/key nonlinearity φ (§3.3 ablation: {SiLU, ReLU, 1+ELU})."""
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "elu1":
        return jax.nn.elu(x) + 1.0
    if kind == "identity":
        return x
    raise ValueError(f"unknown feature map {kind!r}")


def key_normalize(x, kind: str, eps: float = 1e-6):
    """Key/query normalization (§3.3 ablation: L2 vs L1).  L2 makes
    I − βkkᵀ an exact projection at β=1; L1 is the Schlag et al. choice."""
    if kind == "l2":
        return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)
    if kind == "l1":
        return x / (jnp.abs(x).sum(-1, keepdims=True) + eps)
    if kind == "none":
        return x
    raise ValueError(f"unknown key norm {kind!r}")


def retnet_gammas(n_heads: int):
    """RetNet's fixed per-head decay: γ_h = 1 − 2^(−5−h)."""
    return 1.0 - 2.0 ** (-5.0 - jnp.arange(n_heads, dtype=jnp.float32))
