"""L2 — the DeltaNet transformer and all baseline architectures (§3.3–3.4).

LLaMA-style (Transformer++) blocks: pre-RMSNorm, token mixer (4d²), SwiGLU
FFN (8d²).  The token mixer is pluggable per layer:

    deltanet  — the paper's layer: SiLU+L2-norm q/k, σ writing strength β,
                chunkwise-parallel delta-rule kernel (Pallas)
    gla       — gated linear attention (per-channel data-dependent decay)
    retnet    — fixed per-head exponential decay
    mamba2    — scalar data-dependent decay
    linattn   — vanilla linear attention
    attn      — causal softmax attention + rotary (Transformer++ / hybrids)
    swa       — sliding-window attention + rotary (hybrids)

Hybrid layouts (§3.4): `hybrid_swa` interleaves deltanet/swa every other
layer; `hybrid_global` replaces layer 2 and layer N/2+1 with global attn.

Parameters are a FLAT dict {dotted-name: array}; sorted-key order is the
manifest order the Rust side relies on.  All functions are pure.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from . import layers
from .kernels import (delta_chunkwise, delta_chunkwise_ad,
                      gla_chunkwise, gla_ad,
                      linear_attn_chunkwise, linear_attn_ad,
                      scalar_decay_chunkwise, scalar_decay_ad,
                      causal_attention, sliding_window_attention, ref)

Params = Dict[str, jnp.ndarray]

LINEAR_MIXERS = ("deltanet", "gla", "retnet", "mamba2", "linattn")
ATTN_MIXERS = ("attn", "swa")


@dataclasses.dataclass
class ModelConfig:
    vocab_size: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    # architecture: one of deltanet/gla/retnet/mamba2/linattn/transformer/
    # hybrid_swa/hybrid_global — expanded to a per-layer mixer list
    arch: str = "deltanet"
    use_conv: bool = True
    conv_size: int = 4
    feature_map: str = "silu"     # silu | relu | elu1 | identity
    key_norm: str = "l2"          # l2 | l1 | none
    chunk_size: int = 16
    swa_window: int = 32
    max_seq_len: int = 256        # decode-time KV-cache bound for attn layers
    ffn_mult: float = 8.0 / 3.0   # SwiGLU hidden = ffn_mult * d (→ 8d² FLOPs)

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def ffn_dim(self) -> int:
        f = int(self.d_model * self.ffn_mult)
        return max(64, (f + 63) // 64 * 64)

    def mixers(self) -> List[str]:
        """Expand `arch` into the per-layer mixer list."""
        n = self.n_layers
        if self.arch == "transformer":
            return ["attn"] * n
        if self.arch in LINEAR_MIXERS:
            return [self.arch] * n
        if self.arch == "hybrid_swa":
            # Griffin/Samba-style interleave: delta, swa, delta, swa, ...
            return ["deltanet" if i % 2 == 0 else "swa" for i in range(n)]
        if self.arch == "hybrid_global":
            # H3-style: global attention at layer index 1 and N//2 + 1
            attn_at = {1, n // 2 + 1} if n > 2 else {1}
            return ["attn" if i in attn_at else "deltanet" for i in range(n)]
        raise ValueError(f"unknown arch {self.arch!r}")

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


# ---------------------------------------------------------------------------
# Parameter specification — single source of truth for shapes + init.
# Rust initializes buffers from the manifest generated off this spec.
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """Returns ordered list of (name, shape, init) for all parameters.
    init ∈ {"normal:<std>", "zeros", "ones", "const:<v>"}."""
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    hd = H * dh
    spec = [("embed", (cfg.vocab_size, d), "normal:0.02")]
    proj_init = f"normal:{0.02}"
    out_init = f"normal:{0.02 / (2 * cfg.n_layers) ** 0.5}"  # GPT-2-style
    for i, mixer in enumerate(cfg.mixers()):
        L = f"L{i:02d}"
        spec += [(f"{L}.norm1", (d,), "ones"), (f"{L}.norm2", (d,), "ones")]
        spec += [
            (f"{L}.mix.wq", (d, hd), proj_init),
            (f"{L}.mix.wk", (d, hd), proj_init),
            (f"{L}.mix.wv", (d, hd), proj_init),
            (f"{L}.mix.wo", (hd, d), out_init),
        ]
        if mixer in LINEAR_MIXERS:
            spec += [(f"{L}.mix.onorm", (hd,), "ones")]
            if cfg.use_conv:
                for s in ("q", "k", "v"):
                    spec += [(f"{L}.mix.conv_{s}", (cfg.conv_size, hd),
                              f"normal:{1.0 / cfg.conv_size}")]
        if mixer == "deltanet":
            spec += [(f"{L}.mix.wbeta", (d, H), proj_init),
                     (f"{L}.mix.bbeta", (H,), "zeros")]
        elif mixer == "gla":
            spec += [(f"{L}.mix.walpha", (d, hd), proj_init),
                     (f"{L}.mix.balpha", (hd,), "const:2.0")]
        elif mixer == "mamba2":
            spec += [(f"{L}.mix.wgamma", (d, H), proj_init),
                     (f"{L}.mix.bgamma", (H,), "const:2.0")]
        f = cfg.ffn_dim
        spec += [
            (f"{L}.ffn.w_gate", (d, f), proj_init),
            (f"{L}.ffn.w_up", (d, f), proj_init),
            (f"{L}.ffn.w_down", (f, d), out_init),
        ]
    spec += [("final_norm", (d,), "ones")]
    # sorted-by-name: the exact order jax.jit flattens a flat dict, which is
    # the order the manifest (and hence the Rust runtime) relies on
    return sorted(spec, key=lambda e: e[0])


def init_params(cfg: ModelConfig, key) -> Params:
    """Reference initializer (tests + aot sanity; Rust owns the real init)."""
    params = {}
    for name, shape, init in param_spec(cfg):
        key, sub = jax.random.split(key)
        if init.startswith("normal:"):
            std = float(init.split(":")[1])
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
        elif init == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        elif init.startswith("const:"):
            params[name] = jnp.full(shape, float(init.split(":")[1]),
                                    jnp.float32)
        else:
            raise ValueError(init)
    return params


# ---------------------------------------------------------------------------
# Token mixers (single sequence [L, d]; batch is vmapped at the top).
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, prefix, x, conv: bool):
    """q/k/v projections with optional short conv, reshaped to [H, L, dh]."""
    L = x.shape[0]
    H, dh = cfg.n_heads, cfg.head_dim
    out = []
    for s, w in (("q", "wq"), ("k", "wk"), ("v", "wv")):
        h = x @ p[f"{prefix}.{w}"]
        if conv:
            h = layers.short_conv(h, p[f"{prefix}.conv_{s}"])
        out.append(h.reshape(L, H, dh).transpose(1, 0, 2))
    return out  # each [H, L, dh]


def _head_rms(o, g, H, dh):
    """Per-head RMSNorm before the output projection (§3.3 stability)."""
    gh = g.reshape(H, 1, dh)
    var = jnp.mean(jnp.square(o), axis=-1, keepdims=True)
    return o * jax.lax.rsqrt(var + 1e-6) * gh


def mixer_forward(cfg: ModelConfig, mixer: str, p: Params, prefix: str, x,
                  differentiable: bool = True):
    """One token-mixing layer.  x : [L, d] → [L, d]."""
    L = x.shape[0]
    H, dh = cfg.n_heads, cfg.head_dim
    C = cfg.chunk_size

    if mixer in ATTN_MIXERS:
        q, k, v = _project_qkv(cfg, p, prefix, x, conv=False)
        q = jax.vmap(layers.rotary)(q)
        k = jax.vmap(layers.rotary)(k)
        if mixer == "attn":
            o = jax.vmap(causal_attention)(q, k, v)
        else:
            o = jax.vmap(lambda q, k, v: sliding_window_attention(
                q, k, v, cfg.swa_window))(q, k, v)
        o = o.transpose(1, 0, 2).reshape(L, H * dh)
        return o @ p[f"{prefix}.wo"]

    q, k, v = _project_qkv(cfg, p, prefix, x, conv=cfg.use_conv)

    # pad the sequence up to a chunk multiple (padding is causal-safe: it
    # sits at the end, and pad β=0 / decay=1 leaves the state untouched)
    Lp = (L + C - 1) // C * C
    pad = Lp - L
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (q, k, v))

    if mixer == "deltanet":
        q = layers.key_normalize(layers.feature_map(q, cfg.feature_map),
                                 cfg.key_norm)
        k = layers.key_normalize(layers.feature_map(k, cfg.feature_map),
                                 cfg.key_norm)
        beta = jax.nn.sigmoid(
            x @ p[f"{prefix}.wbeta"] + p[f"{prefix}.bbeta"]).T    # [H, L]
        if pad:
            beta = jnp.pad(beta, ((0, 0), (0, pad)))              # β=0: no-op
        if differentiable:
            o = jax.vmap(lambda q, k, v, b:
                         delta_chunkwise_ad(q, k, v, b, C))(q, k, v, beta)
        else:
            o = jax.vmap(lambda q, k, v, b:
                         delta_chunkwise(q, k, v, b, C)[0])(q, k, v, beta)
    elif mixer == "gla":
        q = layers.feature_map(q, cfg.feature_map) * dh ** -0.5
        k = layers.feature_map(k, cfg.feature_map)
        alpha = jax.nn.sigmoid(
            x @ p[f"{prefix}.walpha"] + p[f"{prefix}.balpha"]) ** (1 / 16)
        alpha = alpha.reshape(L, H, dh).transpose(1, 0, 2)        # [H, L, dh]
        if pad:
            alpha = jnp.pad(alpha, ((0, 0), (0, pad), (0, 0)),
                            constant_values=1.0)                  # decay 1
        fn = gla_ad if differentiable else (
            lambda q, k, v, a, C: gla_chunkwise(q, k, v, a, C)[0])
        o = jax.vmap(lambda q, k, v, a: fn(q, k, v, a, C))(q, k, v, alpha)
    elif mixer == "retnet":
        q = layers.feature_map(q, cfg.feature_map) * dh ** -0.5
        k = layers.feature_map(k, cfg.feature_map)
        gam = layers.retnet_gammas(H)                             # [H]
        gseq = jnp.broadcast_to(gam[:, None], (H, Lp))
        fn = scalar_decay_ad if differentiable else (
            lambda q, k, v, g, C: scalar_decay_chunkwise(q, k, v, g, C)[0])
        o = jax.vmap(lambda q, k, v, g: fn(q, k, v, g, C))(q, k, v, gseq)
    elif mixer == "mamba2":
        q = layers.feature_map(q, cfg.feature_map) * dh ** -0.5
        k = layers.feature_map(k, cfg.feature_map)
        gamma = jax.nn.sigmoid(
            x @ p[f"{prefix}.wgamma"] + p[f"{prefix}.bgamma"]) ** (1 / 16)
        if pad:
            gamma = jnp.pad(gamma, ((0, pad), (0, 0)),
                            constant_values=1.0)                  # decay 1
        fn = scalar_decay_ad if differentiable else (
            lambda q, k, v, g, C: scalar_decay_chunkwise(q, k, v, g, C)[0])
        o = jax.vmap(lambda q, k, v, g: fn(q, k, v, g, C))(q, k, v, gamma.T)
    elif mixer == "linattn":
        q = layers.feature_map(q, cfg.feature_map) * dh ** -0.5
        k = layers.feature_map(k, cfg.feature_map)
        fn = linear_attn_ad if differentiable else (
            lambda q, k, v, C: linear_attn_chunkwise(q, k, v, C)[0])
        o = jax.vmap(lambda q, k, v: fn(q, k, v, C))(q, k, v)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    if pad:
        o = o[:, :L]
    o = _head_rms(o, p[f"{prefix}.onorm"], H, dh)
    o = o.transpose(1, 0, 2).reshape(L, H * dh)
    return o @ p[f"{prefix}.wo"]


# ---------------------------------------------------------------------------
# Full LM forward / loss
# ---------------------------------------------------------------------------

def lm_forward(cfg: ModelConfig, params: Params, tokens,
               differentiable: bool = True):
    """tokens : [L] int32 → logits [L, V] (embeddings tied to the LM head)."""
    x = params["embed"][tokens]
    for i, mixer in enumerate(cfg.mixers()):
        Lp = f"L{i:02d}"
        h = layers.rms_norm(x, params[f"{Lp}.norm1"])
        x = x + mixer_forward(cfg, mixer, params, f"{Lp}.mix", h,
                              differentiable)
        h = layers.rms_norm(x, params[f"{Lp}.norm2"])
        x = x + layers.swiglu_ffn(h, {
            "w_gate": params[f"{Lp}.ffn.w_gate"],
            "w_up": params[f"{Lp}.ffn.w_up"],
            "w_down": params[f"{Lp}.ffn.w_down"]})
    x = layers.rms_norm(x, params["final_norm"])
    return x @ params["embed"].T


def lm_loss(cfg: ModelConfig, params: Params, tokens, mask,
            differentiable: bool = True):
    """tokens : [B, L+1] int32, mask : [B, L] f32 over target positions.
    Returns mean masked next-token cross-entropy."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = jax.vmap(lambda t: lm_forward(cfg, params, t, differentiable)
                      )(inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_eval(cfg: ModelConfig, params: Params, tokens, mask):
    """Eval metrics: (masked nll sum, masked argmax-correct sum,
    argmax predictions [B, L] i32).  Feeds both perplexity and the
    synthetic-task accuracy harnesses on the Rust side."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = jax.vmap(lambda t: lm_forward(cfg, params, t, False))(inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = (preds == targets).astype(jnp.float32)
    return (nll * mask).sum(), (correct * mask).sum(), preds


# ---------------------------------------------------------------------------
# Recurrent decode path (constant-memory inference) + prefill
# ---------------------------------------------------------------------------

def state_spec(cfg: ModelConfig, batch: int):
    """Ordered (name, shape) list of decode-state tensors (flat dict)."""
    H, dh = cfg.n_heads, cfg.head_dim
    hd = H * dh
    Kc = cfg.conv_size - 1
    spec = []
    for i, mixer in enumerate(cfg.mixers()):
        L = f"L{i:02d}"
        if mixer in LINEAR_MIXERS:
            spec.append((f"{L}.S", (batch, H, dh, dh)))
            if cfg.use_conv:
                for s in ("q", "k", "v"):
                    spec.append((f"{L}.conv_{s}", (batch, Kc, hd)))
        else:
            spec.append((f"{L}.kcache", (batch, cfg.max_seq_len, hd)))
            spec.append((f"{L}.vcache", (batch, cfg.max_seq_len, hd)))
    return spec


def init_state(cfg: ModelConfig, batch: int):
    return {n: jnp.zeros(s, jnp.float32) for n, s in state_spec(cfg, batch)}


def _mixer_decode_step(cfg, mixer, params, prefix, sname, state, x_t, pos):
    """Single-token mixer step for one sequence.  x_t : [d]."""
    H, dh = cfg.n_heads, cfg.head_dim
    hd = H * dh
    new_state = {}

    def proj(s, w):
        h = x_t @ params[f"{prefix}.{w}"]
        if mixer in LINEAR_MIXERS and cfg.use_conv:
            h, cs = layers.short_conv_step(
                state[f"{sname}.conv_{s}"], h, params[f"{prefix}.conv_{s}"])
            new_state[f"{sname}.conv_{s}"] = cs
        return h

    q = proj("q", "wq")
    k = proj("k", "wk")
    v = proj("v", "wv")

    if mixer in ATTN_MIXERS:
        kc = jax.lax.dynamic_update_slice(
            state[f"{sname}.kcache"], k[None], (pos, 0))
        vc = jax.lax.dynamic_update_slice(
            state[f"{sname}.vcache"], v[None], (pos, 0))
        new_state[f"{sname}.kcache"] = kc
        new_state[f"{sname}.vcache"] = vc
        qh = q.reshape(H, dh)
        qh = jax.vmap(lambda h: layers.rotary(h[None], pos0=pos)[0])(qh)
        kh = kc.reshape(cfg.max_seq_len, H, dh).transpose(1, 0, 2)
        kh = jax.vmap(lambda h: layers.rotary(h))(kh)
        vh = vc.reshape(cfg.max_seq_len, H, dh).transpose(1, 0, 2)
        j = jnp.arange(cfg.max_seq_len)
        if mixer == "swa":
            valid = (j <= pos) & (j > pos - cfg.swa_window)
        else:
            valid = j <= pos
        logits = jnp.einsum("hd,htd->ht", qh, kh) * dh ** -0.5
        logits = jnp.where(valid[None, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("ht,htd->hd", w, vh).reshape(hd)
        return o @ params[f"{prefix}.wo"], new_state

    qh, kh, vh = (t.reshape(H, dh) for t in (q, k, v))
    S = state[f"{sname}.S"]                                    # [H, dh, dh]

    if mixer == "deltanet":
        qh = layers.key_normalize(layers.feature_map(qh, cfg.feature_map),
                                  cfg.key_norm)
        kh = layers.key_normalize(layers.feature_map(kh, cfg.feature_map),
                                  cfg.key_norm)
        beta = jax.nn.sigmoid(x_t @ params[f"{prefix}.wbeta"]
                              + params[f"{prefix}.bbeta"])     # [H]
        o, S = jax.vmap(ref.delta_step)(S, qh, kh, vh, beta)
    elif mixer == "gla":
        qh = layers.feature_map(qh, cfg.feature_map) * dh ** -0.5
        kh = layers.feature_map(kh, cfg.feature_map)
        alpha = jax.nn.sigmoid(x_t @ params[f"{prefix}.walpha"]
                               + params[f"{prefix}.balpha"]) ** (1 / 16)
        o, S = jax.vmap(ref.gla_step)(S, qh, kh, vh, alpha.reshape(H, dh))
    elif mixer == "retnet":
        qh = layers.feature_map(qh, cfg.feature_map) * dh ** -0.5
        kh = layers.feature_map(kh, cfg.feature_map)
        o, S = jax.vmap(ref.scalar_decay_step)(S, qh, kh, vh,
                                               layers.retnet_gammas(H))
    elif mixer == "mamba2":
        qh = layers.feature_map(qh, cfg.feature_map) * dh ** -0.5
        kh = layers.feature_map(kh, cfg.feature_map)
        gamma = jax.nn.sigmoid(x_t @ params[f"{prefix}.wgamma"]
                               + params[f"{prefix}.bgamma"]) ** (1 / 16)
        o, S = jax.vmap(ref.scalar_decay_step)(S, qh, kh, vh, gamma)
    else:  # linattn
        qh = layers.feature_map(qh, cfg.feature_map) * dh ** -0.5
        kh = layers.feature_map(kh, cfg.feature_map)
        o, S = jax.vmap(ref.linear_attn_step)(S, qh, kh, vh)

    new_state[f"{sname}.S"] = S
    o = _head_rms(o[:, None, :], params[f"{prefix}.onorm"], H, dh)[:, 0, :]
    return o.reshape(hd) @ params[f"{prefix}.wo"], new_state


def decode_step(cfg: ModelConfig, params: Params, state, token, pos):
    """One decoding step for a batch.  token : [B] i32, pos : scalar i32
    (shared position — the serve engine batches same-length sequences).
    Returns (logits [B, V], new_state)."""

    def one(tok, st):
        x = params["embed"][tok]
        new_st = {}
        for i, mixer in enumerate(cfg.mixers()):
            Lp = f"L{i:02d}"
            h = layers.rms_norm(x, params[f"{Lp}.norm1"])
            o, ns = _mixer_decode_step(cfg, mixer, params, f"{Lp}.mix",
                                       Lp, st, h, pos)
            x = x + o
            new_st.update(ns)
            h = layers.rms_norm(x, params[f"{Lp}.norm2"])
            x = x + layers.swiglu_ffn(h, {
                "w_gate": params[f"{Lp}.ffn.w_gate"],
                "w_up": params[f"{Lp}.ffn.w_up"],
                "w_down": params[f"{Lp}.ffn.w_down"]})
        x = layers.rms_norm(x, params["final_norm"])
        return x @ params["embed"].T, new_st

    return jax.vmap(one)(token, state)
