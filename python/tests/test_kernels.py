"""Kernel vs oracle — the core correctness signal (pytest + hypothesis).

Every chunkwise/Pallas kernel is checked against the step-by-step recurrent
oracle in kernels.ref, across shapes, chunk sizes and input regimes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref, wy

jax.config.update("jax_enable_x64", False)

ATOL, RTOL = 2e-4, 2e-4


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def make_qkvb(seed, L, dk, dv, normalize_k=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = rand(ks[0], L, dk)
    k = rand(ks[1], L, dk)
    if normalize_k:
        k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    v = rand(ks[2], L, dv)
    beta = jax.nn.sigmoid(rand(ks[3], L))
    return q, k, v, beta


# ---------------------------------------------------------------------------
# WY / UT-transform algebra
# ---------------------------------------------------------------------------

class TestWY:
    @pytest.mark.parametrize("C", [2, 3, 4, 8, 16])
    def test_tri_inv_matches_linalg(self, C):
        A = jnp.tril(rand(jax.random.PRNGKey(C), C, C), -1)
        want = np.linalg.inv(np.eye(C) + np.asarray(A))
        got = wy.tri_inv_unit_lower(A)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("C", [64, 128])
    def test_tri_inv_realistic_regime(self, C):
        """Large chunks with the A the kernel actually sees:
        A = tril(diag(β)KKᵀ, −1) with L2-normalized keys, β ∈ (0,1)."""
        _, k, _, beta = make_qkvb(C, C, 32, 32)
        A = jnp.tril((k * beta[:, None]) @ k.T, -1)
        want = np.linalg.inv(np.eye(C) + np.asarray(A, np.float64))
        got = wy.tri_inv_unit_lower(A)
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)

    @pytest.mark.parametrize("C", [2, 5, 16])
    def test_forward_substitution_matches_doubling(self, C):
        A = jnp.tril(rand(jax.random.PRNGKey(C + 100), C, C), -1)
        np.testing.assert_allclose(
            wy.tri_inv_forward_substitution(A),
            wy.tri_inv_unit_lower(A), atol=1e-4, rtol=1e-4)

    def test_ut_transform_matches_eq7_recurrence(self):
        """W, U from the UT transform == the Eq. 7 sequential recurrences."""
        C, dk, dv = 16, 8, 8
        _, k, v, beta = make_qkvb(0, C, dk, dv)
        W, U = wy.ut_transform(k, v, beta)

        w_seq = np.zeros((C, dk), np.float32)
        u_seq = np.zeros((C, dv), np.float32)
        kn, vn, bn = map(np.asarray, (k, v, beta))
        for r in range(C):
            corr_w = sum(w_seq[i] * (kn[i] @ kn[r]) for i in range(r))
            corr_u = sum(u_seq[i] * (kn[i] @ kn[r]) for i in range(r))
            w_seq[r] = bn[r] * (kn[r] - corr_w)
            u_seq[r] = bn[r] * (vn[r] - corr_u)
        np.testing.assert_allclose(W, w_seq, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(U, u_seq, atol=1e-4, rtol=1e-4)

    def test_wy_p_matrix_is_householder_product(self):
        """P = I − Σ w_t k_tᵀ equals ∏ (I − β_t k_t k_tᵀ) (appendix A)."""
        C, dk = 12, 6
        _, k, v, beta = make_qkvb(1, C, dk, dk)
        W, _ = wy.ut_transform(k, v, beta)
        P_wy = np.eye(dk) - np.asarray(W).T @ np.asarray(k)
        P_prod = np.eye(dk)
        for t in range(C):
            kt = np.asarray(k)[t]
            # row convention: transitions accumulate on the left
            P_prod = P_prod @ (np.eye(dk) - float(beta[t]) * np.outer(kt, kt))
        np.testing.assert_allclose(P_wy, P_prod, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# DeltaNet: recurrent oracle == WY oracle == jnp chunkwise == Pallas kernel
# ---------------------------------------------------------------------------

class TestDeltaNet:
    @pytest.mark.parametrize("L,dk,dv,C", [
        (64, 16, 16, 16), (64, 16, 16, 64), (128, 32, 32, 32),
        (64, 8, 24, 16), (128, 64, 64, 64), (64, 16, 16, 1),
    ])
    def test_chunkwise_pallas_vs_recurrent(self, L, dk, dv, C):
        q, k, v, beta = make_qkvb(L + dk, L, dk, dv)
        o_ref, s_ref = ref.delta_rule_recurrent(q, k, v, beta)
        o, s = kernels.delta_chunkwise(q, k, v, beta, chunk_size=C)
        np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_ref, atol=ATOL, rtol=RTOL)

    def test_wy_oracle_vs_recurrent(self):
        q, k, v, beta = make_qkvb(7, 48, 16, 16)
        o1, s1 = ref.delta_rule_recurrent(q, k, v, beta)
        o2, s2 = ref.delta_rule_wy(q, k, v, beta)
        np.testing.assert_allclose(o1, o2, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s1, s2, atol=ATOL, rtol=RTOL)

    @pytest.mark.parametrize("C", [8, 16, 32, 64])
    def test_chunk_size_invariance(self, C):
        """Output must not depend on the chunk size (C=L is the parallel
        form, small C approaches the recurrent form)."""
        q, k, v, beta = make_qkvb(3, 64, 16, 16)
        o_base, s_base = kernels.delta_chunkwise(q, k, v, beta, chunk_size=64)
        o, s = kernels.delta_chunkwise(q, k, v, beta, chunk_size=C)
        np.testing.assert_allclose(o, o_base, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_base, atol=ATOL, rtol=RTOL)

    def test_jnp_chunkwise_matches_pallas(self):
        q, k, v, beta = make_qkvb(11, 128, 32, 32)
        o1, s1 = kernels.delta_chunkwise_jnp(q, k, v, beta, chunk_size=32)
        o2, s2 = kernels.delta_chunkwise(q, k, v, beta, chunk_size=32)
        np.testing.assert_allclose(o1, o2, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s1, s2, atol=ATOL, rtol=RTOL)

    def test_recurrent_pallas_kernel(self):
        q, k, v, beta = make_qkvb(13, 32, 16, 16)
        o_ref, s_ref = ref.delta_rule_recurrent(q, k, v, beta)
        o, s = kernels.delta_recurrent(q, k, v, beta)
        np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_ref, atol=ATOL, rtol=RTOL)

    def test_initial_state_chaining(self):
        """Running two halves with state chaining == one full pass (the
        prefill/decode contract the serving path depends on)."""
        q, k, v, beta = make_qkvb(17, 64, 16, 16)
        o_full, s_full = kernels.delta_chunkwise_jnp(q, k, v, beta, 16)
        o1, s1 = kernels.delta_chunkwise_jnp(
            q[:32], k[:32], v[:32], beta[:32], 16)
        o2, s2 = kernels.delta_chunkwise_jnp(
            q[32:], k[32:], v[32:], beta[32:], 16, initial_state=s1)
        np.testing.assert_allclose(
            jnp.concatenate([o1, o2]), o_full, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s2, s_full, atol=ATOL, rtol=RTOL)

    def test_beta_zero_freezes_memory(self):
        """β = 0 ⇒ S never changes ⇒ output 0 (identity transition)."""
        q, k, v, _ = make_qkvb(19, 32, 8, 8)
        o, s = kernels.delta_chunkwise(q, k, v, jnp.zeros(32), chunk_size=16)
        np.testing.assert_allclose(o, jnp.zeros_like(o), atol=1e-6)
        np.testing.assert_allclose(s, jnp.zeros_like(s), atol=1e-6)

    def test_beta_one_is_projection_write(self):
        """β = 1 with repeated unit key: second write fully replaces the
        first association (exact retrieval property of the delta rule)."""
        dk = dv = 8
        k1 = jnp.zeros(dk).at[0].set(1.0)
        v1 = jnp.arange(dv, dtype=jnp.float32)
        v2 = -v1
        q = jnp.stack([k1, k1])
        k = jnp.stack([k1, k1])
        v = jnp.stack([v1, v2])
        beta = jnp.ones(2)
        o, s = kernels.delta_chunkwise(q, k, v, beta, chunk_size=2)
        np.testing.assert_allclose(o[0], v1, atol=1e-5)
        np.testing.assert_allclose(o[1], v2, atol=1e-5)  # overwritten

    def test_grad_matches_autodiff_oracle(self):
        """custom-VJP (Pallas fwd + recompute bwd) == autodiff of oracle."""
        q, k, v, beta = make_qkvb(23, 64, 16, 16)

        def loss_ad(q, k, v, b):
            return kernels.delta_chunkwise_ad(q, k, v, b, 16).sum()

        def loss_ref(q, k, v, b):
            return ref.delta_rule_recurrent(q, k, v, b)[0].sum()

        g1 = jax.grad(loss_ad, argnums=(0, 1, 2, 3))(q, k, v, beta)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, beta)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)

    def test_attention_matrix_form(self):
        """Fully-parallel form A = (QKᵀ⊙M)T reproduces the output O = A V."""
        q, k, v, beta = make_qkvb(29, 32, 8, 8)
        A = ref.delta_attention_matrix(q, k, beta)
        o_ref, _ = ref.delta_rule_recurrent(q, k, v, beta)
        np.testing.assert_allclose(A @ v, o_ref, atol=1e-3, rtol=1e-3)

    def test_eigenvalue_stability_bound(self):
        """With L2-normalized keys and β∈(0,1): ‖S‖ stays bounded (the §3.3
        stability argument — eigenvalues of I−βkkᵀ are 1 and 1−β‖k‖²)."""
        q, k, v, beta = make_qkvb(31, 512, 16, 16)  # long roll-out
        _, s = kernels.delta_chunkwise_jnp(q, k, v, beta, 64)
        assert jnp.isfinite(s).all()
        assert jnp.abs(s).max() < 1e3


# ---------------------------------------------------------------------------
# Baseline kernels vs their oracles
# ---------------------------------------------------------------------------

class TestBaselines:
    @pytest.mark.parametrize("C", [16, 32, 64])
    def test_linear_attn(self, C):
        q, k, v, _ = make_qkvb(41, 64, 16, 16)
        o_ref, s_ref = ref.linear_attn_recurrent(q, k, v)
        o, s = kernels.linear_attn_chunkwise(q, k, v, chunk_size=C)
        np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_ref, atol=ATOL, rtol=RTOL)

    @pytest.mark.parametrize("C", [16, 64])
    def test_gla(self, C):
        q, k, v, _ = make_qkvb(43, 64, 16, 16)
        # decay in [0.9, 1): the regime GLA operates in
        alpha = 0.9 + 0.1 * jax.nn.sigmoid(
            rand(jax.random.PRNGKey(5), 64, 16))
        o_ref, s_ref = ref.gla_recurrent(q, k, v, alpha)
        o, s = kernels.gla_chunkwise(q, k, v, alpha, chunk_size=C)
        np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_ref, atol=ATOL, rtol=RTOL)

    def test_retnet(self):
        q, k, v, _ = make_qkvb(47, 64, 16, 16)
        gamma = 0.97
        o_ref, s_ref = ref.retnet_recurrent(q, k, v, gamma)
        o, s = kernels.scalar_decay_chunkwise(
            q, k, v, jnp.full(64, gamma), chunk_size=16)
        np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_ref, atol=ATOL, rtol=RTOL)

    def test_mamba2(self):
        q, k, v, _ = make_qkvb(53, 64, 16, 16)
        gamma = 0.9 + 0.1 * jax.nn.sigmoid(rand(jax.random.PRNGKey(6), 64))
        o_ref, s_ref = ref.mamba2_recurrent(q, k, v, gamma)
        o, s = kernels.scalar_decay_chunkwise(q, k, v, gamma, chunk_size=16)
        np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s, s_ref, atol=ATOL, rtol=RTOL)

    def test_gla_reduces_to_retnet(self):
        """GLA with α_t = γ·1 must equal RetNet."""
        q, k, v, _ = make_qkvb(59, 32, 8, 8)
        gamma = 0.95
        o1, s1 = kernels.gla_chunkwise(
            q, k, v, jnp.full((32, 8), gamma), chunk_size=16)
        o2, s2 = kernels.scalar_decay_chunkwise(
            q, k, v, jnp.full(32, gamma), chunk_size=16)
        np.testing.assert_allclose(o1, o2, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s1, s2, atol=ATOL, rtol=RTOL)

    def test_linear_attn_is_delta_with_beta1_orthogonal_keys(self):
        """With orthonormal keys (≤ d of them) and β=1, DeltaNet's pseudo-
        values equal the raw values ⇒ identical to linear attention."""
        d = 16
        k = jnp.eye(d)                       # 16 orthonormal keys
        q = rand(jax.random.PRNGKey(9), d, d)
        v = rand(jax.random.PRNGKey(10), d, d)
        o1, s1 = kernels.delta_chunkwise(q, k, v, jnp.ones(d), chunk_size=8)
        o2, s2 = kernels.linear_attn_chunkwise(q, k, v, chunk_size=8)
        np.testing.assert_allclose(o1, o2, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(s1, s2, atol=ATOL, rtol=RTOL)

    def test_flash_attention(self):
        q, k, v, _ = make_qkvb(61, 128, 32, 32)
        o_ref = ref.softmax_attention(q, k, v)
        o = kernels.flash_attention(q, k, v, block=32)
        np.testing.assert_allclose(o, o_ref, atol=1e-4, rtol=1e-4)

    def test_swa_window_equals_full_when_window_ge_L(self):
        q, k, v, _ = make_qkvb(67, 32, 16, 16)
        o1 = kernels.sliding_window_attention(q, k, v, window=32)
        o2 = kernels.causal_attention(q, k, v)
        np.testing.assert_allclose(o1, o2, atol=1e-5, rtol=1e-5)

    def test_swa_locality(self):
        """Changing a key/value outside the window must not change o_i."""
        q, k, v, _ = make_qkvb(71, 64, 8, 8)
        w = 8
        o = kernels.sliding_window_attention(q, k, v, window=w)
        k2 = k.at[0].set(k[0] + 10.0)
        v2 = v.at[0].set(v[0] - 5.0)
        o2 = kernels.sliding_window_attention(q, k2, v2, window=w)
        np.testing.assert_allclose(o[w:], o2[w:], atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, chunk sizes, input regimes
# ---------------------------------------------------------------------------

class TestHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        log_l=st.integers(4, 7),           # L ∈ {16..128}
        dk=st.sampled_from([4, 8, 16, 32]),
        dv=st.sampled_from([4, 8, 16, 32]),
        log_c=st.integers(0, 5),
    )
    def test_delta_chunkwise_random(self, seed, log_l, dk, dv, log_c):
        L = 2 ** log_l
        C = min(2 ** log_c, L)
        q, k, v, beta = make_qkvb(seed, L, dk, dv)
        o_ref, s_ref = ref.delta_rule_recurrent(q, k, v, beta)
        o, s = kernels.delta_chunkwise_jnp(q, k, v, beta, chunk_size=C)
        np.testing.assert_allclose(o, o_ref, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(s, s_ref, atol=1e-3, rtol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16),
           beta_mode=st.sampled_from(["zeros", "ones", "half", "random"]))
    def test_delta_beta_regimes(self, seed, beta_mode):
        L, d = 32, 8
        q, k, v, _ = make_qkvb(seed, L, d, d)
        beta = {
            "zeros": jnp.zeros(L), "ones": jnp.ones(L),
            "half": jnp.full(L, 0.5),
            "random": jax.nn.sigmoid(rand(jax.random.PRNGKey(seed), L)),
        }[beta_mode]
        o_ref, s_ref = ref.delta_rule_recurrent(q, k, v, beta)
        o, s = kernels.delta_chunkwise(q, k, v, beta, chunk_size=8)
        np.testing.assert_allclose(o, o_ref, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(s, s_ref, atol=1e-3, rtol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_unnormalized_keys_still_exact(self, seed):
        """The algorithm is exact for any keys (normalization is a modeling
        choice, not an algorithmic requirement)."""
        q, k, v, beta = make_qkvb(seed, 32, 8, 8, normalize_k=False)
        beta = beta * 0.5  # keep ‖I−βkkᵀ‖ bounded for numerical sanity
        o_ref, s_ref = ref.delta_rule_recurrent(q, k, v, beta)
        o, s = kernels.delta_chunkwise(q, k, v, beta, chunk_size=16)
        np.testing.assert_allclose(o, o_ref, atol=5e-3, rtol=5e-3)
