"""L2 model tests: shapes, gradients, decode≡parallel equivalence, export."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import optim
from compile.model import ModelConfig


def tiny_cfg(arch="deltanet", **kw):
    base = dict(vocab_size=32, d_model=32, n_layers=2, n_heads=2,
                chunk_size=8, swa_window=8, max_seq_len=32, arch=arch)
    base.update(kw)
    return ModelConfig(**base)


ALL_ARCHS = ["deltanet", "gla", "retnet", "mamba2", "linattn",
             "transformer", "hybrid_swa", "hybrid_global"]


class TestForward:
    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_forward_shapes_and_finite(self, arch):
        cfg = tiny_cfg(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (16,), 0,
                                    cfg.vocab_size)
        logits = M.lm_forward(cfg, params, tokens)
        assert logits.shape == (16, cfg.vocab_size)
        assert jnp.isfinite(logits).all()

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_loss_and_grads_finite(self, arch):
        cfg = tiny_cfg(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                    cfg.vocab_size)
        mask = jnp.ones((2, 16))
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, tokens, mask))(params)
        assert jnp.isfinite(loss)
        assert set(grads) == set(params)
        for k, g in grads.items():
            assert jnp.isfinite(g).all(), k

    def test_mixer_list_expansion(self):
        assert tiny_cfg("hybrid_swa", n_layers=4).mixers() == [
            "deltanet", "swa", "deltanet", "swa"]
        assert tiny_cfg("hybrid_global", n_layers=6).mixers() == [
            "deltanet", "attn", "deltanet", "deltanet", "attn", "deltanet"]
        assert tiny_cfg("transformer").mixers() == ["attn", "attn"]

    def test_loss_mask_excludes_positions(self):
        """Loss must ignore masked positions entirely."""
        cfg = tiny_cfg()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 17), 0, 32)
        t2 = t1.at[0, -1].set((t1[0, -1] + 5) % 32)  # differ in last target
        mask = jnp.ones((1, 16)).at[0, -1].set(0.0)
        l1 = M.lm_loss(cfg, params, t1, mask)
        l2 = M.lm_loss(cfg, params, t2, mask)
        np.testing.assert_allclose(l1, l2, atol=1e-6)

    def test_feature_map_and_norm_variants(self):
        for fm, kn in (("silu", "l2"), ("elu1", "l1"), ("relu", "l2")):
            cfg = tiny_cfg(feature_map=fm, key_norm=kn)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            tokens = jnp.arange(16) % 32
            assert jnp.isfinite(M.lm_forward(cfg, params, tokens)).all()


class TestTraining:
    @pytest.mark.parametrize("arch", ["deltanet", "hybrid_swa"])
    def test_loss_decreases_on_fixed_batch(self, arch):
        """Overfit one batch for a few steps: loss must drop (the full
        fwd+bwd+AdamW loop works end to end)."""
        cfg = tiny_cfg(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        m = {k: jnp.zeros_like(p) for k, p in params.items()}
        v = {k: jnp.zeros_like(p) for k, p in params.items()}
        tokens = jax.random.randint(jax.random.PRNGKey(7), (4, 17), 0, 32)
        mask = jnp.ones((4, 16))

        @jax.jit
        def step(params, m, v, i):
            loss, grads = jax.value_and_grad(
                lambda p: M.lm_loss(cfg, p, tokens, mask))(params)
            params, m, v = optim.adamw_update(params, grads, m, v, i, 1e-2)
            return params, m, v, loss

        losses = []
        for i in range(8):
            params, m, v, loss = step(params, m, v, jnp.float32(i + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.9, losses

    def test_adamw_weight_decay_only_on_matrices(self):
        cfg = tiny_cfg()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        zeros = {k: jnp.zeros_like(p) for k, p in params.items()}
        # zero grads: update is wd·p for matrices, 0 for vectors
        new_p, _, _ = optim.adamw_update(params, zeros, zeros, zeros,
                                         jnp.float32(1), 1e-2)
        for k, p in params.items():
            if p.ndim >= 2:
                np.testing.assert_allclose(new_p[k], p * (1 - 1e-2 * 1e-2),
                                           rtol=1e-5)
            else:
                np.testing.assert_allclose(new_p[k], p, rtol=1e-6)


class TestDecode:
    @pytest.mark.parametrize("arch", ["deltanet", "gla", "retnet", "mamba2",
                                      "linattn", "hybrid_swa",
                                      "hybrid_global", "transformer"])
    def test_decode_matches_parallel_forward(self, arch):
        """Token-by-token decoding must produce the same logits as the
        parallel (training) forward — the core serving-path contract."""
        cfg = tiny_cfg(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        L = 12
        tokens = jax.random.randint(jax.random.PRNGKey(3), (L,), 0, 32)
        want = M.lm_forward(cfg, params, tokens, differentiable=False)

        state = M.init_state(cfg, batch=1)
        got = []
        step = jax.jit(lambda s, t, p: M.decode_step(cfg, params, s, t, p))
        for pos in range(L):
            logits, state = step(state, tokens[pos][None],
                                 jnp.int32(pos))
            got.append(logits[0])
        got = jnp.stack(got)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)

    def test_state_spec_matches_init_state(self):
        cfg = tiny_cfg("hybrid_global", n_layers=4)
        spec = dict(M.state_spec(cfg, 3))
        state = M.init_state(cfg, 3)
        assert set(spec) == set(state)
        for k, s in spec.items():
            assert state[k].shape == tuple(s)


class TestParamSpec:
    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_spec_matches_init(self, arch):
        cfg = tiny_cfg(arch)
        spec = M.param_spec(cfg)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        assert [n for n, _, _ in spec] == sorted(params)  # sorted = jit order
        for n, s, _ in spec:
            assert params[n].shape == tuple(s), n

    def test_param_count_scaling(self):
        """DeltaNet layer ≈ 4d² mixer + 8d² FFN (paper §3.3)."""
        cfg = tiny_cfg("deltanet", d_model=64, n_layers=1, vocab_size=0 or 1)
        n = sum(np.prod(s) for nm, s, _ in M.param_spec(cfg)
                if nm.startswith("L00"))
        d = 64
        assert 11.5 * d * d < n < 13.5 * d * d, n / d / d
