"""Exporter contract tests: the manifest must describe the lowered program
exactly (names, order, shapes) — the Rust runtime trusts it blindly."""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M
from compile.model import ModelConfig


class TestPresets:
    @pytest.mark.parametrize("preset", list(aot.PRESETS))
    @pytest.mark.parametrize("arch", aot.ARCHS)
    def test_make_config_valid(self, preset, arch):
        cfg = aot.make_config(preset, arch)
        assert cfg.d_model % cfg.n_heads == 0
        assert len(cfg.mixers()) == cfg.n_layers
        # seq_len must be chunk-padding friendly at export shapes
        L = aot.PRESETS[preset]["seq_len"]
        assert L >= cfg.chunk_size

    def test_tiny_vocab_fits_all_tasks(self):
        # mirror of the Rust-side invariant (prop_data): every synthetic
        # task's alphabet must fit the tiny artifact vocab
        assert aot.PRESETS["tiny"]["vocab_size"] >= 98  # mqar:16


class TestManifestContract:
    def test_entries_order_matches_jit_flatten(self):
        """_entries must enumerate leaves in the exact order jax.jit
        flattens a flat dict (sorted keys)."""
        cfg = aot.make_config("tiny", "deltanet")
        pa = aot.param_abstract(cfg)
        entries = aot._entries(pa, "params", "param",
                               {n: i for n, _, i in M.param_spec(cfg)})
        names = [e["name"].split(".", 1)[1] for e in entries]
        leaves, treedef = jax.tree_util.tree_flatten(pa)
        assert names == sorted(pa)           # sorted-key flatten order
        assert len(names) == len(leaves)
        # shapes line up leaf-by-leaf
        for e, leaf in zip(entries, leaves):
            assert tuple(e["shape"]) == tuple(leaf.shape), e["name"]

    def test_param_spec_is_sorted(self):
        for arch in aot.ARCHS:
            cfg = aot.make_config("tiny", arch)
            names = [n for n, _, _ in M.param_spec(cfg)]
            assert names == sorted(names), arch

    def test_state_spec_covers_all_mixers(self):
        cfg = aot.make_config("tiny", "hybrid_global")
        names = [n for n, _ in M.state_spec(cfg, 2)]
        mixers = cfg.mixers()
        for i, m in enumerate(mixers):
            Lp = f"L{i:02d}"
            if m in ("attn", "swa"):
                assert f"{Lp}.kcache" in names
            else:
                assert f"{Lp}.S" in names

    def test_written_artifact_matches_lowered_program(self, tmp_path):
        """Build one real artifact and verify manifest ↔ HLO agreement
        (input count equals the program's parameter count)."""
        name = aot.build_eval(str(tmp_path), "deltanet", "tiny")
        man = json.load(open(tmp_path / f"{name}.manifest.json"))
        hlo = open(tmp_path / f"{name}.hlo.txt").read()
        # count parameter(...) declarations inside the ENTRY computation
        # only (nested while/fusion computations declare their own)
        entry = hlo[hlo.index("ENTRY "):]
        entry = entry[:entry.index("\n}")]
        n_params = entry.count("parameter(")
        assert n_params == len(man["inputs"]), \
            f"manifest {len(man['inputs'])} vs program {n_params}"
        assert man["kind"] == "eval"
        assert man["config"]["arch"] == "deltanet"

    def test_artifact_roles_complete(self, tmp_path):
        name = aot.build_train(str(tmp_path), "linattn", "tiny")
        man = json.load(open(tmp_path / f"{name}.manifest.json"))
        roles = {e["role"] for e in man["inputs"]}
        assert roles == {"param", "opt_m", "opt_v", "data"}
        # every param has an init and every init parses
        for e in man["inputs"]:
            if e["role"] == "param":
                init = e["init"]
                assert (init in ("zeros", "ones")
                        or init.startswith(("normal:", "const:"))), e
        # outputs: one carried tensor per param/m/v plus the loss
        n_par = sum(1 for e in man["inputs"] if e["role"] == "param")
        assert len(man["outputs"]) == 3 * n_par + 1


class TestLoweringNumerics:
    def test_eval_fn_counts_and_preds(self):
        """The eval computation's outputs obey their definitions."""
        cfg = ModelConfig(vocab_size=32, d_model=32, n_layers=1, n_heads=2,
                          chunk_size=8, max_seq_len=32, arch="deltanet")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 32)
        mask = jnp.ones((2, 8)).at[0, 0].set(0.0)
        nll, correct, preds = M.lm_eval(cfg, params, tokens, mask)
        assert nll > 0 and jnp.isfinite(nll)
        assert 0 <= correct <= mask.sum()
        assert preds.shape == (2, 8) and preds.dtype == jnp.int32
        # recompute correct from preds
        want = ((preds == tokens[:, 1:]) * mask).sum()
        assert jnp.allclose(correct, want)
