//! MQAR capacity sweep (the paper's motivating synthetic, Figure 2):
//! train DeltaNet vs a decay-based linear model on associative recall with
//! a growing number of key-value pairs, and watch the delta rule hold
//! recall accuracy where additive/decay state degrades.
//!
//!     cargo run --release --example mqar_sweep

use deltanet::config::DataConfig;
use deltanet::eval::{pct, Table};
use deltanet::repro::{train_cell, ReproOpts};
use deltanet::runtime::Runtime;

fn main() -> deltanet::Result<()> {
    let runtime = Runtime::new("artifacts")?;
    let steps: usize = std::env::var("MQAR_STEPS").ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(250);
    let opts = ReproOpts { steps, seed: 3, eval_batches: 8,
                           ..Default::default() };

    let mut table = Table::new(
        &format!("MQAR sweep: recall accuracy (%) after {steps} steps"),
        &["kv pairs", "deltanet", "mamba2 (decay)"]);

    // offline, deltanet trains on the host engine; mamba2 has no host
    // implementation, so its column prints "-" instead of aborting
    let mut cell = |artifact: &str, pairs: usize| {
        train_cell(&runtime, artifact,
                   DataConfig::Mqar { num_pairs: pairs, seed: 3 }, &opts)
            .map(|(e, _)| pct(e.accuracy))
            .unwrap_or_else(|_| "-".into())
    };
    for pairs in [4, 8, 12] {
        let d = cell("deltanet_tiny", pairs);
        let m = cell("mamba2_tiny", pairs);
        table.row(vec![pairs.to_string(), d, m]);
    }
    table.print();
    println!("expected shape: deltanet stays near 100% as pairs grow; \
              decay-state models fall off.");
    Ok(())
}
