//! Quickstart: load the AOT-compiled chunkwise DeltaNet kernel, run it via
//! PJRT, and cross-check the numerics against the pure-Rust reference
//! implementation of the paper's algorithm.
//!
//!     make artifacts && cargo run --release --example quickstart

use deltanet::reference;
use deltanet::runtime::{HostValue, Runtime};
use deltanet::tensor::Mat;

fn main() -> deltanet::Result<()> {
    let runtime = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", runtime.platform());

    // one of the Fig-1 kernel artifacts: chunkwise DeltaNet forward,
    // B=16 sequences of L=256 tokens, d_head=32, chunk C=64
    let (b, l, d) = (16usize, 256usize, 32usize);
    let exe = runtime.load("kernel_chunkwise_L256_d32_C64_B16")?;
    println!("loaded {} (compile {:.2}s)", exe.manifest.name,
             exe.compile_time.as_secs_f64());

    // random problems with L2-normalized keys (the regime the model uses)
    let mut q_all = vec![0f32; b * l * d];
    let mut k_all = vec![0f32; b * l * d];
    let mut v_all = vec![0f32; b * l * d];
    let mut beta_all = vec![0f32; b * l];
    let mut problems = vec![];
    for bi in 0..b {
        let (q, k, v, beta) =
            reference::random_problem(l, d, d, 42 + bi as u64);
        q_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&q.data);
        k_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&k.data);
        v_all[bi * l * d..(bi + 1) * l * d].copy_from_slice(&v.data);
        beta_all[bi * l..(bi + 1) * l].copy_from_slice(&beta);
        problems.push((q, k, v, beta));
    }

    let t0 = std::time::Instant::now();
    let outs = exe.run(&[
        HostValue::from_f32(&[b, l, d], q_all)?,
        HostValue::from_f32(&[b, l, d], k_all)?,
        HostValue::from_f32(&[b, l, d], v_all)?,
        HostValue::from_f32(&[b, l], beta_all)?,
    ])?;
    println!("PJRT execute: {:.1} ms for {} tokens",
             t0.elapsed().as_secs_f64() * 1e3, b * l);

    // cross-check sequence 0 against the pure-Rust recurrence
    let o = outs[0].as_f32()?;
    let (q, k, v, beta) = &problems[0];
    let want = reference::delta_recurrent(q, k, v, beta, None);
    let got = Mat::from_vec(l, d, o[..l * d].to_vec())?;
    deltanet::ensure!(got.allclose(&want.o, 1e-3, 1e-3),
                    "kernel output disagrees with the reference recurrence");
    println!("numerics OK: chunkwise PJRT kernel == pure-Rust delta rule");

    let s = outs[1].as_f32()?;
    let got_s = Mat::from_vec(d, d, s[..d * d].to_vec())?;
    deltanet::ensure!(got_s.allclose(&want.state, 1e-3, 1e-3));
    println!("state OK: S after {l} tokens matches ({d}x{d})");
    Ok(())
}
