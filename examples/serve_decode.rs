//! Serving demo: batched constant-memory recurrent decoding behind the
//! static-batching admission queue, with latency/throughput reporting —
//! the inference-side payoff of the linear-transformer state (no KV cache
//! for DeltaNet layers).
//!
//! Works with or without artifacts: when the PJRT backend and the
//! `.decode` artifact are present the engine runs the compiled step,
//! otherwise it serves a host model through the same `DecodeEngine` —
//! the Backend-trait serving path.
//!
//!     cargo run --release --example serve_decode

use std::path::Path;
use std::time::{Duration, Instant};

use deltanet::coordinator::generate::Sampling;
use deltanet::coordinator::server::{GenRequest, ServeEngine};

fn main() -> deltanet::Result<()> {
    // DELTANET_TRACE=TRACE_serve.json captures serve.batch/decode.* spans
    deltanet::obs::trace::init_from_env();
    deltanet::obs::flight::init_from_env();
    let artifact = "deltanet_tiny";

    println!("== serving demo: {artifact} ==");
    // DecodeRoute picks pjrt vs host; the engine itself is built inside
    // the serving thread (PJRT handles are not Send)
    let (serve, route) = ServeEngine::spawn_auto(
        Path::new("artifacts"), artifact, 0,
        Sampling::TopK { temperature: 0.8, k: 8 },
        Duration::from_millis(10),
    )?;
    println!("backend {} | d_model {} | state per layer-head: {}x{} f32 \
              (constant in sequence length)",
             route.backend, route.d_model,
             route.d_model / route.n_heads, route.d_model / route.n_heads);
    let (vocab, batch) = (route.vocab as i32, route.batch);

    // a burst of requests with heterogeneous prompt lengths
    let n_requests = 24;
    let max_new = 24;
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n_requests)
        .map(|i| {
            let len = 3 + (i % 6);
            let prompt: Vec<i32> =
                (0..len).map(|j| ((7 * i + j) as i32) % vocab).collect();
            serve.submit(GenRequest { prompt, max_new })
        })
        .collect::<deltanet::Result<_>>()?;

    let mut latencies: Vec<f64> = vec![];
    for (i, t) in tickets.into_iter().enumerate() {
        let resp = t.wait()?;
        latencies.push(resp.queue_ms + resp.decode_ms);
        if i < 3 {
            println!("request {i}: {} new tokens, queue {:.1} ms, \
                      decode {:.1} ms", resp.tokens.len(),
                     resp.queue_ms, resp.decode_ms);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = serve.shutdown();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    println!("\n{} requests in {} batches (occupancy {:.1}/{})",
             st.requests, st.batches, st.mean_batch_occupancy(), batch);
    println!("latency p50 {:.1} ms | p90 {:.1} ms | p99 {:.1} ms",
             p(0.5), p(0.9), p(0.99));
    println!("decode throughput {:.0} tok/s | wall {:.2}s",
             st.tokens_per_sec(), wall);
    deltanet::ensure!(st.requests == n_requests);

    // the same numbers the /metrics endpoint would serve
    for name in ["serve.queue_ms", "serve.decode_ms",
                 "serve.batch_decode_ms"] {
        let h = deltanet::obs::metrics::histogram(name);
        let s = h.stats();
        println!("{name}: p50 {:.1} | p95 {:.1} | p99 {:.1} (n={})",
                 s.p50_ms, s.p95_ms, s.p99_ms, s.count);
    }
    if let Some(path) = deltanet::obs::trace::write_trace_from_env()? {
        println!("trace written to {} (open at https://ui.perfetto.dev)",
                 path.display());
    }
    Ok(())
}
