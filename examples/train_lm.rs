//! End-to-end driver: train a DeltaNet transformer LM on the synthetic
//! corpus for a few hundred steps and log the loss curve — proving all
//! three layers compose (Pallas kernel → JAX train-step HLO → Rust
//! coordinator via PJRT).
//!
//! By default uses the largest artifact present: `deltanet_e2e` (~28M
//! params, built by `make e2e`) if available, else `deltanet_small`, else
//! `deltanet_tiny`.  Override with DELTANET_E2E_ARTIFACT / _STEPS.
//!
//!     make e2e          # exports deltanet_e2e and runs this driver
//!     cargo run --release --example train_lm     # uses what's built

use deltanet::config::{DataConfig, LrSchedule, RunConfig};
use deltanet::coordinator::Trainer;
use deltanet::data::batcher::Split;
use deltanet::runtime::Runtime;

fn main() -> deltanet::Result<()> {
    let runtime = Runtime::new("artifacts")?;
    let artifact = std::env::var("DELTANET_E2E_ARTIFACT").ok()
        .or_else(|| ["deltanet_e2e", "deltanet_small", "deltanet_tiny"]
            .iter()
            .find(|a| runtime.has_artifact(&format!("{a}.train")))
            .map(|s| s.to_string()))
        .ok_or_else(|| deltanet::err!("no deltanet train artifact; \
                                        run `make artifacts`"))?;
    let steps: usize = std::env::var("DELTANET_E2E_STEPS").ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut trainer = Trainer::new(&runtime, &artifact, 7)?;
    println!("== end-to-end LM training ==");
    println!("artifact  : {artifact}");
    println!("params    : {}", trainer.param_count());
    println!("batch     : {} x {} tokens", trainer.batch, trainer.seq_len);
    println!("steps     : {steps}");

    let data = DataConfig::Corpus { seed: 7 };
    let split = Split::from_config(&data);
    let mut train_task = split.train;
    let mut eval_task = split.eval;

    let log_path = std::path::PathBuf::from("train_lm_loss.jsonl");
    let cfg = RunConfig {
        artifact: artifact.clone(),
        artifacts_dir: "artifacts".into(),
        steps,
        seed: 7,
        lr: LrSchedule::paper_default(steps),
        data,
        eval_every: (steps / 4).max(1),
        eval_batches: 4,
        log_path: Some(log_path.clone()),
        checkpoint_path: Some("checkpoints/train_lm.npz".into()),
    };

    let report = trainer.train(&cfg, train_task.as_mut(),
                               Some(eval_task.as_mut()))?;

    println!("\nloss curve (from {}):", log_path.display());
    let text = std::fs::read_to_string(&log_path)?;
    let records: Vec<&str> = text.lines().collect();
    let show = 12.min(records.len());
    for i in 0..show {
        let idx = i * (records.len() - 1) / (show - 1).max(1);
        println!("  {}", records[idx]);
    }

    println!("\nsummary: loss {:.4} -> {:.4} | {:.0} tok/s | {:.1}s total",
             report.first_loss, report.final_loss,
             report.tokens_per_sec, report.elapsed_secs);
    for (step, e) in &report.evals {
        println!("  eval@{step}: held-out ppl {:.3} (nll {:.4}) acc {:.1}%",
                 e.ppl, e.nll, 100.0 * e.accuracy);
    }
    // The corpus has a known entropy floor (MarkovCorpus::entropy_rate ≈
    // 1.9 nats for fanout 8); a working trainer must approach it.
    deltanet::ensure!(report.final_loss < report.first_loss,
                    "loss did not decrease");
    println!("\ncheckpoint: checkpoints/train_lm.npz");
    Ok(())
}
