//! End-to-end driver: train a DeltaNet transformer LM for a few hundred
//! steps and log the loss curve.
//!
//! With PJRT artifacts present this proves all three layers compose
//! (Pallas kernel → JAX train-step HLO → Rust coordinator via PJRT) on the
//! synthetic corpus; with no artifacts the Trainer falls back to the pure
//! host engine (chunkwise forward + hand-derived backward + AdamW) on the
//! MQAR recall task, so this driver runs offline too.
//!
//! By default uses the largest artifact present: `deltanet_e2e` (~28M
//! params, built by `make e2e`) if available, else `deltanet_small`, else
//! `deltanet_tiny` (which trains host-side when its `.train` artifact is
//! missing).  Override with DELTANET_E2E_ARTIFACT / _STEPS.
//!
//!     make e2e          # exports deltanet_e2e and runs this driver
//!     cargo run --release --example train_lm     # uses what's built

use deltanet::config::{DataConfig, LrSchedule, RunConfig};
use deltanet::coordinator::Trainer;
use deltanet::data::batcher::Split;
use deltanet::metrics::Ewma;
use deltanet::runtime::Runtime;
use deltanet::util::json::Json;
use deltanet::Context;

fn main() -> deltanet::Result<()> {
    // DELTANET_TRACE=TRACE_train.json captures a hierarchical span trace
    // (train.step → train.forward/backward/optimizer → kernel spans)
    deltanet::obs::trace::init_from_env();
    // arm the crash post-mortem (FLIGHT_<run>.json on any panic)
    deltanet::obs::flight::init_from_env();
    let runtime = Runtime::new("artifacts")?;
    let artifact = std::env::var("DELTANET_E2E_ARTIFACT").ok()
        .or_else(|| ["deltanet_e2e", "deltanet_small", "deltanet_tiny"]
            .iter()
            .find(|a| runtime.has_artifact(&format!("{a}.train")))
            .map(|s| s.to_string()))
        // nothing on disk: deltanet_tiny trains on the host engine
        .unwrap_or_else(|| "deltanet_tiny".to_string());

    let mut trainer = Trainer::new(&runtime, &artifact, 7)?;
    let host = trainer.backend_name() == "host";
    let steps: usize = std::env::var("DELTANET_E2E_STEPS").ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if host { 150 } else { 300 });

    println!("== end-to-end LM training ==");
    println!("artifact  : {artifact}");
    println!("backend   : {}", trainer.backend_name());
    println!("params    : {}", trainer.param_count());
    println!("batch     : {} x {} tokens", trainer.batch, trainer.seq_len);
    println!("steps     : {steps}");

    // the host model is small; MQAR shows learning (and the paper's point)
    // much faster than the Markov corpus there
    let data = if host {
        DataConfig::Mqar { num_pairs: 8, seed: 7 }
    } else {
        DataConfig::Corpus { seed: 7 }
    };
    let split = Split::from_config(&data);
    let mut train_task = split.train;
    let mut eval_task = split.eval;

    let log_path = std::path::PathBuf::from("train_lm_loss.jsonl");
    let cfg = RunConfig {
        artifact: artifact.clone(),
        artifacts_dir: "artifacts".into(),
        steps,
        seed: 7,
        lr: LrSchedule::paper_default(steps),
        data,
        eval_every: (steps / 4).max(1),
        eval_batches: 4,
        log_path: Some(log_path.clone()),
        checkpoint_path: Some("checkpoints/train_lm.npz".into()),
    };

    let report = trainer.train(&cfg, train_task.as_mut(),
                               Some(eval_task.as_mut()))?;

    println!("\nloss curve (from {}):", log_path.display());
    let text = std::fs::read_to_string(&log_path)?;
    let records: Vec<&str> = text.lines().collect();
    let show = 12.min(records.len());
    for i in 0..show {
        let idx = i * (records.len() - 1) / (show - 1).max(1);
        println!("  {}", records[idx]);
    }

    // steps >= 1 here, so both endpoints are recorded
    let first_loss = report.first_loss.context("no first loss recorded")?;
    let final_loss = report.final_loss.context("no final loss recorded")?;
    println!("\nsummary: loss {:.4} -> {:.4} | {:.0} tok/s | {:.1}s total",
             first_loss, final_loss,
             report.tokens_per_sec, report.elapsed_secs);
    for (step, e) in &report.evals {
        println!("  eval@{step}: held-out ppl {:.3} (nll {:.4}) acc {:.1}%",
                 e.ppl, e.nll, 100.0 * e.accuracy);
    }

    // Smooth the per-step losses (EWMA) and require the smoothed curve to
    // drop strictly across quarter checkpoints — a stronger claim than
    // first-vs-last, robust to per-batch noise.
    let mut ew = Ewma::new(0.08);
    let smoothed: Vec<f64> = records.iter()
        .map(|line| Ok(ew.update(Json::parse(line)?.req("loss")?.as_f64()?)))
        .collect::<deltanet::Result<_>>()?;
    if smoothed.len() >= 8 {
        let q = |f: f64| smoothed[(((smoothed.len() - 1) as f64) * f) as usize];
        let (s25, s50, s100) = (q(0.25), q(0.5), q(1.0));
        println!("smoothed loss: 25% {:.4} | 50% {:.4} | end {:.4}",
                 s25, s50, s100);
        deltanet::ensure!(s25 > s50 && s50 > s100,
                          "smoothed loss is not strictly decreasing: \
                           {s25:.4} -> {s50:.4} -> {s100:.4}");
    }
    deltanet::ensure!(final_loss < first_loss, "loss did not decrease");
    println!("\ncheckpoint: checkpoints/train_lm.npz");

    let step_hist = deltanet::obs::metrics::histogram("train.step_ms");
    if step_hist.count() > 0 {
        let s = step_hist.stats();
        println!("train.step_ms: p50 {:.1} | p95 {:.1} | p99 {:.1} \
                  (n={})", s.p50_ms, s.p95_ms, s.p99_ms, s.count);
    }
    if let Some(path) = deltanet::obs::trace::write_trace_from_env()? {
        println!("trace written to {} (open at https://ui.perfetto.dev)",
                 path.display());
    }
    Ok(())
}
